"""End-to-end driver (the paper's kind = inference): serve a small LM

with batched requests and 1-bit packed weights.

* loads a reduced starcoder2 config with QuantMode.BINARY_WEIGHT,
* packs every projection ONCE (paper C2, 16-32x weight memory cut),
* prefills a batch of prompts and decodes with continuous batching,
* reports tokens/s and the packed-vs-fp parameter bytes.

    PYTHONPATH=src python examples/serve_binary_lm.py [--new 24]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import linear as LN
from repro.models import model as M
from repro.utils.tree import tree_bytes


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new", type=int, default=24)
    args = ap.parse_args()

    cfg = get_config("starcoder2-3b", quant="binary_weight", reduced=True)
    key = jax.random.PRNGKey(0)
    params_fp = M.init_model(key, cfg)
    fp_bytes = tree_bytes(params_fp["stack"])
    params = LN.maybe_pack_tree(params_fp, cfg.quant)
    print(f"packed stack: {fp_bytes} -> {tree_bytes(params['stack'])} bytes"
          f" ({fp_bytes / tree_bytes(params['stack']):.1f}x)")

    max_len = args.prompt_len + args.new
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    t0 = time.monotonic()
    logits, cache = jax.jit(
        lambda p, b: M.prefill(p, cfg, b, max_len))(params,
                                                    {"tokens": prompts})
    jax.block_until_ready(logits)
    print(f"prefill {args.batch}x{args.prompt_len}: "
          f"{time.monotonic() - t0:.2f}s")

    decode = jax.jit(lambda p, c, t, i: M.decode_step(p, cfg, t, c, i))
    tok = jnp.argmax(logits[:, 0], -1)[:, None].astype(jnp.int32)
    toks = [tok]
    t0 = time.monotonic()
    for t in range(args.new - 1):
        logits, cache = decode(params, cache, tok,
                               jnp.int32(args.prompt_len + t))
        tok = jnp.argmax(logits[:, 0], -1)[:, None].astype(jnp.int32)
        toks.append(tok)
    jax.block_until_ready(tok)
    dt = time.monotonic() - t0
    total = (args.new - 1) * args.batch
    print(f"decoded {total} tokens in {dt:.2f}s "
          f"({total / dt:.1f} tok/s batched)")
    out = jnp.concatenate(toks, axis=1)
    for b in range(args.batch):
        print(f"  seq{b}: {out[b, :12].tolist()} ...")


if __name__ == "__main__":
    main()
