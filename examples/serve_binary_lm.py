"""Serve a small LM with continuous batching and 1-bit packed weights.

The LM-side serving demo (the packed BCNN/BMLP serving engine is
``python -m repro.launch.serve``; see docs/serving.md):

* loads a reduced starcoder2 config with QuantMode.BINARY_WEIGHT,
* packs every projection ONCE (paper C2, 16-32x weight memory cut),
* drives ``train.serve.BatchedServer`` — a ragged mix of requests
  shares one ring of decode slots; finished requests free their slot
  for the next queued prompt, and requests the shared cache cannot
  finish come back flagged ``truncated`` (never dropped),
* reports tokens/s and the packed-vs-fp parameter bytes.

    PYTHONPATH=src python examples/serve_binary_lm.py [--requests 6]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import linear as LN
from repro.models import model as M
from repro.train import serve as SV
from repro.utils.tree import tree_bytes


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=48)
    args = ap.parse_args()

    cfg = get_config("starcoder2-3b", quant="binary_weight", reduced=True)
    key = jax.random.PRNGKey(0)
    params_fp = M.init_model(key, cfg)
    fp_bytes = tree_bytes(params_fp["stack"])
    params = LN.maybe_pack_tree(params_fp, cfg.quant)
    print(f"packed stack: {fp_bytes} -> {tree_bytes(params['stack'])} bytes"
          f" ({fp_bytes / tree_bytes(params['stack']):.1f}x)")

    server = SV.BatchedServer(cfg, params, batch_slots=args.slots,
                              max_len=args.max_len)
    # Ragged request mix: prompts of different lengths, different budgets
    # — continuous batching packs them into the slot ring as slots free.
    reqs = [SV.Request(
        rid=i,
        prompt=jax.random.randint(jax.random.fold_in(key, i),
                                  (args.prompt_len + i % 3,), 0,
                                  cfg.vocab_size).astype(jnp.int32),
        max_new=args.max_new + i % 2)
        for i in range(args.requests)]

    t0 = time.monotonic()
    done = server.submit_and_run(reqs)
    dt = time.monotonic() - t0
    total = sum(len(r.out) for r in done)
    print(f"served {len(done)} requests ({total} tokens) in {dt:.2f}s "
          f"({total / max(dt, 1e-9):.1f} tok/s, {args.slots} slots)")
    for r in sorted(done, key=lambda r: r.rid):
        mark = " [truncated]" if r.truncated else ""
        print(f"  req{r.rid}: prompt={len(r.prompt)} -> "
              f"{r.out[:8]}{'...' if len(r.out) > 8 else ''}{mark}")
    assert {r.rid for r in done} == {r.rid for r in reqs}, "request lost"


if __name__ == "__main__":
    main()
