"""Quickstart: the paper's XNOR-popcount dot in 20 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import binarize as B
from repro.kernels import ops, ref

key = jax.random.PRNGKey(0)
a = jax.random.normal(key, (64, 1000))              # activations
w = jax.random.normal(jax.random.fold_in(key, 1), (256, 1000))  # weights

# 1. pack once (paper C2): 32 ±1 values per uint32 word
w_packed = B.pack_bits(w)
print(f"weights: {w.size * 4} bytes fp32 -> {w_packed.size * 4} packed "
      f"({w.size * 4 / (w_packed.size * 4):.0f}x smaller)")

# 2. binary GEMM: a.b == K - 2*popcount(XOR) (paper eq. 2)
out = ops.binary_matmul(a, w, backend="jnp")         # pure-jnp variant
out_pallas = ops.binary_matmul(a, w, backend="pallas")  # TPU kernel
expected = ref.binary_matmul_ref(a, w)               # fp oracle
assert (out == expected).all() and (out_pallas == expected).all()
print("XNOR-popcount GEMM == sign-binarized fp GEMM, bit-exact  ✓")

# 3. first-layer fixed-precision input via bit-planes (paper eq. 3)
x = jax.random.randint(key, (4, 1000), 0, 256).astype(jnp.uint8)
wb = B.sign_pm1(w)
exact = B.bitplane_dot(x, wb)
assert (exact == (x.astype(jnp.int32) @ wb.astype(jnp.int32).T)).all()
print("bit-plane first layer == exact integer GEMM              ✓")
