"""Paper §4.3 / §6.2: first-layer binary optimization via bit-planes.

Shows (1) the exact integer identity, (2) the work accounting behind the
paper's ~3x whole-network claim: with bit-planes the first layer costs
8 packed GEMMs instead of one fp GEMM — on binary hardware ops that is
8 * K/32 bitwise ops vs K FMAs per dot (4x fewer ops, and no fp unit).

    PYTHONPATH=src python examples/bitplane_first_layer.py
"""
import jax
import jax.numpy as jnp

from repro.core import binarize as B
from repro.core import binary_layers as L

key = jax.random.PRNGKey(0)
d_in, d_out, batch = 784, 512, 8
params = L.init_binary_dense(key, d_in, d_out)
x = jax.random.randint(jax.random.fold_in(key, 1), (batch, d_in), 0,
                       256).astype(jnp.uint8)

want = L.apply_bitplane_dense_float(params, x)          # integer GEMM
packed = L.pack_bitplane_dense(params)
got = L.apply_bitplane_dense_packed(packed, x, backend="jnp")
assert (got == want.astype(jnp.int32)).all()
print("bit-plane packed first layer == integer GEMM, exact  ✓")

fma_ops = d_in                                  # per output dot, fp path
plane_ops = 8 * 2 * (d_in // 32 + 1)            # 8 planes x (xor+popcnt)
print(f"per-dot work: {fma_ops} FMAs (fp) vs {plane_ops} bitwise ops "
      f"(packed, 8 planes) -> {fma_ops / plane_ops:.1f}x fewer ops, "
      "no FPU needed (paper reports ~3x whole-net)")
