"""Train a BinaryNet MLP end-to-end (STE + latent clipping, paper §4.4),

then deploy it the Espresso way: pack once, serve packed, verify the
packed network classifies identically to the training-time reference.

    PYTHONPATH=src python examples/train_binary_mlp.py [--steps 300]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import binarize as B
from repro.models import cnn


def synthetic_mnist(key, n):
    """Deterministic MNIST-shaped task: class = argmax over 10 quadrant
    means — learnable by a binary MLP."""
    x = jax.random.randint(key, (n, 784), 0, 256).astype(jnp.uint8)
    proto = jax.random.normal(jax.random.fold_in(key, 1), (10, 784))
    y = jnp.argmax(x.astype(jnp.float32) @ proto.T, axis=1)
    return x, y


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    args = ap.parse_args()

    key = jax.random.PRNGKey(0)
    spec = cnn.BMLPSpec(sizes=(784, 256, 128, 10))
    params = cnn.init_bmlp(key, spec)
    xs, ys = synthetic_mnist(jax.random.fold_in(key, 7), 4096)

    def loss_fn(p, xb, yb):
        logits = cnn.bmlp_forward_float(p, xb, ste=True)
        lp = jax.nn.log_softmax(logits)
        return -jnp.mean(lp[jnp.arange(xb.shape[0]), yb])

    @jax.jit
    def step(p, i):
        sl = (i * args.batch) % (4096 - args.batch)
        xb = jax.lax.dynamic_slice_in_dim(xs, sl, args.batch)
        yb = jax.lax.dynamic_slice_in_dim(ys, sl, args.batch)
        loss, g = jax.value_and_grad(loss_fn)(p, xb, yb)
        # SGD on fp latents + clip to [-1,1] (paper §4.4)
        p = jax.tree.map(lambda w, gw: B.clip_latent(w - args.lr * gw),
                         p, g)
        return p, loss

    for i in range(args.steps):
        params, loss = step(params, i)
        if i % 50 == 0:
            print(f"step {i:4d}  loss {float(loss):.4f}")

    # deploy: pack once (C2), serve packed
    packed = cnn.pack_bmlp(params, spec)
    logits_ref = cnn.bmlp_forward_float(params, xs[:512])
    logits_bin = cnn.bmlp_forward_packed(packed, xs[:512], backend="jnp")
    acc_ref = float((jnp.argmax(logits_ref, 1) == ys[:512]).mean())
    acc_bin = float((jnp.argmax(logits_bin, 1) == ys[:512]).mean())
    agree = float((jnp.argmax(logits_ref, 1)
                   == jnp.argmax(logits_bin, 1)).mean())
    print(f"reference acc {acc_ref:.3f} | packed acc {acc_bin:.3f} "
          f"| prediction agreement {agree:.3f}")
    assert agree > 0.999, "packed deployment must match the reference"
    print("packed deployment is numerically equivalent  ✓")


if __name__ == "__main__":
    main()
