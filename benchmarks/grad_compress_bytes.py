"""Beyond-paper: 1-bit gradient compression wire bytes (signSGD-EF).

The paper's C1 packing applied to the DP all-reduce: measures the actual
packed byte count for a reduced LM's gradient pytree vs fp32/bf16, and
the quality proxy (cosine similarity of compressed vs true gradient sum
over steps with error feedback)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import binarize as B
from repro.optim import compress as CMP
from repro.train import trainer as TR


def rows() -> list[tuple]:
    cfg = get_config("starcoder2-3b", reduced=True)
    tc = TR.TrainConfig()
    state = TR.init_train_state(jax.random.PRNGKey(0), cfg, tc)
    params = state["params"]
    leaves = jax.tree.leaves(params)
    n_elems = sum(l.size for l in leaves)
    fp32 = n_elems * 4
    bf16 = n_elems * 2
    packed = sum(B.pack_bits(l.reshape(1, -1)).size * 4 + 4
                 for l in leaves)          # words + 1 fp32 scale each
    out = [
        ("grad_compress/fp32_bytes", float(fp32), ""),
        ("grad_compress/bf16_bytes", float(bf16), ""),
        ("grad_compress/packed_1bit_bytes", float(packed),
         f"{fp32 / packed:.1f}x vs fp32, {bf16 / packed:.1f}x vs bf16 "
         f"on the DP all-reduce wire"),
    ]
    # EF quality proxy
    key = jax.random.PRNGKey(1)
    err = CMP.signsgd_ef_init({"w": jnp.zeros((4096,))})
    tot_g = jnp.zeros((4096,))
    tot_c = jnp.zeros((4096,))
    for i in range(30):
        g = {"w": jax.random.normal(jax.random.fold_in(key, i), (4096,))}
        c, err = CMP.signsgd_ef_compress(g, err)
        tot_g += g["w"]
        tot_c += c["w"]
    cos = float(jnp.dot(tot_g, tot_c)
                / (jnp.linalg.norm(tot_g) * jnp.linalg.norm(tot_c)))
    out.append(("grad_compress/ef_cosine_30steps", cos * 1e6,
                "cosine(sum compressed, sum true) x 1e6 — EF keeps it ~1"))
    return out


def main() -> None:
    for name, us, note in rows():
        print(f"{name},{us:.1f},{note}")


if __name__ == "__main__":
    main()
