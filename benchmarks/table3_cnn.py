"""Paper Table 3: binary CNN forward on CIFAR-10-shaped input (batch 1).

Paper: CPU 85.2 ms / GPU 5.2 ms / GPUopt 1.0 ms; memory 53.54 MB ->
1.73 MB (~31x).  CPU container: we measure the float-sign reference vs
the packed path *per backend* (jnp = host-side im2col, pallas =
in-kernel im2col via interpret mode) at a reduced spatial size, the
exact 31x memory figure at full size, and op-level evidence that the
Pallas conv kernel no longer materializes the im2col patch matrix in
HBM (the largest live intermediate drops to the conv output itself).

    PYTHONPATH=src python -m benchmarks.table3_cnn          # CSV + JSON
    REPRO_BENCH_SMOKE=1 ... python -m benchmarks.table3_cnn # CI-sized

Writes ``experiments/BENCH_table3_cnn.json``.
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp

from repro.core import binary_layers as L
from repro.kernels import ops as kops
from repro.models import cnn
from repro.analysis import count_pallas_calls, max_intermediate_bytes

SMOKE = bool(os.environ.get("REPRO_BENCH_SMOKE"))


def _time(fn, *args, reps=3):
    jax.block_until_ready(fn(*args))
    t0 = time.monotonic()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.monotonic() - t0) / reps * 1e6


# Largest-intermediate evidence ("the Pallas conv path never stages the
# (B·H'·W', KH·KW·Cw) patch matrix") comes from the shared traversal
# in repro.analysis (analysis/graph.py) — the same walker behind the
# launch counts, the telemetry probes, and the packedness pass.
_max_intermediate_bytes = max_intermediate_bytes


def rows() -> list[tuple]:
    key = jax.random.PRNGKey(0)
    out = []

    # Reduced spatial size for CPU wall-time comparison (CI smoke shrinks
    # further: interpret-mode Pallas is emulated op-by-op on CPU).
    if SMOKE:
        spec_s = cnn.BCNNSpec(input_hw=(8, 8), c_in=3,
                              stages=(cnn.ConvStage(32),
                                      cnn.ConvStage(64, pool=True)),
                              dense=(64, 10))
        reps, tag = 1, "bcnn8"
    else:
        spec_s = cnn.BCNNSpec(input_hw=(16, 16), c_in=3,
                              stages=(cnn.ConvStage(128),
                                      cnn.ConvStage(128, pool=True),
                                      cnn.ConvStage(256, pool=True),
                                      cnn.ConvStage(512, pool=True)),
                              dense=(1024, 10))
        reps, tag = 3, "bcnn16"
    params = cnn.init_bcnn(key, spec_s)
    packed = cnn.pack_bcnn(params, spec_s)
    x = jax.random.randint(key, (1, *spec_s.input_hw, 3), 0,
                           256).astype(jnp.uint8)
    f_float = jax.jit(lambda v: cnn.bcnn_forward_float(params, v, spec_s))
    t_float = _time(f_float, x, reps=reps)
    out.append((f"table3/{tag}_float_fwd_b1", t_float,
                "float-sign reference"))
    for backend in ("jnp", "pallas"):
        f_packed = jax.jit(lambda v, be=backend:
                           cnn.bcnn_forward_packed(packed, v, backend=be))
        t = _time(f_packed, x, reps=reps)
        note = ("host-side im2col + packed GEMM (pre-subsystem path)"
                if backend == "jnp" else
                "fused Pallas conv + BN-sign-repack epilogue (interpret)")
        out.append((f"table3/{tag}_packed_fwd_b1_{backend}", t,
                    f"{t_float / t:.2f}x vs float | {note}"))

    # Patch-matrix materialization evidence on one mid-stack conv layer:
    # the jnp backend's largest intermediate IS the im2col patch matrix;
    # the Pallas backend's largest is the conv output / packed image.
    ci, co, hh = (32, 64, 8) if SMOKE else (128, 256, 16)
    wconv = jax.random.normal(jax.random.fold_in(key, 3), (co, 3, 3, ci))
    plan = L.pack_binary_conv2d({"w": wconv}, input_hw=(hh, hh))
    xs = jax.random.normal(jax.random.fold_in(key, 4), (1, hh, hh, ci))
    x_p = kops.bitpack(xs.reshape(-1, ci), backend="jnp"
                       ).reshape(1, hh, hh, -1)
    for backend in ("jnp", "pallas"):
        nbytes, shape = _max_intermediate_bytes(
            lambda v, be=backend: kops.binary_conv2d_packed(plan, v,
                                                            backend=be),
            x_p)
        what = ("host im2col: patch matrix + XOR broadcast staged in HBM"
                if backend == "jnp" else
                "in-kernel im2col: largest live array is the conv output")
        out.append((f"table3/conv{hh}_max_intermediate_{backend}",
                    float(nbytes),
                    f"largest HBM intermediate {shape} | {what}"))

    # First-layer bit-plane conv: ONE fused kernel launch (in-kernel
    # plane loop over the VMEM-resident plane stack) vs the 8 sequential
    # per-plane convs of the jnp/pre-fusion path.
    pc0 = packed["convs"][0]
    nb = spec_s.nbits_input
    launches = count_pallas_calls(
        lambda v: cnn._bitplane_conv_packed(pc0, v, nb, backend="pallas"),
        x)
    out.append((f"table3/{tag}_bitplane_l1_kernel_launches", float(launches),
                f"{nb} planes fused into 1 pallas_call "
                "(was 8 sequential plane convs)"))
    for backend, note in (("jnp", "8-plane sequential reference"),
                          ("pallas", "single fused launch (interpret)")):
        f_l1 = jax.jit(lambda v, be=backend:
                       cnn._bitplane_conv_packed(pc0, v, nb, backend=be))
        t = _time(f_l1, x, reps=reps)
        out.append((f"table3/{tag}_bitplane_l1_fwd_{backend}", t, note))

    # Dense megakernel suite evidence (BMLP hidden stack + GEMV serving).
    out.extend(bmlp_rows())

    # Sharded forward, one row per mesh shape: bit-exactness + collective
    # profile + steady-state wall time on a forced-8-device CPU mesh.
    # Device count is fixed at jax init, so the sweep runs in its own
    # process (repro.distributed.verify_sharded, same as the CI job).
    out.extend(sharded_rows())

    # Full paper architecture: memory only (params), fwd at batch 1.
    if not SMOKE:
        spec = cnn.BCNNSpec()
        params_f = cnn.init_bcnn(jax.random.PRNGKey(1), spec)
        packed_f = cnn.pack_bcnn(params_f, spec)
        conv_fp = sum(p["w"].size * 4 for p in params_f["convs"]) + \
            sum(p["w"].size * 4 for p in params_f["denses"])
        conv_bin = sum(p["w_packed"].size * 4 for p in packed_f["convs"]) + \
            sum(p["w_packed"].size * 4 for p in packed_f["denses"])
        out.append(("table3/bcnn_param_bytes_float", float(conv_fp),
                    f"{conv_fp / 2**20:.1f} MiB (paper: 53.54 MB)"))
        out.append(("table3/bcnn_param_bytes_packed", float(conv_bin),
                    f"{conv_fp / conv_bin:.1f}x smaller (paper: ~31x)"))
        x32 = jax.random.randint(key, (1, 32, 32, 3), 0,
                                 256).astype(jnp.uint8)
        f32 = jax.jit(lambda v: cnn.bcnn_forward_packed(packed_f, v,
                                                        backend="jnp"))
        out.append(("table3/bcnn32_packed_fwd_b1", _time(f32, x32, reps=1),
                    "full paper CNN, packed path"))
    return out


def bmlp_rows() -> list[tuple]:
    """Dense megakernel rows (paper §6.2 / Table 2 shapes on the Table-3
    evidence format): fused vs unfused hidden dense layer with
    max-intermediate-HBM evidence (the int32 (M, N) activation drops to
    packed uint32 words), single-launch vs per-layer hidden stack, and
    the batch-1 GEMV serving shape."""
    key = jax.random.PRNGKey(2)
    if SMOKE:
        spec = cnn.BMLPSpec(sizes=(64, 128, 128, 128, 10))
        reps, tag = 1, "bmlp128"
    else:
        spec = cnn.BMLPSpec()                 # 784-4096-4096-4096-10
        reps, tag = 1, "bmlp4096"
    params = cnn.init_bmlp(key, spec)
    packed = cnn.pack_bmlp(params, spec)
    n_layers = len(packed["layers"])
    hidden = list(range(1, n_layers - 1))
    stages = [{"w_packed": packed["layers"][i]["w_packed"],
               "k_true": packed["layers"][i]["k_true"],
               "tau": packed["folded"][i]["tau"],
               "flip": packed["folded"][i]["flip"]} for i in hidden]
    layer0, folded0 = packed["layers"][hidden[0]], packed["folded"][hidden[0]]
    out = []

    # Fused epilogue vs separate GEMM -> bn_sign_pack on one hidden
    # layer: wall time and the largest HBM intermediate.  Unfused stages
    # the full int32 (M, N) activation between the two launches; fused
    # emits packed words straight from the kernel flush.
    mb = 16 if SMOKE else 64
    xh = kops.bitpack(jax.random.normal(jax.random.fold_in(key, 1),
                                        (mb, layer0["k_true"])),
                      backend="jnp")

    def unfused(v):
        z = kops.binary_matmul_packed(v, layer0["w_packed"],
                                      k_true=layer0["k_true"],
                                      backend="pallas")
        return kops.bn_sign_pack(z, folded0["tau"], folded0["flip"],
                                 backend="pallas")

    def fused(v):
        return kops.binary_matmul_bn_sign_packed(
            v, layer0["w_packed"], folded0["tau"], folded0["flip"],
            k_true=layer0["k_true"], backend="pallas")

    for name, fn, what in (
            ("unfused", unfused, "int32 (M, N) staged in HBM between the "
             "GEMM and bn_sign_pack launches"),
            ("fused", fused, "kernel flush emits packed uint32 words — "
             "the int32 activation never leaves VMEM")):
        nbytes, shape = _max_intermediate_bytes(fn, xh)
        out.append((f"table3/bmlp_dense_max_intermediate_{name}",
                    float(nbytes),
                    f"largest HBM intermediate {shape} | {what}"))
    t_unf = _time(jax.jit(unfused), xh, reps=reps)
    t_fus = _time(jax.jit(fused), xh, reps=reps)
    out.append((f"table3/{tag}_dense_hidden_fwd_unfused_b{mb}", t_unf,
                "separate GEMM + bn_sign_pack launches (interpret)"))
    out.append((f"table3/{tag}_dense_hidden_fwd_fused_b{mb}", t_fus,
                f"{t_unf / t_fus:.2f}x vs unfused | fused epilogue "
                "(interpret)"))

    # Single-launch resident stack vs per-layer fused launches.
    launches_auto = count_pallas_calls(
        lambda v: kops.binary_dense_stack_packed(stages, v,
                                                 backend="pallas"), xh)
    launches_per = count_pallas_calls(
        lambda v: kops.binary_dense_stack_packed(stages, v,
                                                 backend="pallas",
                                                 resident=False), xh)
    out.append(("table3/bmlp_stack_kernel_launches", float(launches_auto),
                f"{len(stages)} hidden layers in 1 pallas_call on the "
                f"VMEM-resident path (per-layer fallback = {launches_per} "
                "launches)"))
    for mode, res, note in (("resident", True,
                             "ONE launch, weights resident in VMEM"),
                            ("per_layer", False,
                             "one fused launch per hidden layer")):
        f_stack = jax.jit(lambda v, r=res: kops.binary_dense_stack_packed(
            stages, v, backend="pallas", resident=r))
        out.append((f"table3/{tag}_hidden_stack_fwd_{mode}",
                    _time(f_stack, xh, reps=reps), f"{note} (interpret)"))

    # GEMV serving shape (paper §6.2): batch-1 forward takes the N-major
    # grid in every dense GEMM + the resident hidden stack.
    x1 = jax.random.randint(jax.random.fold_in(key, 3),
                            (1, spec.sizes[0]), 0, 256).astype(jnp.uint8)
    for backend, note in (
            ("jnp", "host packed GEMMs (pre-subsystem path)"),
            ("pallas", "N-major GEMV grid + single-launch resident "
             "hidden stack (interpret)")):
        f1 = jax.jit(lambda v, be=backend:
                     cnn.bmlp_forward_packed(packed, v, backend=be))
        out.append((f"table3/{tag}_gemv_fwd_b1_{backend}",
                    _time(f1, x1, reps=reps), note))
    return out


def sharded_rows() -> list[tuple]:
    """Per-mesh-shape rows for the sharded packed forward (subprocess)."""
    from repro.distributed.subproc import run_verifier
    try:
        results = run_verifier()
    except Exception as e:                          # record, don't crash
        return [("table3/sharded_fwd_error", -1.0, f"{e!r}"[:300])]
    rows = []
    for r in results:
        d, m = r["mesh"]
        coll = r["collective_kinds"] or {}
        rows.append((
            f"table3/sharded_{r['kind']}_fwd_mesh{d}x{m}_{r['backend']}",
            r["fwd_us"],
            f"bitexact={r['bitexact']} shards={r['shard_plan']} "
            f"collectives={coll or 'none'} (8 forced CPU devices)"))
    return rows


def write_bench_json(rs: list[tuple], path="experiments/BENCH_table3_cnn.json"
                     ) -> None:
    os.makedirs(os.path.dirname(path), exist_ok=True)
    payload = [{"name": n, "value": v, "note": note} for n, v, note in rs]
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)


def main() -> None:
    rs = rows()
    for name, us, note in rs:
        print(f"{name},{us:.1f},{note}")
    write_bench_json(rs)
    print("wrote experiments/BENCH_table3_cnn.json")


if __name__ == "__main__":
    main()
