"""Paper Table 3: binary CNN forward on CIFAR-10-shaped input (batch 1).

Paper: CPU 85.2 ms / GPU 5.2 ms / GPUopt 1.0 ms; memory 53.54 MB ->
1.73 MB (~31x).  CPU container: we measure the float-sign reference vs
the packed path at a reduced spatial size (full 32x32 VGG on CPU jnp is
seconds — reported too), and the exact 31x memory figure at full size."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.models import cnn
from repro.utils.tree import tree_bytes


def _time(fn, *args, reps=3):
    jax.block_until_ready(fn(*args))
    t0 = time.monotonic()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.monotonic() - t0) / reps * 1e6


def rows() -> list[tuple]:
    key = jax.random.PRNGKey(0)
    out = []

    # reduced spatial size for CPU wall-time comparison
    spec_s = cnn.BCNNSpec(input_hw=(16, 16), c_in=3,
                          stages=(cnn.ConvStage(128),
                                  cnn.ConvStage(128, pool=True),
                                  cnn.ConvStage(256, pool=True),
                                  cnn.ConvStage(512, pool=True)),
                          dense=(1024, 10))
    params = cnn.init_bcnn(key, spec_s)
    packed = cnn.pack_bcnn(params, spec_s)
    x = jax.random.randint(key, (1, 16, 16, 3), 0, 256).astype(jnp.uint8)
    f_float = jax.jit(lambda v: cnn.bcnn_forward_float(params, v, spec_s))
    out.append(("table3/bcnn16_float_fwd_b1", _time(f_float, x),
                "float-sign reference"))
    f_packed = jax.jit(lambda v: cnn.bcnn_forward_packed(packed, v,
                                                         backend="jnp"))
    out.append(("table3/bcnn16_packed_fwd_b1", _time(f_packed, x),
                "packed XNOR conv via channel-packed im2col (C3/C6)"))

    # full paper architecture: memory only (params), fwd at batch 1
    spec = cnn.BCNNSpec()
    params_f = cnn.init_bcnn(jax.random.PRNGKey(1), spec)
    packed_f = cnn.pack_bcnn(params_f, spec)
    conv_fp = sum(p["w"].size * 4 for p in params_f["convs"]) + \
        sum(p["w"].size * 4 for p in params_f["denses"])
    conv_bin = sum(p["w_packed"].size * 4 for p in packed_f["convs"]) + \
        sum(p["w_packed"].size * 4 for p in packed_f["denses"])
    out.append(("table3/bcnn_param_bytes_float", float(conv_fp),
                f"{conv_fp / 2**20:.1f} MiB (paper: 53.54 MB)"))
    out.append(("table3/bcnn_param_bytes_packed", float(conv_bin),
                f"{conv_fp / conv_bin:.1f}x smaller (paper: ~31x)"))
    x32 = jax.random.randint(key, (1, 32, 32, 3), 0, 256).astype(jnp.uint8)
    f32 = jax.jit(lambda v: cnn.bcnn_forward_packed(packed_f, v,
                                                     backend="jnp"))
    out.append(("table3/bcnn32_packed_fwd_b1", _time(f32, x32, reps=1),
                "full paper CNN, packed path"))
    return out


def main() -> None:
    for name, us, note in rows():
        print(f"{name},{us:.1f},{note}")


if __name__ == "__main__":
    main()
