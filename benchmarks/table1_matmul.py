"""Paper Table 1: binary dense matrix multiplication, 8192 x 8192.

The paper reports wall-clock on a GTX 960 (88 ms BinaryNet -> 11 ms
Espresso 64-bit).  This container is CPU-only, so we report:

* measured CPU wall-time of the three backend variants at a scaled size
  (the full 8192^2 on CPU interpret-mode Pallas is minutes — the jnp
  packed variant runs the full size), and
* the structural claim behind the speedup: ops and bytes per dot-product
  (64 FMAs -> 1 XNOR + 1 popcount per word in the paper; 32 on TPU),
  i.e. the work reduction the kernel realizes on real hardware.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import binarize as B
from repro.kernels import ops


def _time(fn, *args, reps=3):
    fn(*args).block_until_ready()
    t0 = time.monotonic()
    for _ in range(reps):
        out = fn(*args)
    out.block_until_ready()
    return (time.monotonic() - t0) / reps * 1e6     # us


def rows() -> list[tuple]:
    key = jax.random.PRNGKey(0)
    out = []
    n = 8192
    a = jax.random.normal(key, (n, n), jnp.float32)
    b = jax.random.normal(jax.random.fold_in(key, 1), (n, n), jnp.float32)

    # float reference GEMM (the FMA baseline)
    t_float = _time(jax.jit(lambda x, y: x @ y.T), a, b, reps=1)
    out.append(("table1/float_gemm_8192", t_float,
                "fp32 FMA baseline (XLA CPU)"))

    # packed path: pack once (C2), then XNOR-popcount GEMM (jnp backend)
    ap, bp = B.pack_bits(a), B.pack_bits(b)
    pm = jax.jit(lambda x, y: B.packed_matmul(x, y, n, block_kw=8))
    t_bin = _time(pm, ap, bp, reps=1)
    out.append(("table1/binary_packed_gemm_8192", t_bin,
                "XNOR+popcount on packed uint32 (binary-jnp)"))

    t_pack = _time(jax.jit(B.pack_bits), a, reps=1)
    out.append(("table1/bitpack_8192", t_pack,
                "per-call packing cost BinaryNet pays, Espresso does not"))

    # structural work reduction (paper Sec 4.2, TPU 32-bit adaptation)
    out.append(("table1/fma_ops_per_dot", float(n),
                "multiply-adds per 8192-dot"))
    out.append(("table1/xnor_popcnt_ops_per_dot", float(2 * n // 32),
                "bitwise ops per 8192-dot (32-bit words)"))
    out.append(("table1/weight_bytes_fp32", float(n * n * 4), ""))
    out.append(("table1/weight_bytes_packed", float(n * (n // 32) * 4),
                "32x memory reduction (paper C8)"))

    # pallas kernel at reduced size (interpret mode executes per-op)
    m = 256
    a2, b2 = a[:m, :m], b[:m, :m]
    t_pl = _time(lambda x, y: ops.binary_matmul(x, y, backend="pallas"),
                 a2, b2, reps=1)
    out.append((f"table1/pallas_interpret_{m}", t_pl,
                "TPU kernel semantics validated on CPU (interpret)"))
    return out


def main() -> None:
    for name, us, note in rows():
        print(f"{name},{us:.1f},{note}")


if __name__ == "__main__":
    main()
