"""Serving-subsystem benchmark: request latency + throughput-vs-batch.

Drives ``train.serve.PackedInferenceServer`` (the Espresso
prediction-phase engine) on CPU:

* an arrival trace against the continuous-batching queue → per-request
  p50/p99 latency under the deadline-flush policy,
* forced flushes at batch 1..max → throughput-vs-batch rows, each
  annotated with the GEMV/GEMM route the ``ops.dispatch_batch`` seam
  picked,
* pack-once / zero-steady-state-allocation evidence: the weight cache
  packs each config exactly once regardless of request count, and the
  scratch pool stops allocating once its buckets are warm,
* a ``telemetry`` section: the instrumented run's metrics snapshot +
  span names, and the tracer-enabled vs tracer-disabled p50 (the
  disabled span path is one attribute check; the measured overhead
  ratio is the standing evidence for it).

    PYTHONPATH=src python -m benchmarks.serve_latency          # full
    REPRO_BENCH_SMOKE=1 ... python -m benchmarks.serve_latency # CI-sized

Writes ``experiments/BENCH_serve.json`` as
``{"rows": [...], "telemetry": {...}}``.
"""
from __future__ import annotations

import json
import os
import statistics
import time

import numpy as np

from repro.models import cnn
from repro.train import serve as SV

SMOKE = bool(os.environ.get("REPRO_BENCH_SMOKE"))


def _build(model: str):
    return cnn.demo_model(model, smoke=SMOKE)


def trace_rows(model: str, *, requests: int, deadline_s: float = 0.005,
               max_batch: int = 8) -> list[tuple]:
    """Replay an arrival trace; report per-request latency percentiles."""
    params, spec, kind = _build(model)
    srv = SV.PackedInferenceServer(max_batch=max_batch,
                                   default_deadline=deadline_s)
    srv.register(model, params, spec, kind=kind, backend="jnp")
    eng = srv.engine()
    rng = np.random.default_rng(0)
    xs = rng.integers(0, 256, (requests, *eng.example_shape),
                      dtype=np.uint8)
    # Warm EVERY bucket the trace can flush through, so no request's
    # recorded latency includes a jit compile (a ragged tail flush would
    # otherwise hit a cold bucket).
    for b in eng.buckets:
        if b <= max_batch:
            srv.serve(list(xs[:b]))
    srv.served.clear()
    srv.flushes.clear()

    t0 = time.monotonic()
    # Completions come from the step() returns, not srv.served — served
    # is bounded history (train.serve truncates it to the mailbox cap).
    done = []
    for i in range(requests):
        srv.submit(xs[i])
        done += srv.step()
    while srv.pending():
        done += srv.step()
    wall = time.monotonic() - t0
    lats = sorted(r.latency for r in done)
    assert len(lats) == requests
    batches = [f.batch for f in srv.flushes]
    note = (f"{requests} reqs, deadline={deadline_s * 1e3:.0f}ms, "
            f"max_batch={max_batch}, flush batches={batches}, jnp backend")
    return [
        (f"serve/{model}_p50_latency_us",
         statistics.median(lats) * 1e6, note),
        (f"serve/{model}_p99_latency_us",
         SV.latency_percentile(lats, 0.99) * 1e6, note),
        (f"serve/{model}_trace_throughput_rps", requests / wall, note),
    ]


def throughput_rows(model: str, *, reps: int) -> list[tuple]:
    """Forced flushes at fixed batch sizes: throughput vs batch, each
    row carrying the route the dispatch seam chose for that bucket."""
    params, spec, kind = _build(model)
    srv = SV.PackedInferenceServer(max_batch=32)
    srv.register(model, params, spec, kind=kind, backend="jnp")
    eng = srv.engine()
    rng = np.random.default_rng(1)
    rows = []
    for b in (1, 2, 4, 8, 16, 32):
        xs = list(rng.integers(0, 256, (b, *eng.example_shape),
                               dtype=np.uint8))
        srv.serve(xs)                          # warm this bucket
        t0 = time.monotonic()
        for _ in range(reps):
            srv.serve(xs)
        dt = time.monotonic() - t0
        rows.append((f"serve/{model}_throughput_b{b}_rps",
                     b * reps / dt,
                     f"route={srv.route_for(b)} bucket={b} "
                     f"({reps} flushes, jnp backend)"))
    # pack-once + steady-state evidence for the whole sweep
    rows.append((f"serve/{model}_weight_cache_packs",
                 float(srv.cache.misses),
                 f"configs packed once across "
                 f"{sum(f.batch for f in srv.flushes)} served requests"))
    allocs = srv.pool.allocations
    for b in (1, 8, 32):
        srv.serve(list(rng.integers(0, 256, (b, *eng.example_shape),
                                    dtype=np.uint8)))
    rows.append((f"serve/{model}_steady_state_new_allocs",
                 float(srv.pool.allocations - allocs),
                 "staging buffers allocated AFTER all buckets warm "
                 "(scratch pool reuse)"))
    return rows


def gemv_row() -> list[tuple]:
    """Batch-1 serving through the interpret-mode Pallas engine: the
    flush takes the N-major GEMV grid end-to-end (launch-shape contract
    tested in tests/test_serve_batching.py)."""
    params, spec, kind = _build("bmlp")
    srv = SV.PackedInferenceServer(max_batch=8)
    srv.register("bmlp-pallas", params, spec, kind=kind, backend="pallas")
    eng = srv.engine()
    x = [np.zeros(eng.example_shape, np.uint8)]
    srv.serve(x)                               # compile bucket 1
    t0 = time.monotonic()
    srv.serve(x)
    dt = time.monotonic() - t0
    assert srv.flushes[-1].route == "gemv"
    return [("serve/bmlp_gemv_b1_pallas_us", dt * 1e6,
             "batch-1 flush via the N-major GEMV grid "
             "(interpret mode on CPU)")]


def telemetry_section(model: str = "bmlp", *, requests: int,
                      deadline_s: float = 0.005,
                      max_batch: int = 8) -> dict:
    """Identical arrival traces with the tracer disabled and enabled:
    the p50 pair is the measured cost of the span instrumentation
    (disabled path = one attribute check per span), and the enabled
    run's metrics snapshot + span taxonomy are carried as the
    machine-readable serving-health record."""
    def run(enable_tracing: bool):
        params, spec, kind = _build(model)
        srv = SV.PackedInferenceServer(max_batch=max_batch,
                                       default_deadline=deadline_s)
        if enable_tracing:
            srv.telemetry.enable_tracing()
        srv.register(model, params, spec, kind=kind, backend="jnp")
        eng = srv.engine()
        rng = np.random.default_rng(0)
        xs = rng.integers(0, 256, (requests, *eng.example_shape),
                          dtype=np.uint8)
        for b in eng.buckets:                  # warm every bucket
            if b <= max_batch:
                srv.serve(list(xs[:b]))
        done = []
        for i in range(requests):
            srv.submit(xs[i])
            done += srv.step()
        while srv.pending():
            done += srv.step()
        return statistics.median(r.latency for r in done), srv

    p50_off, _ = run(False)
    p50_on, srv = run(True)
    tr = srv.telemetry.tracer
    return {
        "model": model,
        "requests": requests,
        "p50_latency_us": {"tracer_disabled": p50_off * 1e6,
                           "tracer_enabled": p50_on * 1e6},
        "tracer_enabled_overhead_ratio": p50_on / p50_off,
        "trace_events": len(tr.events),
        "span_names": tr.span_names(),
        "metrics": srv.telemetry.metrics.snapshot(),
    }


def rows() -> list[tuple]:
    out = []
    reqs = 16 if SMOKE else 48
    reps = 2 if SMOKE else 5
    for model in ("bmlp", "bcnn"):
        out += trace_rows(model, requests=reqs)
        out += throughput_rows(model, reps=reps)
    out += gemv_row()
    return out


def write_bench_json(rs: list[tuple], telemetry: dict,
                     path="experiments/BENCH_serve.json") -> None:
    os.makedirs(os.path.dirname(path), exist_ok=True)
    payload = {"rows": [{"name": n, "value": v, "note": note}
                        for n, v, note in rs],
               "telemetry": telemetry}
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)


def main() -> None:
    rs = rows()
    for name, v, note in rs:
        print(f"{name},{v:.1f},{note}")
    tel = telemetry_section(requests=16 if SMOKE else 48)
    print(f"telemetry: tracer overhead ratio "
          f"{tel['tracer_enabled_overhead_ratio']:.3f} "
          f"({tel['trace_events']} events, "
          f"{len(tel['span_names'])} span kinds)")
    write_bench_json(rs, tel)


if __name__ == "__main__":
    main()
