# One function per paper table. Prints ``name,us_per_call,derived`` CSV.
"""Benchmark harness: paper Tables 1-3 + memory + beyond-paper rows.

    PYTHONPATH=src python -m benchmarks.run

Roofline analysis (reads the dry-run artifacts) is separate:
    PYTHONPATH=src python -m benchmarks.roofline
"""
from __future__ import annotations


def main() -> None:
    import json
    import os

    from benchmarks import (grad_compress_bytes, table1_matmul, table2_mlp,
                            table3_cnn)
    print("name,us_per_call,derived")
    mods = [table1_matmul, table2_mlp, table3_cnn, grad_compress_bytes]
    all_rows = []
    for mod in mods:
        for name, us, note in mod.rows():
            print(f"{name},{us:.1f},{note}")
            all_rows.append({"name": name, "value": us, "note": note})
    os.makedirs("experiments", exist_ok=True)
    with open("experiments/BENCH_run.json", "w") as f:
        json.dump(all_rows, f, indent=1)
    print("wrote experiments/BENCH_run.json")


if __name__ == "__main__":
    main()
