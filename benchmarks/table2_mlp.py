"""Paper Table 2: binary MLP forward on MNIST-shaped input (batch 1).

Reports forward wall-time for the full 784-4096^3-10 BMLP across the
backend variants (paper: CPU 37.4 ms / GPU 3.2 ms / GPUopt 0.26 ms), the
first-layer bit-plane optimization on/off delta (paper: ~3x whole-net),
and the 31x memory figure (paper §6.2)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.models import cnn
from repro.utils.tree import tree_bytes


def _time(fn, *args, reps=5):
    jax.block_until_ready(fn(*args))
    t0 = time.monotonic()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.monotonic() - t0) / reps * 1e6


def rows() -> list[tuple]:
    key = jax.random.PRNGKey(0)
    spec = cnn.BMLPSpec()                     # 784-4096-4096-4096-10
    params = cnn.init_bmlp(key, spec)
    packed = cnn.pack_bmlp(params, spec)
    x = jax.random.randint(key, (1, 784), 0, 256).astype(jnp.uint8)

    out = []
    f_float = jax.jit(lambda v: cnn.bmlp_forward_float(params, v))
    out.append(("table2/bmlp_float_fwd_b1", _time(f_float, x),
                "float-sign reference (Espresso-CPU analogue)"))
    f_packed = jax.jit(lambda v: cnn.bmlp_forward_packed(packed, v,
                                                         backend="jnp"))
    out.append(("table2/bmlp_packed_fwd_b1", _time(f_packed, x),
                "packed XNOR path (GPUopt analogue, binary-jnp)"))

    # first-layer binary optimization off: first layer in float, rest
    # packed — measures the paper's ~3x first-layer claim structurally
    import repro.core.binary_layers as L

    def hybrid(p_packed, p_float, v):
        z = L.apply_bitplane_dense_float(p_float["layers"][0], v)
        h = L.apply_bn_sign_folded(p_packed["folded"][0], z)
        z = L.apply_binary_dense_packed(p_packed["layers"][1], h,
                                        backend="jnp")
        h = L.apply_bn_sign_folded(p_packed["folded"][1], z)
        z = L.apply_binary_dense_packed(p_packed["layers"][2], h,
                                        backend="jnp")
        h = L.apply_bn_sign_folded(p_packed["folded"][2], z)
        z = L.apply_binary_dense_packed(p_packed["layers"][3], h,
                                        backend="jnp")
        return L.apply_batchnorm(p_packed["bn_out"], z)

    f_hybrid = jax.jit(lambda v: hybrid(packed, params, v))
    out.append(("table2/bmlp_first_layer_float_fwd_b1",
                _time(f_hybrid, x),
                "first layer NOT binary-optimized (paper §6.2 ablation)"))

    fp_b = tree_bytes(params)
    bin_b = tree_bytes(packed)
    out.append(("table2/bmlp_param_bytes_float", float(fp_b), ""))
    out.append(("table2/bmlp_param_bytes_packed", float(bin_b),
                f"{fp_b / bin_b:.1f}x smaller (paper reports ~31x)"))
    return out


def main() -> None:
    for name, us, note in rows():
        print(f"{name},{us:.1f},{note}")


if __name__ == "__main__":
    main()
