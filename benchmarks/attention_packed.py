"""Packed-vs-float attention: wall time, launch counts, live memory.

The flash-style binary attention kernel's claims, as bench rows:

* wall-clock of the float-sign softmax attention vs the packed jnp
  oracle vs the Pallas kernel (interpret mode on CPU — TPU semantics,
  emulated op-by-op);
* launch counts: one blocked attention launch per (layer, call) and the
  full packed transformer forward's launch budget;
* the memory story: the (B, H, Sq, Skv) float score matrix an unfused
  attention materializes vs the largest live HBM intermediate of the
  packed attention launch (online softmax keeps the carry in VMEM), and
  the 32x Q/K operand shrink from channel packing.

    PYTHONPATH=src python -m benchmarks.attention_packed          # CSV + JSON
    REPRO_BENCH_SMOKE=1 ... python -m benchmarks.attention_packed # CI-sized

Writes ``experiments/BENCH_attention.json``.
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp

from repro.core import binarize as B
from repro.kernels import binary_attention as BA
from repro.kernels import ops as kops
from repro.analysis import count_pallas_calls, max_intermediate_bytes

SMOKE = bool(os.environ.get("REPRO_BENCH_SMOKE"))


def _time(fn, *args, reps=3):
    jax.block_until_ready(fn(*args))
    t0 = time.monotonic()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.monotonic() - t0) / reps * 1e6


def _float_sign_attention(q, k, v):
    """The unfused baseline: sign-binarized Q/K, full (Sq, Skv) score
    matrix in HBM, exact softmax."""
    s = jnp.einsum("bqhd,bkhd->bhqk", B.sign_pm1(q), B.sign_pm1(k))
    s = s * q.shape[-1] ** -0.5
    pos = jnp.arange(q.shape[1])
    s = jnp.where((pos[:, None] >= pos[None, :])[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))


def rows() -> list[tuple]:
    key = jax.random.PRNGKey(0)
    out = []

    b, h, d = 1, 4, 64
    # jnp-path size: the O(S^2) score matrix must dominate the O(S)
    # padded output even at smoke size, so S >= 256 (Dv pads to 128).
    s = 256 if SMOKE else 512
    sp = 32 if SMOKE else 128           # interpret-mode Pallas size
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, s, h, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, h, d), jnp.float32)

    # -- wall time ---------------------------------------------------------
    t_float = _time(jax.jit(_float_sign_attention), q, q, v)
    out.append((f"attn/float_softmax_s{s}", t_float,
                "float-sign attention, (Sq,Skv) score matrix in HBM"))
    t_oracle = _time(
        jax.jit(lambda a, b_, c: kops.binary_attention(a, b_, c,
                                                       backend="jnp")),
        q, q, v)
    out.append((f"attn/binary_oracle_s{s}", t_oracle,
                "binary_attention jnp oracle (exact softmax)"))
    t_pl = _time(lambda a, b_, c: kops.binary_attention(a, b_, c,
                                                        backend="pallas"),
                 q[:, :sp], q[:, :sp], v[:, :sp], reps=1)
    out.append((f"attn/pallas_interpret_s{sp}", t_pl,
                "TPU kernel semantics validated on CPU (interpret)"))

    # -- launch counts -----------------------------------------------------
    n = count_pallas_calls(
        lambda a, b_, c: kops.binary_attention(a, b_, c, backend="pallas"),
        q, q, v)
    out.append(("attn/launches_one_call", float(n),
                "2 bitpack launches + 1 blocked attention launch"))

    from repro.configs import get_config
    from repro.models import transformer as TF
    cfg = get_config("gemma2-9b", reduced=True)
    params = TF.init_binary_lm(jax.random.PRNGKey(1), cfg)
    packed = TF.pack_transformer(params, cfg, max_len=8)
    toks = jnp.zeros((1, 8), jnp.uint8)
    n_tf = count_pallas_calls(
        lambda t: TF.transformer_forward_packed(packed, t,
                                                backend="pallas"), toks)
    out.append((f"attn/transformer_launches_L{cfg.num_layers}", float(n_tf),
                "full packed LM forward (attention + dense megakernels)"))

    # -- live-memory story -------------------------------------------------
    score_bytes = b * h * s * s * 4
    out.append((f"attn/score_matrix_bytes_s{s}", float(score_bytes),
                "(B,H,Sq,Skv) fp32 — what unfused attention materializes"))
    un_bytes, un_shape = max_intermediate_bytes(
        jax.jit(_float_sign_attention), q, q, v)
    out.append((f"attn/max_live_unfused_s{s}", float(un_bytes),
                f"largest HBM intermediate, unfused path {list(un_shape)}"))
    qp = kops.bitpack(q)
    pk_bytes, pk_shape = max_intermediate_bytes(
        lambda a, b_, c: BA.binary_attention_packed(
            a, b_, c, d_true=d, causal=True, interpret=True), qp, qp, v)
    out.append((f"attn/max_live_packed_s{s}", float(pk_bytes),
                f"largest HBM intermediate, packed launch {list(pk_shape)} "
                "(online softmax: no score matrix)"))
    out.append((f"attn/qk_operand_bytes_float_s{s}",
                float(2 * b * s * h * d * 4), "fp32 Q+K"))
    out.append((f"attn/qk_operand_bytes_packed_s{s}",
                float(2 * b * s * h * (d // 32) * 4),
                "channel-packed uint32 Q+K (32x)"))
    return out


def write_bench_json(rs: list[tuple],
                     path="experiments/BENCH_attention.json") -> None:
    os.makedirs(os.path.dirname(path), exist_ok=True)
    payload = [{"name": n, "value": v, "note": note} for n, v, note in rs]
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)


def main() -> None:
    rs = rows()
    for name, us, note in rs:
        print(f"{name},{us:.1f},{note}")
    write_bench_json(rs)
    print("wrote experiments/BENCH_attention.json")


if __name__ == "__main__":
    main()
