"""§Perf hillclimb driver: hypothesis -> change -> re-lower -> measure.

Runs the three selected cells through their optimization sequences and
emits the iteration log consumed by EXPERIMENTS.md §Perf.  Each variant
is a REAL re-lowering of the cell (same analysis-mode methodology as the
baseline roofline) — numbers are measured from the partitioned HLO, not
estimated.

Cells (selection rationale in EXPERIMENTS.md):
  A. starcoder2-3b x decode_32k   — paper-representative (binary weights
                                    target exactly this regime)
  B. chatglm3-6b  x train_4k      — most collective-bound
  C. mamba2-1.3b  x prefill_32k   — worst roofline fraction (TP-dead arch)

    PYTHONPATH=src python -m benchmarks.perf_iterations
"""
from __future__ import annotations

import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

import json

from benchmarks import roofline as RL

OUT = "experiments/perf_iterations.json"

# (cell, variant-tag, quant, opts, hypothesis)
SEQUENCES = [
    ("A", "starcoder2-3b", "decode_32k", [
        ("v0_baseline", None, {"kv_layout": "batch_heads"},
         "baseline: params FSDP-sharded over data; decode all-gathers "
         "the full weights every token (~5.4 GB/step predicted)"),
        ("v1_resident_weights", None, {"fsdp": False,
                                       "kv_layout": "batch_heads"},
         "replicate params over data (inference has no opt state; "
         "3B x 2B / 16 TP = 375 MB/chip) -> weight all-gathers vanish; "
         "napkin: collective 90ms -> ~2ms (small TP all-reduces left)"),
        ("v2_kv_seq_model", None, {"fsdp": False,
                                   "kv_layout": "seq_model"},
         "kv=2 heads cannot shard over model=16 -> attention replicated "
         "16x; shard cache S over model instead: per-chip KV 16x down, "
         "GSPMD synthesizes the flash-decoding combine; napkin: "
         "attention flops/chip /16, memory term ~/2"),
        ("v3_binary_weights", "binary_weight", {"fsdp": False,
                                                "kv_layout": "seq_model"},
         "paper technique: 1-bit packed weights (C1/C2) -> weight HBM "
         "reads 16x down vs bf16; decode is weight-read-bound so the "
         "memory term should drop ~10x (KV reads remain)"),
        ("v4_int8_kv", "binary_weight", {"fsdp": False,
                                         "kv_layout": "seq_model",
                                         "kv_int8": True},
         "beyond-paper: the paper's pack-the-memory-bound-operand idea "
         "applied to the KV cache (int8 + per-(token,head) scale): KV "
         "reads halve -> memory 0.66 ms -> ~0.45 ms; decode logits "
         "within 0.03 of bf16 (tests)"),
    ]),
    ("B", "chatglm3-6b", "train_4k", [
        ("v0_baseline", None, {},
         "baseline: FSDP over data + TP over model; GSPMD resolves the "
         "d_in@data x token@data contractions by all-reducing activation"
         "-sized partials (~2 TB/step measured at depth-2 extrapolation)"),
        ("v1_zero0", None, {"fsdp": False},
         "ZeRO-degree-0: 6B params x 18 B opt bytes / 16 TP = 6.8 GB/chip"
         " fits -> replicate over data; collectives reduce to one grad "
         "all-reduce (2 x P_local x 4B ~ 3 GB) + TP reductions; napkin: "
         "collective term 41 s -> ~1.5 s (25x)"),
        ("v2_replicate_embed", None, {"fsdp": False,
                                      "replicate_embed": True},
         "HLO showed the vocab-sharded embedding emitting masked-gather "
         "+ f32 (B,S,D) all-reduce per step (fwd + scatter-add bwd); "
         "replicating the 0.5 GB table removes both; napkin: "
         "-2 x 4.3 GB x 2(ring) = -17 GB/step -> coll -0.35 s plus the "
         "same again in backward"),
        ("v3_bf16_grads", None, {"fsdp": False, "replicate_embed": True,
                                 "grads_bf16": True},
         "mixed precision: differentiate w.r.t. bf16 weight casts so the "
         "DP gradient all-reduce is bf16 (-24 GB, ~-4%); AdamW still "
         "updates fp32 masters"),
    ]),
    ("C", "mamba2-1.3b", "prefill_32k", [
        ("v0_baseline", None, {},
         "baseline: fused in_proj interleaves [z|x|B|C|dt] so TP cannot "
         "split it -> mamba compute replicated 16x over model"),
        ("v1_resident_weights", None, {"fsdp": False},
         "inference params replicated over data (no FSDP gathers)"),
        ("v2_split_proj", None, {"fsdp": False, "ssm_split": True},
         "split z/x/B/C/dt projections + per-block conv: d_inner and "
         "heads shard cleanly over model -> SSD einsums parallelize "
         "16x; napkin: compute term /16, plus out_proj all-reduce "
         "(tokens x D x 4B per layer) added"),
    ]),
]


def main() -> None:
    log = []
    for cell_id, arch, shape, variants in SEQUENCES:
        prev = None
        for tag, quant, opts, hypothesis in variants:
            r = RL.analyze_cell(arch, shape, quant=quant, opts=opts,
                                tag=tag)
            entry = {
                "cell": cell_id, "arch": arch, "shape": shape,
                "variant": tag, "quant": quant or "float", "opts": opts,
                "hypothesis": hypothesis,
                "compute_s": r["compute_s"], "memory_s": r["memory_s"],
                "collective_s": r["collective_s"],
                "bound_s": r["bound_s"], "dominant": r["dominant"],
                "roofline_fraction": r["roofline_fraction"],
            }
            if prev is not None:
                entry["delta_bound"] = prev["bound_s"] / max(
                    r["bound_s"], 1e-12)
            log.append(entry)
            print(f"[perf] {cell_id}/{tag:22s} dom={r['dominant']:10s} "
                  f"bound={r['bound_s']:.3e}s "
                  f"(c={r['compute_s']:.2e} m={r['memory_s']:.2e} "
                  f"coll={r['collective_s']:.2e}) "
                  f"frac={r['roofline_fraction']:.3f}"
                  + (f"  [{entry['delta_bound']:.1f}x better]"
                     if prev else ""))
            prev = entry
    with open(OUT, "w") as f:
        json.dump(log, f, indent=1)
    print(f"wrote {OUT}")


if __name__ == "__main__":
    main()
