"""§Roofline: three-term roofline per (arch x shape) from the compiled

dry-run (single-pod 16x16 = 256 chips).

Methodology (DESIGN.md §6 + EXPERIMENTS.md §Roofline):

* XLA's HloCostAnalysis visits a while-loop body once, so a scanned
  program under-reports by the trip count.  We therefore re-lower each
  cell in **analysis mode** (every scan unrolled) at TWO reduced depths
  L1 = period, L2 = 3*period and extrapolate linearly to the full depth:
      f(L) = f(L1) + (f(L2) - f(L1)) * (L - L1) / (L2 - L1)
  Layers are homogeneous within a pattern period, so per-device FLOPs,
  bytes, and collective bytes are exactly affine in depth; the intercept
  carries the depth-independent work (embeddings, logits/loss chunks).

* Hardware constants (TPU v5e): 197 TFLOP/s bf16/chip, 819 GB/s HBM,
  50 GB/s/link ICI.

    compute    = flops_per_device / 197e12
    memory     = hbm_bytes_per_device / 819e9
    collective = collective_bytes_per_device / 50e9

Usage:
    PYTHONPATH=src python -m benchmarks.roofline [--cells a:s ...] \
        [--quant binary_weight] [--table]
Emits experiments/roofline/<cell>.json per depth and a combined
experiments/roofline/table.csv + markdown to stdout with --table.
"""
from __future__ import annotations

import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

import argparse
import dataclasses
import json

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

ROOF_DIR = "experiments/roofline"
DRY_DIR = "experiments/dryrun"


def _cells_all():
    from repro.configs import list_configs
    from repro.configs.shapes import SHAPES
    return [(a, s) for a in list_configs() for s in SHAPES]


def _analysis_depths(cfg) -> tuple[int, int]:
    # 2 and 4 periods: avoids single-layer GSPMD strategy degeneracies
    # that break the linear-in-depth assumption.
    p = cfg.pattern_period
    return 2 * p, 4 * p


def analyze_cell(arch: str, shape_name: str, *, quant: str | None = None,
                 force: bool = False, opts: dict | None = None,
                 tag: str = "") -> dict | None:
    """Two reduced-depth analysis lowers + extrapolation -> roofline terms."""
    from repro.configs import get_config, get_shape
    from repro.launch import dryrun as DR

    cfg = get_config(arch, quant=quant)
    # mirror run_cell's opts-driven config transforms so the analytic
    # terms (memory model, MODEL_FLOPS) see the same architecture
    if opts and opts.get("ssm_split") and cfg.ssm is not None:
        cfg = dataclasses.replace(cfg, ssm=dataclasses.replace(
            cfg.ssm, fused_proj=False))
    if opts and opts.get("kv_int8"):
        cfg = dataclasses.replace(cfg, kv_cache_dtype="int8")
    shape = get_shape(shape_name)
    if DR.cell_skip_reason(cfg, shape):
        return None
    l1, l2 = _analysis_depths(cfg)

    recs = {}
    for L in (l1, l2):
        cid = (f"{arch}__{shape_name}__16x16__{quant or 'float'}"
               f"__analysis__L{L}" + (f"__{tag}" if tag else ""))
        path = os.path.join(ROOF_DIR, cid + ".json")
        if os.path.exists(path) and not force:
            recs[L] = json.load(open(path))
        else:
            recs[L] = DR.run_cell(arch, shape_name, quant=quant,
                                  out_dir=ROOF_DIR, analysis=True,
                                  layers_override=L, opts=opts, tag=tag)
    L_full = cfg.num_layers

    def extrap(key_fn):
        f1, f2 = key_fn(recs[l1]), key_fn(recs[l2])
        per_layer = max((f2 - f1) / (l2 - l1), 0.0)   # clamp: GSPMD may
        base = max(f1 - per_layer * l1, 0.0)          # change strategy
        return base + per_layer * L_full

    flops = extrap(lambda r: r["flops_per_device"])
    hbm = extrap(lambda r: r["bytes_per_device"])
    coll = extrap(lambda r: r["collective_bytes_per_device"]["total"])
    hbm_analytic = analytic_hbm_bytes(cfg, shape)

    terms = {
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": hbm_analytic / HBM_BW,
        "collective_s": coll / ICI_BW,
    }
    dominant = max(terms, key=terms.get)
    model_flops = model_flops_of(cfg, shape)
    # per-device ideal = model flops / 256 chips
    useful_ratio = (model_flops / 256) / max(flops, 1.0)
    out = {
        "arch": arch, "shape": shape_name, "quant": quant or "float",
        "tag": tag,
        "flops_per_device": flops, "hbm_bytes_per_device": hbm_analytic,
        "hlo_bytes_per_device": hbm,
        "memory_hlo_s": hbm / HBM_BW,
        "collective_bytes_per_device": coll,
        **{k: v for k, v in terms.items()},
        "dominant": dominant.replace("_s", ""),
        "model_flops_global": model_flops,
        "model_vs_hlo_ratio": useful_ratio,
        "bound_s": max(terms.values()),
        "roofline_fraction": useful_ratio * (terms["compute_s"]
                                             / max(terms.values())),
    }
    with open(os.path.join(
            ROOF_DIR,
            f"{arch}__{shape_name}__{quant or 'float'}"
            + (f"__{tag}" if tag else "") + "__terms.json"),
            "w") as f:
        json.dump(out, f, indent=1)
    return out


def analytic_hbm_bytes(cfg, shape) -> float:
    """Per-device HBM traffic model (fused-execution realistic bound).

    The HLO 'bytes accessed' on the CPU backend counts every unfused
    intermediate and overstates HBM traffic by orders of magnitude; this
    analytic model is what a fused TPU program actually moves per step
    and is used for the memory roofline term (the raw HLO figure is also
    reported as ``memory_hlo_s``).

    Model (per chip, mesh 16x16: model=16 TP shards, data=16 DP shards):
      weights:  active params / 16 (TP), x(2B read)  [train: +grad write
                4B + adam m/v read+write 16B + master rw 8B = 30B/param]
                binary-packed weights: /16 bytes on the read.
      acts:     ~8 live tensor passes per layer x local tokens x D x 2B
                (train with remat: ~20 passes incl. recompute+bwd)
      kv cache: decode reads the whole local cache slice per step.
      logits:   local tokens x V/16 x 2B (train/prefill).
    """
    pc = cfg.param_counts()
    n_active = pc["body_active"] + (
        0 if cfg.tie_embeddings else cfg.vocab_size * cfg.d_model)
    tp = 16
    dp = 16
    packed = cfg.quant.mode.value != "float"
    w_read = n_active / tp * (0.125 * 1.0 if packed else 2.0)
    tokens_local = shape.global_batch * shape.seq_len / dp
    d = cfg.d_model
    L = cfg.num_layers + cfg.encoder_layers
    if shape.kind == "train":
        w_bytes = n_active / tp * (2.0 + 30.0) if not packed else \
            n_active / tp * 32.0
        act_bytes = 20.0 * L * tokens_local * d * 2.0
        logit_bytes = tokens_local * cfg.vocab_size / tp * 2.0
        return w_bytes + act_bytes + logit_bytes
    if shape.kind == "prefill":
        act_bytes = 8.0 * L * tokens_local * d * 2.0
        logit_bytes = shape.global_batch / dp * cfg.vocab_size / tp * 2.0
        return w_read + act_bytes + logit_bytes
    # decode: weights + full local KV slice + tiny activations
    n_attn = sum(1 for i in range(cfg.num_layers)
                 if cfg.layer_kind(i) in ("global", "local"))
    kv_len = {"global": shape.seq_len,
              "local": min(cfg.window_size, shape.seq_len)}
    kv_byte = 2.0 if cfg.kv_cache_dtype != "int8" else         (1.0 + 2.0 / max(cfg.head_dim, 1))      # int8 + bf16 scale / D
    kv_bytes = 0.0
    for i in range(cfg.num_layers):
        k = cfg.layer_kind(i)
        if k in kv_len:
            kv_bytes += (2 * shape.global_batch * kv_len[k]
                         * cfg.num_kv_heads * cfg.head_dim * kv_byte)
    kv_bytes /= (dp * tp) if shape.global_batch >= dp else tp
    state_bytes = 0.0
    if cfg.ssm:
        s = cfg.ssm
        d_in = s.expand * d
        state_bytes = cfg.num_layers * shape.global_batch * (
            d_in // s.head_dim) * s.head_dim * s.d_state * 4.0 * 2
    if cfg.rglru:
        w = cfg.rglru.lru_width or d
        state_bytes += cfg.num_layers * shape.global_batch * w * 4.0 * 2
    act_bytes = 8.0 * L * (shape.global_batch / min(dp,
                                                    shape.global_batch)
                           ) * d * 2.0
    return w_read + kv_bytes + state_bytes + act_bytes


def model_flops_of(cfg, shape) -> float:
    """Analytic MODEL_FLOPS (global, per step) — the 'useful' compute.

    train: 6 * N_active * tokens;  prefill: 2 * N_active * tokens +
    attention 4*B*L_attn*Hq*D*S^2/2(causal); decode: 2 * N_active * B +
    attention KV reads 4*B*L_attn*Hq*D*S.
    """
    pc = cfg.param_counts()
    n_active = pc["body_active"] + (
        0 if cfg.tie_embeddings else cfg.vocab_size * cfg.d_model)
    tokens = shape.global_batch * shape.seq_len
    n_attn = sum(1 for i in range(cfg.num_layers)
                 if cfg.layer_kind(i) in ("global", "local"))
    hd, hq = cfg.head_dim, cfg.num_heads
    if shape.kind == "train":
        base = 6.0 * n_active * tokens
        attn = 0.0
        for i in range(cfg.num_layers):
            k = cfg.layer_kind(i)
            if k == "global":
                attn += 3 * 4 * shape.global_batch * hq * hd \
                    * shape.seq_len ** 2 / 2
            elif k == "local":
                w = min(cfg.window_size, shape.seq_len)
                attn += 3 * 4 * shape.global_batch * hq * hd \
                    * shape.seq_len * w / 2
        # logits: 6 * B*S * D * V
        base += 6.0 * tokens * cfg.d_model * cfg.vocab_size
        return base + attn
    if shape.kind == "prefill":
        base = 2.0 * n_active * tokens
        attn = 0.0
        for i in range(cfg.num_layers):
            k = cfg.layer_kind(i)
            if k == "global":
                attn += 4 * shape.global_batch * hq * hd \
                    * shape.seq_len ** 2 / 2
            elif k == "local":
                w = min(cfg.window_size, shape.seq_len)
                attn += 4 * shape.global_batch * hq * hd \
                    * shape.seq_len * w / 2
        return base + attn + 2.0 * shape.global_batch * cfg.d_model \
            * cfg.vocab_size
    # decode: one token per sequence
    base = 2.0 * n_active * shape.global_batch
    attn = 0.0
    for i in range(cfg.num_layers):
        k = cfg.layer_kind(i)
        if k == "global":
            attn += 4 * shape.global_batch * hq * hd * shape.seq_len
        elif k == "local":
            attn += 4 * shape.global_batch * hq * hd \
                * min(cfg.window_size, shape.seq_len)
    return base + attn + 2.0 * shape.global_batch * cfg.d_model \
        * cfg.vocab_size


def emit_table(rows: list[dict]) -> str:
    hdr = ("| arch | shape | quant | compute s | memory s | collective s |"
           " dominant | MODEL/HLO | roofline frac |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['quant']} "
            f"| {r['compute_s']:.2e} | {r['memory_s']:.2e} "
            f"| {r['collective_s']:.2e} | {r['dominant']} "
            f"| {r['model_vs_hlo_ratio']:.3f} "
            f"| {r['roofline_fraction']:.3f} |")
    return hdr + "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--cells", nargs="*", default=None,
                    help="arch:shape pairs; default all")
    ap.add_argument("--quant", default=None)
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    cells = ([tuple(c.split(":")) for c in args.cells] if args.cells
             else _cells_all())
    rows = []
    for a, s in cells:
        try:
            r = analyze_cell(a, s, quant=args.quant, force=args.force)
        except Exception as e:  # noqa: BLE001
            print(f"[roofline] {a}:{s} FAILED {e!r}")
            continue
        if r:
            rows.append(r)
            print(f"[roofline] {a:28s} {s:12s} dominant={r['dominant']:10s} "
                  f"bound={r['bound_s']:.2e}s frac={r['roofline_fraction']:.3f}")
    os.makedirs(ROOF_DIR, exist_ok=True)
    with open(os.path.join(ROOF_DIR, "table.md"), "w") as f:
        f.write(emit_table(rows))
    print(emit_table(rows))


if __name__ == "__main__":
    main()
