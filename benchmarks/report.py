"""Assemble EXPERIMENTS.md table fragments from the JSON artifacts.

    PYTHONPATH=src python -m benchmarks.report
Writes experiments/fragments/{dryrun.md,roofline.md,perf.md}.
"""
from __future__ import annotations

import glob
import json
import os

DRY = "experiments/dryrun"
ROOF = "experiments/roofline"
FRAG = "experiments/fragments"

ARCH_ORDER = ["nemotron-4-15b", "chatglm3-6b", "gemma2-9b",
              "starcoder2-3b", "mamba2-1.3b", "llama4-maverick-400b-a17b",
              "qwen3-moe-30b-a3b", "qwen2-vl-72b", "whisper-base",
              "recurrentgemma-9b"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def _fmt_bytes(b):
    if b is None:
        return "-"
    return f"{b / 2**30:.2f} GiB"


def dryrun_table() -> str:
    rows = []
    for mesh in ("16x16", "2x16x16"):
        for arch in ARCH_ORDER:
            for shape in SHAPE_ORDER:
                path = f"{DRY}/{arch}__{shape}__{mesh}__float.json"
                if not os.path.exists(path):
                    continue
                r = json.load(open(path))
                if r["status"] == "skipped":
                    rows.append(f"| {arch} | {shape} | {mesh} | skipped |"
                                f" — | — | — | {r['skip_reason'][:60]}… |")
                    continue
                mem = r.get("memory", {})
                arg = mem.get("argument_size_in_bytes")
                tmp = mem.get("temp_size_in_bytes")
                coll = r["collective_bytes_per_device"]["total"]
                rows.append(
                    f"| {arch} | {shape} | {mesh} | ok "
                    f"({r['compile_s']:.0f}s) | {_fmt_bytes(arg)} "
                    f"| {_fmt_bytes(tmp)} | {coll / 2**30:.2f} GiB | |")
    hdr = ("| arch | shape | mesh | compile | args/device | temps/device "
           "| collective B/device (scan-body) | note |\n"
           "|---|---|---|---|---|---|---|---|\n")
    return hdr + "\n".join(rows)


def roofline_table() -> str:
    rows = []
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            path = f"{ROOF}/{arch}__{shape}__float__terms.json"
            if not os.path.exists(path):
                continue
            r = json.load(open(path))
            dom = r["dominant"]
            rows.append(
                f"| {arch} | {shape} | {r['compute_s']:.2e} "
                f"| {r['memory_s']:.2e} | {r['collective_s']:.2e} "
                f"| **{dom}** | {r['model_flops_global']:.2e} "
                f"| {r['model_vs_hlo_ratio']:.3f} "
                f"| {r['roofline_fraction']:.3f} |")
    hdr = ("| arch | shape | compute s | memory s | collective s | "
           "dominant | MODEL_FLOPS | MODEL/HLO | roofline frac |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    return hdr + "\n".join(rows)


def perf_table() -> str:
    path = "experiments/perf_iterations.json"
    if not os.path.exists(path):
        return "(perf iterations pending)"
    log = json.load(open(path))
    rows = []
    for e in log:
        delta = f"{e.get('delta_bound', 0):.1f}x" if "delta_bound" in e \
            else "baseline"
        rows.append(
            f"| {e['cell']} | {e['arch']} x {e['shape']} | {e['variant']} "
            f"| {e['compute_s']:.2e} | {e['memory_s']:.2e} "
            f"| {e['collective_s']:.2e} | {e['dominant']} "
            f"| {e['bound_s']:.3e} | {delta} |")
    hdr = ("| cell | target | variant | compute s | memory s | "
           "collective s | dominant | bound s | vs prev |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    return hdr + "\n".join(rows)


def main() -> None:
    os.makedirs(FRAG, exist_ok=True)
    for name, fn in (("dryrun", dryrun_table),
                     ("roofline", roofline_table),
                     ("perf", perf_table)):
        with open(f"{FRAG}/{name}.md", "w") as f:
            f.write(fn())
        print(f"wrote {FRAG}/{name}.md")


if __name__ == "__main__":
    main()
