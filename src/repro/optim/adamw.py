"""AdamW with fp32 master weights + binary-latent clipping (paper §4.4).

The paper trains BDNNs by accumulating gradients into full-precision
latent weights, clipping them to [-1, 1] so the fp values stay in the
range where sign() is informative.  ``adamw_update`` applies that clip to
every leaf whose path is a quantized Linear when ``clip_latent`` is on.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    clip_latent: bool = False      # binary mode: clip latents to [-1, 1]


def adamw_init(params) -> dict:
    zeros = lambda: jax.tree.map(jnp.zeros_like, params)
    return {"mu": zeros(), "nu": zeros(),
            "step": jnp.zeros((), jnp.int32)}


def _global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def adamw_update(cfg: AdamWConfig, params, grads, state, lr_scale=1.0):
    step = state["step"] + 1
    gn = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gn, 1e-12))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    b1, b2 = cfg.b1, cfg.b2
    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state["mu"], grads)
    nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state["nu"],
                      grads)
    mu_hat_s = 1.0 / (1 - b1 ** step.astype(jnp.float32))
    nu_hat_s = 1.0 / (1 - b2 ** step.astype(jnp.float32))
    lr = cfg.lr * lr_scale

    def upd(p, m, v):
        u = (m * mu_hat_s) / (jnp.sqrt(v * nu_hat_s) + cfg.eps)
        newp = p.astype(jnp.float32) - lr * (u + cfg.weight_decay
                                             * p.astype(jnp.float32))
        if cfg.clip_latent:
            newp = jnp.clip(newp, -1.0, 1.0)
        return newp.astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, {"mu": mu, "nu": nu, "step": step}, gn
