"""1-bit gradient compression with error feedback (signSGD-EF).

The paper's C1 (pack ±1 into words, 32x byte cut) applied to the
*training* communication path: before the data-parallel all-reduce each
worker transmits sign(g + e) — one bit per element plus one fp scale —
and keeps the quantization error e for the next step (Seide et al. 2014;
Karimireddy et al. 2019 EF-signSGD).

In a jit/GSPMD program the all-reduce is implicit, so this is implemented
as a gradient transform whose *numerics* match 1-bit-compressed
communication; the 32x collective-byte reduction it would buy on the wire
is accounted analytically in EXPERIMENTS.md §Perf.  ``pack_bits`` from
the core library is reused for the on-the-wire layout in the benchmark
(`benchmarks/grad_compress_bytes.py`).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def signsgd_ef_init(params):
    return jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32),
                        params)


def signsgd_ef_compress(grads, error):
    """Returns (compressed_grads, new_error).

    compressed = scale * sign(g + e) with scale = mean(|g + e|) per tensor
    (the unbiased-ish magnitude-preserving choice); e' = (g + e) - comp.
    """

    def one(g, e):
        corr = g.astype(jnp.float32) + e
        scale = jnp.mean(jnp.abs(corr))
        comp = jnp.sign(corr) * scale
        return comp.astype(g.dtype), corr - comp

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(error)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    comp = jax.tree.unflatten(tdef, [o[0] for o in outs])
    new_e = jax.tree.unflatten(tdef, [o[1] for o in outs])
    return comp, new_e
