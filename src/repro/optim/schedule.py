"""LR schedules."""
from __future__ import annotations

import jax.numpy as jnp


def cosine_schedule(step, *, warmup: int = 100, total: int = 10000,
                    floor: float = 0.1):
    step = step.astype(jnp.float32)
    warm = jnp.minimum((step + 1.0) / max(warmup, 1), 1.0)
    prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return warm * (floor + (1 - floor) * cos)
