from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.optim.compress import signsgd_ef_init, signsgd_ef_compress
from repro.optim.schedule import cosine_schedule

__all__ = ["AdamWConfig", "adamw_init", "adamw_update",
           "signsgd_ef_init", "signsgd_ef_compress", "cosine_schedule"]
