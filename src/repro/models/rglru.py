"""RG-LRU recurrent block — Griffin / RecurrentGemma (arXiv:2402.19427).

Recurrence (eq. 1-4 of the paper):
    r_t = sigmoid(W_a x_t + b_a)                      (recurrence gate)
    i_t = sigmoid(W_x x_t + b_x)                      (input gate)
    a_t = exp(-c * softplus(Lambda) * r_t)            (log-space decay)
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

The block around it (Griffin "recurrent block"): two parallel branches of
width ``lru_width`` — (linear -> GeLU) and (linear -> causal conv1d ->
RG-LRU) — merged multiplicatively, then projected back to d_model.

Training uses ``jax.lax.associative_scan`` over the linear recurrence
(h_t = a_t h_{t-1} + b_t), which parallelizes to O(log S) depth — the
TPU-native mapping of the paper's custom "linear scan" Pallas/TPU kernel.
Decode is the O(1) recurrence.

Paper-technique note (DESIGN.md §7): branch projections are quant-aware;
the gates/recurrence stay fp (data-dependent products in (0,1)).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import common as C
from repro.models import linear as LN


def _width(cfg: ArchConfig) -> int:
    return cfg.rglru.lru_width or cfg.d_model


def init_rglru_block(key: jax.Array, cfg: ArchConfig) -> dict:
    d = cfg.d_model
    w = _width(cfg)
    r = cfg.rglru
    ks = jax.random.split(key, 7)
    # Lambda init so a^c in [0.9, 0.999] at r=1 (paper App. A)
    u = jax.random.uniform(ks[4], (w,), minval=0.9 ** 2, maxval=0.999 ** 2)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / (2 * r.c_exponent)))
    return {
        "w_gelu": LN.init_linear(ks[0], d, w),
        "w_rec_in": LN.init_linear(ks[1], d, w),
        "conv_w": jax.random.normal(ks[2], (r.conv_width, w)) * 0.1,
        "conv_b": jnp.zeros((w,)),
        "wa": LN.init_linear(ks[3], w, w),
        "ba": jnp.zeros((w,)),
        "wx": LN.init_linear(ks[5], w, w),
        "bx": jnp.zeros((w,)),
        "lambda_p": lam,
        "w_out": LN.init_linear(ks[6], w, d),
    }


def _gates(params: dict, cfg: ArchConfig, x: jax.Array):
    """x: (..., W) fp32 -> (log_a, gated_input) both (..., W) fp32."""
    r = cfg.rglru
    ra = jax.nn.sigmoid(
        LN.apply_linear(params["wa"], x, cfg.quant, dtype=jnp.float32)
        + params["ba"])
    ix = jax.nn.sigmoid(
        LN.apply_linear(params["wx"], x, cfg.quant, dtype=jnp.float32)
        + params["bx"])
    log_a = -r.c_exponent * jax.nn.softplus(params["lambda_p"]) * ra
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (ix * x)
    return a, b


def _conv1d(x: jax.Array, w: jax.Array, b: jax.Array,
            init_state: jax.Array | None = None):
    k = w.shape[0]
    if init_state is None:
        init_state = jnp.zeros((x.shape[0], k - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([init_state, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(k)) + b
    return y, xp[:, -(k - 1):, :]


def rglru_block_forward(params: dict, cfg: ArchConfig, x: jax.Array, *,
                        init_cache: dict | None = None,
                        return_cache: bool = False):
    """x: (B, S, D) -> (B, S, D)."""
    dt = cfg.activation_dtype
    gelu_branch = jax.nn.gelu(
        LN.apply_linear(params["w_gelu"], x, cfg.quant,
                        dtype=jnp.float32))
    rec = LN.apply_linear(params["w_rec_in"], x, cfg.quant,
                          dtype=jnp.float32)
    conv_init = init_cache["conv"] if init_cache else None
    rec, conv_state = _conv1d(rec, params["conv_w"], params["conv_b"],
                              conv_init)
    a, b = _gates(params, cfg, rec)                       # (B,S,W)
    h0 = init_cache["h"] if init_cache else jnp.zeros(
        (x.shape[0], rec.shape[-1]), jnp.float32)
    # fold h0 into the first step:  h_1 = a_1 h_0 + b_1
    b = b.at[:, 0, :].add(a[:, 0, :] * h0)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    y = (gelu_branch * h).astype(dt)
    out = LN.apply_linear(params["w_out"], y, cfg.quant, dtype=dt)
    if return_cache:
        return out, {"conv": conv_state, "h": h[:, -1, :]}
    return out


def init_rglru_cache(cfg: ArchConfig, batch: int) -> dict:
    w = _width(cfg)
    return {"conv": jnp.zeros((batch, cfg.rglru.conv_width - 1, w),
                              jnp.float32),
            "h": jnp.zeros((batch, w), jnp.float32)}


def rglru_block_decode(params: dict, cfg: ArchConfig, x: jax.Array,
                       cache: dict):
    """x: (B, 1, D) single-step recurrence."""
    dt = cfg.activation_dtype
    gelu_branch = jax.nn.gelu(
        LN.apply_linear(params["w_gelu"], x, cfg.quant, dtype=jnp.float32))
    rec = LN.apply_linear(params["w_rec_in"], x, cfg.quant,
                          dtype=jnp.float32)
    conv_in = jnp.concatenate([cache["conv"], rec], axis=1)
    y_conv = (conv_in * params["conv_w"][None]).sum(axis=1, keepdims=True) \
        + params["conv_b"]
    new_conv = conv_in[:, 1:, :]
    a, b = _gates(params, cfg, y_conv)                    # (B,1,W)
    h = a[:, 0] * cache["h"] + b[:, 0]
    y = (gelu_branch[:, 0] * h).astype(dt)[:, None, :]
    out = LN.apply_linear(params["w_out"], y, cfg.quant, dtype=dt)
    return out, {"conv": new_conv, "h": h}
