"""The paper's evaluation networks (§6.2, §6.3) as JAX models.

* ``bmlp``  — BinaryNet MLP for MNIST (Courbariaux et al. 2016 §2.1):
              784 -> 3 x [4096 dense, BN, sign] -> 10 dense, BN.
* ``bcnn``  — BinaryNet VGG-like CNN for CIFAR-10 (Hubara et al. 2016
              §2.3): 2x128C3-MP2-2x256C3-MP2-2x512C3-MP2-2x1024FC-10FC,
              BN + sign after every conv/dense.

Each network has:
  init(key, spec)        -> trainable params (latent fp weights + BN)
  forward_float(...)     -> the float-sign reference forward
  pack(params, spec)     -> one-time packed inference params (paper C2)
  forward_packed(...)    -> the optimized packed forward

forward_packed == forward_float exactly on the integer dots, and to fp
round-off on the final BN logits (tests/test_paper_equivalence.py).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from repro import telemetry
from repro.core import binarize as B
from repro.core import binary_layers as L
from repro.kernels import ops as kops


# ---------------------------------------------------------------------------
# Binary MLP (paper §6.2)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class BMLPSpec:
    sizes: tuple[int, ...] = (784, 4096, 4096, 4096, 10)
    nbits_input: int = 8          # MNIST pixels are 8-bit (paper §4.3)


def init_bmlp(key: jax.Array, spec: BMLPSpec) -> dict:
    layers, bns = [], []
    for i, (d_in, d_out) in enumerate(zip(spec.sizes[:-1], spec.sizes[1:])):
        key, sub = jax.random.split(key)
        layers.append(L.init_binary_dense(sub, d_in, d_out))
        bns.append(L.init_batchnorm(d_out))
    return {"layers": layers, "bns": bns}


def bmlp_forward_float(params: dict, x_uint8: jax.Array, *,
                       ste: bool = False) -> jax.Array:
    """Reference forward.  x_uint8: (B, 784) fixed-precision input."""
    n = len(params["layers"])
    h = None
    for i in range(n):
        if i == 0:
            z = L.apply_bitplane_dense_float(params["layers"][i], x_uint8)
        else:
            z = L.apply_binary_dense_float(params["layers"][i], h, ste=ste)
        z = L.apply_batchnorm(params["bns"][i], z)
        if i < n - 1:
            h = B.binarize_ste(z) if ste else B.sign_pm1(z)
    return z                       # logits (no sign on the output layer)


def pack_bmlp(params: dict, spec: BMLPSpec) -> dict:
    n = len(params["layers"])
    packed_layers = []
    for i in range(n):
        if i == 0:
            packed_layers.append(
                L.pack_bitplane_dense(params["layers"][i],
                                      nbits=spec.nbits_input))
        else:
            packed_layers.append(L.pack_binary_dense(params["layers"][i]))
    folded = [L.fold_bn_sign(bn) for bn in params["bns"][:-1]]
    return {"layers": packed_layers, "folded": folded,
            "bn_out": params["bns"][-1]}


def _gather_packed(hp: jax.Array, axis_name: str) -> jax.Array:
    """Reassemble a C_out-sharded PACKED activation along its word axis.

    Inside the sharded forward each model shard packs its own span of
    32-bit words (``bn_sign_pack`` on its local channels), so a tiled
    all-gather along the trailing word axis reconstructs the exact
    single-device word layout — this is the ONLY cross-device traffic in
    the packed forward, and it moves 1-bit words, never the int32
    pre-threshold activation.

    Every gather site bumps ``sharding.gathers`` on the process-wide
    telemetry registry at TRACE time — i.e. it counts the all-gather
    eqns a sharded forward lowers to, the same structural fact the
    probes' ``collective_kinds`` gate, not per-execution traffic (the
    compiled function re-runs without re-tracing).
    """
    tel = telemetry.default()
    tel.metrics.counter("sharding.gathers").inc()
    with tel.span("sharding.gather", axis=axis_name):
        return jax.lax.all_gather(hp, axis_name, axis=hp.ndim - 1,
                                  tiled=True)


def _check_dense_stack(dense_stack: str) -> None:
    if dense_stack not in ("auto", "resident", "per_layer"):
        raise ValueError(f"unknown dense_stack mode {dense_stack!r}")


def _dense_hidden_stack(layers: list, foldeds: list, hp: jax.Array, *,
                        backend: str, model_axis: str | None,
                        shards: tuple[int, ...],
                        dense_stack: str) -> jax.Array:
    """The hidden dense stack shared by both networks: every layer is a

    fused GEMM + BN-sign + re-bitpack, packed in / packed out.

    Unsharded stacks route through ``apply_binary_dense_stack_packed``:
    ONE kernel launch when the stack's weights + folded thresholds are
    VMEM-resident (``dense_stack='auto'``; ``'resident'`` forces it,
    ``'per_layer'`` forces the fallback), per-layer fused launches
    otherwise.  C_out-sharded layers always run per-layer — each shard
    computes its own word span (the ``c_out % (32·|model|)`` pack-seam
    rule guarantees word alignment) and the packed bits are
    all-gathered before the next contraction.
    """
    _check_dense_stack(dense_stack)
    if not layers:
        return hp
    if all(s == 1 for s in shards) and dense_stack != "per_layer":
        return L.apply_binary_dense_stack_packed(
            layers, foldeds, hp, backend=backend,
            resident=True if dense_stack == "resident" else None)
    for i, (layer, folded) in enumerate(zip(layers, foldeds)):
        hp = L.apply_binary_dense_bn_packed(layer, folded, hp,
                                            backend=backend)
        if shards[i] > 1:
            hp = _gather_packed(hp, model_axis)
    return hp


def bmlp_forward_packed(packed: dict, x_uint8: jax.Array, *,
                        backend: str = "auto", model_axis: str | None = None,
                        layer_shards: tuple[int, ...] | None = None,
                        dense_stack: str = "auto") -> jax.Array:
    """Optimized forward: bit-plane first layer (C4), packed GEMMs (C1),

    folded BN+sign thresholds between layers (no fp math until the output
    BN).  Hidden layers run as fused GEMM + BN-sign + re-bitpack kernels
    — and, when the stack is VMEM-resident, as ONE kernel launch for the
    whole hidden stack (``dense_stack``: 'auto' | 'resident' |
    'per_layer').

    When called per-shard inside ``shard_map`` (see
    ``distributed.sharding.make_sharded_forward``), ``layer_shards[i]``
    says how many ways layer ``i``'s d_out is split over ``model_axis``;
    a sharded layer computes its local output columns and the packed
    bits are all-gathered (word-aligned) before the next GEMM.  The
    final layer is always replicated (its output feeds the fp BN).
    """
    n = len(packed["layers"])
    shards = layer_shards or (1,) * n
    assert shards[-1] == 1, "output layer must stay replicated"
    # Stage spans fire at TRACE time (this body runs under jit): they
    # mark which model stage each kernel/gather was traced from, not
    # per-execution wall time (docs/observability.md, "structural
    # spans").  Disabled tracer -> one attribute check per stage.
    tel = telemetry.default()
    with tel.span("model.bmlp.bitplane_dense"):
        z = L.apply_bitplane_dense_packed(packed["layers"][0], x_uint8,
                                          backend=backend)
        # Layer 0 accumulates over bit planes in int32, so its epilogue
        # runs standalone; every later hidden layer fuses GEMM + epilogue.
        hp = L.apply_bn_sign_folded_packed(packed["folded"][0], z,
                                           backend=backend)
        if shards[0] > 1:
            hp = _gather_packed(hp, model_axis)
    with tel.span("model.bmlp.dense_stack", layers=n - 2):
        hp = _dense_hidden_stack(
            packed["layers"][1:n - 1], packed["folded"][1:], hp,
            backend=backend, model_axis=model_axis, shards=shards[1:n - 1],
            dense_stack=dense_stack)
    with tel.span("model.bmlp.output"):
        z = L.apply_binary_dense_prepacked(packed["layers"][n - 1], hp,
                                           backend=backend)
        return L.apply_batchnorm(packed["bn_out"], z)


# ---------------------------------------------------------------------------
# Binary CNN (paper §6.3)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ConvStage:
    c_out: int
    pool: bool = False


@dataclass(frozen=True)
class BCNNSpec:
    input_hw: tuple[int, int] = (32, 32)
    c_in: int = 3
    stages: tuple[ConvStage, ...] = (
        ConvStage(128), ConvStage(128, pool=True),
        ConvStage(256), ConvStage(256, pool=True),
        ConvStage(512), ConvStage(512, pool=True),
    )
    dense: tuple[int, ...] = (1024, 1024, 10)
    ksize: int = 3
    nbits_input: int = 8


def _stage_hw(spec: BCNNSpec):
    """Spatial size entering each conv stage (SAME convs, pool /2)."""
    h, w = spec.input_hw
    out = []
    for st in spec.stages:
        out.append((h, w))
        if st.pool:
            h, w = h // 2, w // 2
    return out, (h, w)


def init_bcnn(key: jax.Array, spec: BCNNSpec) -> dict:
    convs, conv_bns = [], []
    c = spec.c_in
    for st in spec.stages:
        key, sub = jax.random.split(key)
        convs.append(L.init_binary_conv2d(sub, spec.ksize, spec.ksize, c,
                                          st.c_out))
        conv_bns.append(L.init_batchnorm(st.c_out))
        c = st.c_out
    _, (fh, fw) = _stage_hw(spec)
    d_in = fh * fw * c
    denses, dense_bns = [], []
    for d_out in spec.dense:
        key, sub = jax.random.split(key)
        denses.append(L.init_binary_dense(sub, d_in, d_out))
        dense_bns.append(L.init_batchnorm(d_out))
        d_in = d_out
    return {"convs": convs, "conv_bns": conv_bns,
            "denses": denses, "dense_bns": dense_bns}


def bcnn_forward_float(params: dict, x_uint8: jax.Array, spec: BCNNSpec,
                       *, ste: bool = False) -> jax.Array:
    """Reference forward.  x_uint8: (B, H, W, C) fixed-precision input.

    First conv consumes the raw integer input (no sign) — the binary
    technique handles it via bit-planes in the packed path (paper C4)."""
    binarize = B.binarize_ste if ste else B.sign_pm1
    h = x_uint8.astype(jnp.float32)
    for i, st in enumerate(spec.stages):
        w = binarize(params["convs"][i]["w"])
        z = jax.lax.conv_general_dilated(
            h if i == 0 else binarize(h),
            jnp.transpose(w, (1, 2, 3, 0)), (1, 1), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        if st.pool:
            z = L.maxpool2d(z)
        z = L.apply_batchnorm(params["conv_bns"][i], z)
        h = z
    h = binarize(h).reshape(h.shape[0], -1)
    n = len(params["denses"])
    for i in range(n):
        z = L.apply_binary_dense_float(params["denses"][i], h, ste=ste)
        z = L.apply_batchnorm(params["dense_bns"][i], z)
        if i < n - 1:
            h = binarize(z)
    return z


def pack_bcnn(params: dict, spec: BCNNSpec) -> dict:
    hws, _ = _stage_hw(spec)
    packed_convs = []
    for i, st in enumerate(spec.stages):
        if i == 0:
            # First layer runs via bit-planes (C4): the plan's rowsum
            # absorbs both the {0,1}->±1 shift and the pad correction
            # (pads are plane-value 0 == p̂ = -1), and the packed forward
            # runs all planes in ONE fused kernel launch.
            pc = L.pack_bitplane_conv2d(params["convs"][i],
                                        input_hw=hws[i], stride=1,
                                        padding="SAME",
                                        nbits=spec.nbits_input)
        else:
            pc = L.pack_binary_conv2d(params["convs"][i], input_hw=hws[i],
                                      stride=1, padding="SAME")
        packed_convs.append(pc)
    folded_conv = [L.fold_bn_sign(bn) for bn in params["conv_bns"]]
    # Bit-domain pooling masks (flip > 0 per channel) for pooled stages.
    pool_masks = [L.pool_flip_mask(folded_conv[i]) if st.pool else None
                  for i, st in enumerate(spec.stages)]
    # The first dense layer consumes the flattened *packed* conv activation
    # (fh, fw, Cw) — pack its weights per pixel group so the zero-bit
    # channel tails line up (see pack_binary_dense_grouped).
    c_last = spec.stages[-1].c_out
    packed_dense = [L.pack_binary_dense_grouped(params["denses"][0], c_last)]
    packed_dense += [L.pack_binary_dense(p) for p in params["denses"][1:]]
    folded_dense = [L.fold_bn_sign(bn) for bn in params["dense_bns"][:-1]]
    return {"convs": packed_convs, "folded_conv": folded_conv,
            "pool_masks": pool_masks,
            "denses": packed_dense, "folded_dense": folded_dense,
            "bn_out": params["dense_bns"][-1], "spec": spec}


def _bitplane_conv_packed(pc: dict, x_uint8: jax.Array, nbits: int, *,
                          backend: str = "auto") -> jax.Array:
    """Stage-0 conv on raw uint8 input: ONE kernel launch on the pallas

    backend (in-kernel plane loop, 2^i weighting + rowsum correction in
    the epilogue) — previously 8 sequential per-plane conv launches.
    ``nbits`` must match the plan (kept as an argument for the call sites
    / launch-count test)."""
    assert nbits == pc["nbits"], (nbits, pc["nbits"])
    return kops.bitplane_conv2d_packed(pc, x_uint8, backend=backend)


def bcnn_forward_packed(packed: dict, x_uint8: jax.Array, *,
                        backend: str = "auto", model_axis: str | None = None,
                        conv_shards: tuple[int, ...] | None = None,
                        dense_shards: tuple[int, ...] | None = None,
                        dense_stack: str = "auto") -> jax.Array:
    """Optimized forward: after the bit-plane first stage, every

    inter-layer activation stays bit-packed in HBM end-to-end — fused
    conv + BN-sign + re-bitpack kernels between conv stages, bit-domain
    max-pooling (OR/AND under the flip mask), and fused
    GEMM + BN-sign + re-bitpack kernels through the hidden dense tail
    (one launch for the whole tail when it is VMEM-resident;
    ``dense_stack``: 'auto' | 'resident' | 'per_layer').  Thresholding
    before pooling is exact because the folded BN-sign compare is
    monotone per channel.

    Sharded execution (per-shard body under ``shard_map``, built by
    ``distributed.sharding.make_sharded_forward``): ``conv_shards[i]`` /
    ``dense_shards[i]`` give the C_out-parallel split of each stage over
    ``model_axis``.  A sharded stage owns its own packed weight rows,
    folded BN thresholds, correction columns, and pool-mask words — the
    conv + BN-sign + repack (+ bit-domain pool) epilogue is fully local
    — and ends with a word-aligned all-gather of the PACKED activation
    so the next stage (which contracts over all input channels) sees the
    full image.  The conv→dense flatten needs no special casing: the
    last conv stage's gather restores the exact single-device word
    layout the grouped dense packing was built against.
    """
    spec: BCNNSpec = packed["spec"]
    n_conv = len(packed["convs"])
    conv_shards = conv_shards or (1,) * n_conv
    dense_shards = dense_shards or (1,) * len(packed["denses"])
    assert dense_shards[-1] == 1, "output layer must stay replicated"
    # Stage spans fire at TRACE time (see bmlp_forward_packed).
    tel = telemetry.default()
    # Stage 0 accumulates 8 bit-plane convs in int32, so its epilogue runs
    # standalone: pool on int32, then fused threshold + re-bitpack.
    with tel.span("model.bcnn.bitplane_conv"):
        z = _bitplane_conv_packed(
            L.localize_conv_plan(packed["convs"][0], conv_shards[0]),
            x_uint8, spec.nbits_input, backend=backend)
        if spec.stages[0].pool:
            z = L.maxpool2d(z)
        hp = L.apply_bn_sign_folded_packed(packed["folded_conv"][0], z,
                                           backend=backend)
        if conv_shards[0] > 1:
            hp = _gather_packed(hp, model_axis)
    # Stages 1..n-1: packed in, packed out — zero un-packed activations.
    for i in range(1, n_conv):
        with tel.span("model.bcnn.conv_stage", stage=i):
            hp = L.apply_binary_conv2d_bn_packed(
                L.localize_conv_plan(packed["convs"][i], conv_shards[i]),
                packed["folded_conv"][i], hp, backend=backend)
            if spec.stages[i].pool:
                hp = L.maxpool2d_packed(hp, packed["pool_masks"][i])
            if conv_shards[i] > 1:
                hp = _gather_packed(hp, model_axis)
    h = hp.reshape(hp.shape[0], -1)         # packed (B, fh*fw*Cw) words
    # Classifier tail: hidden dense layers are fused GEMM + BN-sign +
    # re-bitpack (single-launch when VMEM-resident), the output layer
    # stays int32 for the fp batch-norm.
    n = len(packed["denses"])
    with tel.span("model.bcnn.dense_stack", layers=n - 1):
        h = _dense_hidden_stack(
            packed["denses"][:n - 1], packed["folded_dense"], h,
            backend=backend, model_axis=model_axis,
            shards=dense_shards[:n - 1], dense_stack=dense_stack)
    with tel.span("model.bcnn.output"):
        z = L.apply_binary_dense_prepacked(packed["denses"][n - 1], h,
                                           backend=backend)
        return L.apply_batchnorm(packed["bn_out"], z)


# ---------------------------------------------------------------------------
# Serving seams (train/serve.py): one uniform view over both networks
# ---------------------------------------------------------------------------

def packed_kind(packed: dict) -> str:
    """'bcnn' | 'bmlp' | 'transformer' from the shape of a ``pack_*`` tree.

    The serving layer and the sharding rules both dispatch on this, so
    the check lives once, next to the pack functions whose layout it
    reads ('transformer' trees come from
    ``models.transformer.pack_transformer`` and carry a ``blocks`` list).
    Raises ``ValueError`` for anything else.
    """
    if "convs" in packed:
        return "bcnn"
    if "blocks" in packed:
        return "transformer"
    if "layers" in packed:
        return "bmlp"
    raise ValueError(
        f"not a pack_bcnn/pack_bmlp/pack_transformer tree: "
        f"keys {sorted(packed)}")


def packed_input_shape(packed: dict) -> tuple[int, ...]:
    """Per-example input shape (no batch axis) a packed forward consumes.

    bcnn: ``(H, W, C_in)`` raw uint8; bmlp: ``(K,)`` raw uint8;
    transformer: ``(S,)`` uint8 token ids (reduced registry configs have
    vocab ≤ 256) — every workload takes fixed-precision input, so the
    serving scratch pool can stage requests without knowing which
    network is behind the queue.
    """
    kind = packed_kind(packed)
    if kind == "bcnn":
        spec: BCNNSpec = packed["spec"]
        return (*spec.input_hw, spec.c_in)
    if kind == "transformer":
        return (int(packed["meta"]["seq_len"]),)
    return (int(packed["layers"][0]["k_true"]),)


def packed_dense_kw_words(packed: dict) -> int:
    """Widest dense packed-K extent of the network, in uint32 words.

    The K side of ``kernels.ops.dispatch_batch``: a batch routes
    through the GEMV serving grid only if every dense layer's packed K
    fits the resident activation block, so the widest layer decides
    the route for the whole forward.
    """
    kind = packed_kind(packed)
    if kind == "transformer":
        mats = [blk[w] for blk in packed["blocks"]
                for w in ("wq", "wk", "wv", "wo", "w1", "w2")]
        mats.append(packed["head"])
        return max(int(p["w_packed"].shape[1]) for p in mats)
    layers = packed["denses"] if kind == "bcnn" else packed["layers"]
    return max(int(p["w_packed"].shape[1]) for p in layers)


def demo_model(kind: str, *, smoke: bool = False, seed: int = 0):
    """Reduced evaluation-network preset + random params for demo
    drivers — the serving CLI (``launch/serve.py``) and the serving
    benchmark (``benchmarks/serve_latency.py``) both build from this
    one place so their shapes cannot drift.  Returns
    ``(params, spec, kind)``.  ``smoke`` picks CI-sized shapes.
    """
    key = jax.random.PRNGKey(seed)
    if kind == "bcnn":
        spec = BCNNSpec(
            input_hw=(8, 8) if smoke else (16, 16), c_in=3,
            stages=(ConvStage(64), ConvStage(64, pool=True)),
            dense=(128, 10))
        return init_bcnn(key, spec), spec, "bcnn"
    if kind == "bmlp":
        spec = BMLPSpec(sizes=(784, 256, 256, 10) if smoke
                        else (784, 1024, 1024, 10))
        return init_bmlp(key, spec), spec, "bmlp"
    raise ValueError(f"kind must be 'bcnn' or 'bmlp', got {kind!r}")


def make_packed_forward(packed: dict, *, backend: str = "auto",
                        dense_stack: str = "auto"):
    """Jitted single-device forward ``fwd(x_uint8) -> logits``.

    Works for either packed network — the serving layer's default
    engine, and the same call signature as
    ``distributed.sharding.make_sharded_forward`` so a device mesh can
    sit behind the request queue as a drop-in.  ``backend`` /
    ``dense_stack`` validate as in the underlying forward (unknown
    values raise at first call).
    """
    kind = packed_kind(packed)
    if kind == "bcnn":
        def fwd(x):
            return bcnn_forward_packed(packed, x, backend=backend,
                                       dense_stack=dense_stack)
    elif kind == "transformer":
        from repro.models import transformer as TF

        def fwd(x):
            return TF.transformer_forward_packed(packed, x,
                                                 backend=backend,
                                                 dense_stack=dense_stack)
    else:
        def fwd(x):
            return bmlp_forward_packed(packed, x, backend=backend,
                                       dense_stack=dense_stack)
    return jax.jit(fwd)
