"""Mixture-of-Experts FFN with capacity-based gather/scatter dispatch.

Design (DESIGN.md §5): tokens are flattened to (G groups, Tg tokens) and
each group dispatches into per-expert slot buffers of capacity
C = ceil(Tg * top_k / E * capacity_factor).  Dispatch uses a cumsum-based
position-in-expert (the (T, E) mask is materialized — cheap — never the
(T, E, C) one-hot), then pure gathers/scatters:

    slot_token[g, e, c] -> token index (or -1)     scatter
    x_disp[g, e, c, :]  =  x[g, slot_token]        gather
    y[g, t, :]         +=  w_slot * expert_e(x_disp)[g, e, c]   scatter-add

Sharding: G maps to the data axes, E to the model axis (expert
parallelism); the combine scatter-add produces per-expert partials that
GSPMD all-reduces over the model axis — the standard EP collective.
Overflowing tokens are dropped (GShard/Switch semantics); tests check the
ample-capacity case reproduces the dense reference exactly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, MoEConfig
from repro.models import ffn as F
from repro.models import linear as LN


def _expert_w(p: dict, cfg: ArchConfig) -> jax.Array:
    """Expert weight under the quant policy: FLOAT -> raw; BINARY* ->

    sign(W) * per-(expert, out-channel) alpha with STE (the paper's
    technique applied to expert FFNs — DESIGN.md §7).  Expert weights stay
    unpacked in the EP einsum path; the 32x storage cut applies via
    ``pack_bits`` at deployment (documented, not exercised here)."""
    from repro.core import binarize as B
    from repro.core.quantize import QuantMode
    w = p["we"]
    if cfg.quant.mode == QuantMode.FLOAT:
        return w
    alpha = jax.lax.stop_gradient(jnp.mean(jnp.abs(w), axis=-2,
                                           keepdims=True))
    return B.binarize_ste(w) * alpha


def init_moe(key: jax.Array, cfg: ArchConfig) -> dict:
    m = cfg.moe
    d, f, e = cfg.d_model, m.d_ff_expert, m.num_experts
    ks = jax.random.split(key, 5)
    n_up = 2 if F.is_gated(cfg.ffn_type) else 1
    p = {
        "router": LN.init_linear(ks[0], d, e),
        "we_up": {"we": jax.random.normal(ks[1], (e, d, f)) * d ** -0.5},
        "we_down": {"we": jax.random.normal(ks[2], (e, f, d)) * f ** -0.5},
    }
    if n_up == 2:
        p["we_gate"] = {"we": jax.random.normal(ks[3], (e, d, f)) * d ** -0.5}
    if m.shared_experts:
        p["shared"] = F.init_ffn(ks[4], cfg, d_ff=m.d_ff_expert
                                 * m.shared_experts)
    return p


def _capacity(tg: int, m: MoEConfig) -> int:
    c = int(tg * m.top_k / m.num_experts * m.capacity_factor)
    return max(4, -(-c // 4) * 4)


def _dispatch_indices(sel: jax.Array, e: int, c: int):
    """sel: (T, K) expert ids.  Returns (slot_token (E, C) int32 [-1 pad],
    slot_weighti (E, C) int32 index into (T*K) flat slots, keep mask)."""
    t, k = sel.shape
    flat = sel.reshape(t * k)
    onehot = jax.nn.one_hot(flat, e, dtype=jnp.int32)         # (T*K, E)
    pos = jnp.cumsum(onehot, axis=0) * onehot - 1             # pos in expert
    pos = pos.max(axis=1)                                     # (T*K,)
    keep = pos < c
    dest = jnp.where(keep, flat * c + pos, e * c)             # overflow slot
    slot_flatidx = jnp.full((e * c + 1,), -1, jnp.int32)
    slot_flatidx = slot_flatidx.at[dest].set(
        jnp.arange(t * k, dtype=jnp.int32))
    slot_flatidx = slot_flatidx[:-1].reshape(e, c)            # (E, C)
    slot_token = jnp.where(slot_flatidx >= 0, slot_flatidx // k, -1)
    return slot_token, slot_flatidx


def apply_moe(params: dict, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    """x: (B, S, D) -> (B, S, D)."""
    m = cfg.moe
    dt = cfg.activation_dtype
    b, s, d = x.shape
    xf = x.reshape(b * s, d)                                  # groups = B*S/Tg
    # group so dispatch buffers stay device-local; G == B keeps the batch
    # sharding intact.
    g = b
    tg = s
    xg = xf.reshape(g, tg, d)

    logits = LN.apply_linear(params["router"], xg, cfg.quant,
                             dtype=jnp.float32)               # (G, Tg, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, m.top_k)              # (G, Tg, K)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    c = _capacity(tg, m)

    def per_group(xg1, sel1, w1):
        slot_token, slot_flatidx = _dispatch_indices(sel1, m.num_experts, c)
        x_disp = xg1[jnp.clip(slot_token, 0)]                 # (E, C, D)
        x_disp = x_disp * (slot_token >= 0)[..., None]
        # expert FFN (E batched einsums)
        up = jnp.einsum("ecd,edf->ecf", x_disp.astype(dt),
                        _expert_w(params["we_up"], cfg).astype(dt))
        if "we_gate" in params:
            gate = jnp.einsum("ecd,edf->ecf", x_disp.astype(dt),
                              _expert_w(params["we_gate"], cfg).astype(dt))
            act = jax.nn.silu if cfg.ffn_type == "swiglu" else jax.nn.gelu
            h = act(gate.astype(jnp.float32)).astype(dt) * up
        else:
            h = jax.nn.gelu(up.astype(jnp.float32)).astype(dt)
        y_disp = jnp.einsum("ecf,efd->ecd", h,
                            _expert_w(params["we_down"], cfg).astype(dt))  # (E, C, D)
        # combine: scatter-add back to tokens with routing weights
        w_flat = w1.reshape(-1)                                # (Tg*K,)
        w_slot = jnp.where(slot_flatidx >= 0,
                           w_flat[jnp.clip(slot_flatidx, 0)], 0.0)
        y = jnp.zeros((tg, d), jnp.float32)
        y = y.at[jnp.clip(slot_token, 0)].add(
            (y_disp.astype(jnp.float32) * w_slot[..., None]))
        return y

    y = jax.vmap(per_group)(xg, top_e, top_w)                  # (G, Tg, D)
    y = y.reshape(b, s, d).astype(dt)
    if "shared" in params:
        y = y + F.apply_ffn(params["shared"], cfg, x)
    return y


def moe_dense_reference(params: dict, cfg: ArchConfig, x: jax.Array
                        ) -> jax.Array:
    """O(T*E) dense oracle: every expert on every token, combine by router

    weights.  Used by tests (ample capacity must match exactly up to
    dtype)."""
    m = cfg.moe
    dt = jnp.float32
    b, s, d = x.shape
    logits = LN.apply_linear(params["router"], x, cfg.quant, dtype=dt)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, m.top_k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)
    up = jnp.einsum("bsd,edf->bsef", x.astype(dt),
                    _expert_w(params["we_up"], cfg).astype(dt))
    if "we_gate" in params:
        gate = jnp.einsum("bsd,edf->bsef", x.astype(dt),
                          _expert_w(params["we_gate"], cfg).astype(dt))
        act = jax.nn.silu if cfg.ffn_type == "swiglu" else jax.nn.gelu
        h = act(gate) * up
    else:
        h = jax.nn.gelu(up)
    y_all = jnp.einsum("bsef,efd->bsed", h, _expert_w(params["we_down"], cfg).astype(dt))
    mask = jax.nn.one_hot(top_e, m.num_experts, dtype=dt)      # (B,S,K,E)
    w_per_e = jnp.einsum("bske,bsk->bse", mask, top_w)
    y = jnp.einsum("bsed,bse->bsd", y_all, w_per_e)
    if "shared" in params:
        y = y + F.apply_ffn(params["shared"], cfg,
                            x).astype(dt)
    return y.astype(cfg.activation_dtype)
