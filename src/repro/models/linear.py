"""Quantization-aware linear maps — the paper's technique as an LM feature.

A ``Linear`` params dict is either:

* float form:   {"w": (d_in, d_out) fp32}                (training / FLOAT)
* packed form:  {"w_packed": (d_out, d_in/32) uint32,    (inference,
                 "alpha": (d_out,) fp32, "k_true": int}    pack-once — C2)

``apply_linear`` dispatches on QuantMode + GemmStrategy:

* FLOAT          -> bf16 einsum (MXU).
* BINARY_WEIGHT  -> sign(W) with per-output-channel scale alpha
                    (XNOR-Net-style scaling, Rastegari et al. 2016 — the
                    binarization family the paper builds on); activations
                    stay real.  Packed weights cut HBM bytes 32x/16x-vs-
                    bf16; contraction via MXU_UNPACK or VPU bit-count.
* BINARY         -> paper-faithful: sign on activations too (STE in
                    training), XNOR-popcount dot (eq. 2).

Strategy (DESIGN.md §2, the GPU->TPU inversion):
* VPU_XNOR   — packed XOR+popcount (``binary-jnp`` here; the Pallas kernel
               in ``repro.kernels`` is the on-device path).
* MXU_UNPACK — unpack ±1 -> bf16, contract on the MXU.
* AUTO       — by output-row count (memory- vs compute-bound).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import binarize as B
from repro.core.quantize import GemmStrategy, QuantConfig, QuantMode


def init_linear(key: jax.Array, d_in: int, d_out: int, *,
                scale: float | None = None) -> dict:
    s = scale if scale is not None else d_in ** -0.5
    return {"w": jax.random.normal(key, (d_in, d_out), jnp.float32) * s}


def pack_linear(params: dict) -> dict:
    """One-time conversion to packed inference form (paper C2).

    Handles scan-stacked weights: (..., d_in, d_out) packs along d_in.
    ``k_true`` (the logical d_in) is NOT stored — it is recovered
    statically from the activation's trailing dim at apply time, so the
    packed dict contains only arrays (scan-stackable).
    """
    w = params["w"]
    wt = jnp.swapaxes(w, -1, -2)                      # (..., d_out, d_in)
    alpha = jnp.mean(jnp.abs(wt), axis=-1)            # per-output scale
    return {"w_packed": B.pack_bits(wt), "alpha": alpha}


def is_packed(params: dict) -> bool:
    return "w_packed" in params


def apply_linear(params: dict, x: jax.Array, quant: QuantConfig,
                 *, dtype=jnp.bfloat16) -> jax.Array:
    """y = x @ W under the quantization policy.  x: (..., d_in)."""
    mode = quant.mode
    if is_packed(params):
        return _apply_packed(params, x, quant, dtype)
    w = params["w"]
    if mode == QuantMode.FLOAT:
        return jnp.einsum("...d,df->...f", x.astype(dtype), w.astype(dtype))
    # latent-weight training paths (STE)
    wb = B.binarize_ste(w)                            # ±1 with STE bwd
    alpha = jax.lax.stop_gradient(jnp.mean(jnp.abs(w), axis=0))
    if mode == QuantMode.BINARY:
        xb = B.binarize_ste(x.astype(jnp.float32))
        y = jnp.einsum("...d,df->...f", xb, wb)
    else:                                             # BINARY_WEIGHT
        y = jnp.einsum("...d,df->...f", x.astype(jnp.float32), wb)
    return (y * alpha).astype(dtype)


def _apply_packed(params: dict, x: jax.Array, quant: QuantConfig,
                  dtype) -> jax.Array:
    k = x.shape[-1]                                   # logical d_in (static)
    alpha = params["alpha"]
    m = 1
    for s in x.shape[:-1]:
        m *= s
    strat = quant.strategy
    if strat == GemmStrategy.AUTO:
        strat = quant.resolve_strategy(m, alpha.shape[0], k)
    if quant.mode == QuantMode.BINARY:
        xb = B.sign_pm1(x.astype(jnp.float32))
        if strat == GemmStrategy.VPU_XNOR:
            x2 = xb.reshape(m, k)
            xp = B.pack_bits(x2)
            y = B.packed_matmul(xp, params["w_packed"], k).astype(jnp.float32)
            y = y.reshape(*x.shape[:-1], -1)
        else:
            y = B.binary_dot_unpacked_mxu(xb, params["w_packed"], k,
                                          dtype=jnp.float32)
    else:                                             # BINARY_WEIGHT
        # real activations: XNOR path does not apply; always unpack->MXU.
        y = B.binary_dot_unpacked_mxu(x, params["w_packed"], k, dtype=dtype)
        y = y.astype(jnp.float32)
    return (y * alpha).astype(dtype)


def maybe_pack_tree(params, quant: QuantConfig):
    """Recursively pack every Linear in a param tree for inference
    (weights pack ONCE at load — paper C2).  Leaves non-linear params
    untouched.  Embeddings / heads follow the QuantConfig knobs upstream.
    """
    if quant.mode == QuantMode.FLOAT:
        return params
    if isinstance(params, dict):
        if "w" in params and len(params) == 1 and \
                getattr(params["w"], "ndim", 0) >= 2:
            return pack_linear(params)
        return {k: maybe_pack_tree(v, quant) for k, v in params.items()}
    if isinstance(params, (list, tuple)):
        return type(params)(maybe_pack_tree(v, quant) for v in params)
    return params
