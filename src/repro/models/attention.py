"""Attention: GQA with chunked (flash-style, memory-bounded) softmax.

Implemented in pure jnp so the distributed dry-run's HLO is analyzable
(cost_analysis counts the FLOPs) and GSPMD can shard it.  The online-
softmax scan over KV chunks is the TPU-friendly formulation of
FlashAttention — no (Sq, Skv) materialization, VMEM-sized tiles.

Supports: GQA/MQA, causal + local (sliding-window) masks, attention
softcap (gemma-2), partial RoPE (chatglm), M-RoPE (qwen2-vl), QK-norm
(qwen3), cross-attention (whisper), and single-token decode against a
(ring-buffered, for local layers) KV cache.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import common as C
from repro.models import linear as LN
from repro.utils.flags import in_analysis_mode, xscan, xmap_seq

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_attention(key: jax.Array, cfg: ArchConfig, *,
                   cross: bool = False) -> dict:
    d, hq, hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": LN.init_linear(ks[0], d, hq * hd),
        "wk": LN.init_linear(ks[1], d, hkv * hd),
        "wv": LN.init_linear(ks[2], d, hkv * hd),
        "wo": LN.init_linear(ks[3], hq * hd, d),
    }
    if cfg.qk_norm:
        p["q_norm"] = C.init_rmsnorm(hd)
        p["k_norm"] = C.init_rmsnorm(hd)
    del cross
    return p


# ---------------------------------------------------------------------------
# projections + rope
# ---------------------------------------------------------------------------

def _project_qkv(params: dict, cfg: ArchConfig, x: jax.Array,
                 kv_src: jax.Array | None = None):
    dt = cfg.activation_dtype
    kv_src = x if kv_src is None else kv_src
    b, sq = x.shape[:2]
    skv = kv_src.shape[1]
    q = LN.apply_linear(params["wq"], x, cfg.quant, dtype=dt)
    k = LN.apply_linear(params["wk"], kv_src, cfg.quant, dtype=dt)
    v = LN.apply_linear(params["wv"], kv_src, cfg.quant, dtype=dt)
    q = q.reshape(b, sq, cfg.num_heads, cfg.head_dim)
    k = k.reshape(b, skv, cfg.num_kv_heads, cfg.head_dim)
    v = v.reshape(b, skv, cfg.num_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = C.apply_rmsnorm(params["q_norm"], q)
        k = C.apply_rmsnorm(params["k_norm"], k)
    return q, k, v


def _rope(cfg: ArchConfig, x: jax.Array, positions: jax.Array) -> jax.Array:
    if cfg.rope_style == "none":
        return x
    if cfg.rope_style == "mrope":
        pos3 = jnp.broadcast_to(positions[None], (3, *positions.shape))
        half = cfg.head_dim // 2
        t = half // 4
        rem = half - t
        sections = (t, rem // 2, rem - rem // 2)
        return C.apply_mrope(x, pos3, sections=sections, base=cfg.rope_base)
    frac = cfg.rope_fraction if cfg.rope_style == "partial" else 1.0
    return C.apply_rope(x, positions, fraction=frac, base=cfg.rope_base)


# ---------------------------------------------------------------------------
# chunked attention core (training / prefill)
# ---------------------------------------------------------------------------

def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool, window: int | None = None,
                      attn_softcap: float | None = None,
                      q_offset: int = 0,
                      q_chunk: int = 1024, kv_chunk: int = 1024
                      ) -> jax.Array:
    """Online-softmax attention.

    q: (B, Sq, Hq, D); k, v: (B, Skv, Hkv, D) with Hq % Hkv == 0.
    ``q_offset``: absolute position of q[0] relative to k[0] (prefill
    continuation).  ``window``: sliding-window size for local layers
    (positions with q_pos - k_pos >= window are masked).
    Returns (B, Sq, Hq, D) in q.dtype; accumulation in fp32.
    """
    b, sq, hq, d = q.shape
    _, skv, hkv, _ = k.shape
    g = hq // hkv
    scale = d ** -0.5

    if in_analysis_mode():
        # coarser tiles: identical FLOPs, far fewer unrolled HLO ops
        q_chunk, kv_chunk = 8192, 8192
    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, skv)
    nq = -(-sq // q_chunk)
    nkv = -(-skv // kv_chunk)
    sq_p, skv_p = nq * q_chunk, nkv * kv_chunk

    qp = jnp.pad(q, ((0, 0), (0, sq_p - sq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, skv_p - skv), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, skv_p - skv), (0, 0), (0, 0)))
    # (B, nq, qc, Hkv, G, D) grouped query layout
    qp = qp.reshape(b, nq, q_chunk, hkv, g, d)
    kp = kp.reshape(b, nkv, kv_chunk, hkv, d)
    vp = vp.reshape(b, nkv, kv_chunk, hkv, d)

    q_pos = (q_offset + jnp.arange(sq_p)).reshape(nq, q_chunk)
    k_pos = jnp.arange(skv_p).reshape(nkv, kv_chunk)
    k_valid = (jnp.arange(skv_p) < skv).reshape(nkv, kv_chunk)

    def q_block(args):
        qb, qpos = args                               # (B,qc,Hkv,G,D),(qc,)

        def kv_step(carry, inp):
            m, l, acc = carry
            kb, vb, kpos, kval = inp
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qb.astype(jnp.float32),
                           kb.astype(jnp.float32)) * scale
            if attn_softcap is not None:
                s = attn_softcap * jnp.tanh(s / attn_softcap)
            mask = kval[None, :]
            if causal:
                mask = mask & (qpos[:, None] >= kpos[None, :])
            if window is not None:
                mask = mask & (qpos[:, None] - kpos[None, :] < window)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p, vb.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, hkv, g, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, q_chunk, d), jnp.float32)
        (m, l, acc), _ = xscan(
            kv_step, (m0, l0, a0),
            (jnp.moveaxis(kp, 1, 0), jnp.moveaxis(vp, 1, 0), k_pos, k_valid))
        out = acc / jnp.maximum(l, 1e-30)[..., None]  # (B,Hkv,G,qc,D)
        return jnp.einsum("bhgqd->bqhgd", out)

    outs = xmap_seq(q_block, (jnp.moveaxis(qp, 1, 0), q_pos))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, sq_p, hq, d)[:, :sq]
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# full-sequence forward (training / prefill)
# ---------------------------------------------------------------------------

def attention_forward(params: dict, cfg: ArchConfig, x: jax.Array, *,
                      positions: jax.Array, kind: str = "global",
                      causal: bool = True,
                      kv_src: jax.Array | None = None,
                      return_kv: bool = False):
    """x: (B, S, D) -> (B, S, D).  kind: 'global' | 'local'.

    ``kv_src`` switches to cross-attention (no rope on cross, whisper
    convention keeps rope_style == 'none' anyway)."""
    q, k, v = _project_qkv(params, cfg, x, kv_src)
    is_cross = kv_src is not None
    if not is_cross:
        q = _rope(cfg, q, positions)
        k = _rope(cfg, k, positions)
    window = cfg.window_size if kind == "local" else None
    out = chunked_attention(q, k, v, causal=causal and not is_cross,
                            window=window, attn_softcap=cfg.attn_softcap)
    b, s = x.shape[:2]
    y = LN.apply_linear(params["wo"], out.reshape(b, s, -1), cfg.quant,
                        dtype=cfg.activation_dtype)
    if return_kv:
        return y, (k, v)
    return y


# ---------------------------------------------------------------------------
# decode (single token, KV cache)
# ---------------------------------------------------------------------------

def _kv_quantize(x: jax.Array):
    """(..., D) -> (int8 values, bf16 absmax-over-D scale).

    Beyond-paper: the paper packs the memory-bound operand (weights);
    at long context the KV cache becomes the memory-bound operand, so
    the same idea applies (per-(token, head) scale keeps decode logits
    within ~1e-2 of bf16 — tests/test_attention.py)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-6) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale[..., 0].astype(jnp.bfloat16)


def _kv_dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale.astype(jnp.float32)[..., None]


def init_attn_cache(cfg: ArchConfig, batch: int, max_len: int,
                    kind: str = "global", dtype=None) -> dict:
    dtype = dtype or cfg.activation_dtype
    size = min(max_len, cfg.window_size) if kind == "local" else max_len
    shape = (batch, size, cfg.num_kv_heads, cfg.head_dim)
    if cfg.kv_cache_dtype == "int8":
        return {"k": jnp.zeros(shape, jnp.int8),
                "v": jnp.zeros(shape, jnp.int8),
                "k_scale": jnp.zeros(shape[:-1], jnp.bfloat16),
                "v_scale": jnp.zeros(shape[:-1], jnp.bfloat16)}
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def attention_decode(params: dict, cfg: ArchConfig, x: jax.Array,
                     cache: dict, idx: jax.Array, *, kind: str = "global",
                     cross_kv: tuple | None = None):
    """One-token decode.  x: (B, 1, D); idx: scalar int32 — the absolute

    position being generated.  Local layers use a ring buffer of
    ``window_size`` slots (slot = pos % window); global layers index the
    full cache.  Returns (y, new_cache).

    ``cross_kv`` is not supported here: self-attention decode and
    cross-attention are separate modules, and silently ignoring the
    argument used to make whisper-style callers decode *without* their
    encoder context.  Raises ``NotImplementedError`` instead — use
    :func:`cross_attention_decode` for the encoder K/V read."""
    if cross_kv is not None:
        raise NotImplementedError(
            "attention_decode does not consume cross_kv; call "
            "cross_attention_decode with the precomputed encoder K/V "
            "(see models/encdec.py) instead of passing it here")
    b = x.shape[0]
    q, k, v = _project_qkv(params, cfg, x)            # (B,1,H*,D)
    pos = jnp.full((b, 1), idx, jnp.int32)
    q = _rope(cfg, q, pos)
    k = _rope(cfg, k, pos)

    size = cache["k"].shape[1]
    slot = idx % size if kind == "local" else idx
    int8_kv = cfg.kv_cache_dtype == "int8"
    new_cache = dict(cache)
    if int8_kv:
        kq, ks = _kv_quantize(k)
        vq, vs = _kv_quantize(v)
        new_cache["k"] = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], kq, slot, axis=1)
        new_cache["v"] = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], vq, slot, axis=1)
        new_cache["k_scale"] = jax.lax.dynamic_update_slice_in_dim(
            cache["k_scale"], ks, slot, axis=1)
        new_cache["v_scale"] = jax.lax.dynamic_update_slice_in_dim(
            cache["v_scale"], vs, slot, axis=1)
        ck = _kv_dequantize(new_cache["k"], new_cache["k_scale"])
        cv = _kv_dequantize(new_cache["v"], new_cache["v_scale"])
    else:
        new_cache["k"] = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), slot, axis=1)
        new_cache["v"] = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), slot, axis=1)
        ck, cv = new_cache["k"], new_cache["v"]

    j = jnp.arange(size)
    if kind == "local":
        # absolute position stored in slot j (ring): largest p <= idx with
        # p % size == j.  The validity window is bounded by the ACTUAL
        # ring size — init_attn_cache allocates min(max_len, window_size)
        # slots, so when max_len < window_size a mask built from
        # cfg.window_size would admit slots the ring never held.
        abs_pos = idx - ((idx - j) % size)
        window = min(cfg.window_size, size)
        valid = (abs_pos >= 0) & (abs_pos >= idx - window + 1)
    else:
        valid = j <= idx

    y = _decode_score(q, ck, cv, valid, cfg)
    out = LN.apply_linear(params["wo"], y.reshape(b, 1, -1), cfg.quant,
                          dtype=cfg.activation_dtype)
    return out, new_cache


def _decode_score(q, ck, cv, valid, cfg: ArchConfig):
    b, _, hq, d = q.shape
    hkv = cfg.num_kv_heads
    g = hq // hkv
    qg = q.reshape(b, hkv, g, d)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg.astype(jnp.float32),
                   ck.astype(jnp.float32)) * d ** -0.5
    if cfg.attn_softcap is not None:
        s = cfg.attn_softcap * jnp.tanh(s / cfg.attn_softcap)
    s = jnp.where(valid[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bkhd->bhgd", p, cv.astype(jnp.float32))
    return o.reshape(b, 1, hq, d).astype(cfg.activation_dtype)


def cross_attention_decode(params: dict, cfg: ArchConfig, x: jax.Array,
                           cross_k: jax.Array, cross_v: jax.Array):
    """Decoder cross-attention against precomputed encoder K/V."""
    b = x.shape[0]
    dt = cfg.activation_dtype
    q = LN.apply_linear(params["wq"], x, cfg.quant, dtype=dt)
    q = q.reshape(b, 1, cfg.num_heads, cfg.head_dim)
    valid = jnp.ones((cross_k.shape[1],), bool)
    y = _decode_score(q, cross_k, cross_v, valid, cfg)
    return LN.apply_linear(params["wo"], y.reshape(b, 1, -1), cfg.quant,
                           dtype=dt)
