"""Feed-forward blocks: gated (SwiGLU/GeGLU) and plain (GELU/squared-ReLU).

All projections route through the quant-aware Linear so the paper's binary
mode applies uniformly (DESIGN.md §3).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import linear as LN


def _act(name: str, x: jax.Array) -> jax.Array:
    if name == "gelu":
        return jax.nn.gelu(x)
    if name == "silu":
        return jax.nn.silu(x)
    if name == "relu2":                       # nemotron squared-ReLU
        r = jax.nn.relu(x)
        return r * r
    raise ValueError(name)


def is_gated(ffn_type: str) -> bool:
    return ffn_type in ("swiglu", "geglu")


def init_ffn(key: jax.Array, cfg: ArchConfig, d_ff: int | None = None
             ) -> dict:
    d = cfg.d_model
    f = d_ff if d_ff is not None else cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {"w_up": LN.init_linear(ks[0], d, f),
         "w_down": LN.init_linear(ks[1], f, d)}
    if is_gated(cfg.ffn_type):
        p["w_gate"] = LN.init_linear(ks[2], d, f)
    return p


def apply_ffn(params: dict, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    dt = cfg.activation_dtype
    up = LN.apply_linear(params["w_up"], x, cfg.quant, dtype=dt)
    t = cfg.ffn_type
    if t == "swiglu":
        gate = LN.apply_linear(params["w_gate"], x, cfg.quant, dtype=dt)
        h = jax.nn.silu(gate.astype(jnp.float32)).astype(dt) * up
    elif t == "geglu":
        gate = LN.apply_linear(params["w_gate"], x, cfg.quant, dtype=dt)
        h = jax.nn.gelu(gate.astype(jnp.float32)).astype(dt) * up
    else:
        h = _act(t, up.astype(jnp.float32)).astype(dt)
    return LN.apply_linear(params["w_down"], h, cfg.quant, dtype=dt)
