"""Encoder-decoder backbone (whisper-base).

The conv/audio frontend is a STUB per the assignment: ``input_specs()``
provides precomputed frame embeddings (B, S_enc, d_model).  This module
implements the transformer backbone: bidirectional encoder, causal
decoder with cross-attention, sinusoidal encoder positions + learned
decoder positions (whisper conventions).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as A
from repro.models import common as C
from repro.models import ffn as F
from repro.models import linear as LN
from repro.utils import tree as T
from repro.utils.flags import xscan


def init_encdec_stack(key: jax.Array, cfg: ArchConfig) -> dict:
    ks = jax.random.split(key, 4)

    def enc_layer(k):
        k1, k2 = jax.random.split(k)
        return {"ln1": C.init_norm(cfg.norm_type, cfg.d_model),
                "attn": A.init_attention(k1, cfg),
                "ln2": C.init_norm(cfg.norm_type, cfg.d_model),
                "mlp": F.init_ffn(k2, cfg)}

    def dec_layer(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {"ln1": C.init_norm(cfg.norm_type, cfg.d_model),
                "attn": A.init_attention(k1, cfg),
                "ln_x": C.init_norm(cfg.norm_type, cfg.d_model),
                "xattn": A.init_attention(k2, cfg, cross=True),
                "ln2": C.init_norm(cfg.norm_type, cfg.d_model),
                "mlp": F.init_ffn(k3, cfg)}

    enc = T.tree_stack([enc_layer(jax.random.fold_in(ks[0], i))
                        for i in range(cfg.encoder_layers)])
    dec = T.tree_stack([dec_layer(jax.random.fold_in(ks[1], i))
                        for i in range(cfg.num_layers)])
    return {
        "enc": enc, "dec": dec,
        "enc_ln_out": C.init_norm(cfg.norm_type, cfg.d_model),
        "dec_pos": jax.random.normal(ks[2], (cfg.max_position, cfg.d_model)
                                     ) * 0.01,
    }


def encode(params: dict, cfg: ArchConfig, frames: jax.Array,
           *, remat: bool = True) -> jax.Array:
    """frames: (B, S_enc, D) precomputed frame embeddings (stub frontend)."""
    b, s, _ = frames.shape
    pos = C.sinusoidal_positions(s, cfg.d_model).astype(frames.dtype)
    x = frames + pos[None]
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    def body(h, lp):
        a = A.attention_forward(lp["attn"], cfg,
                                C.apply_norm(cfg.norm_type, lp["ln1"], h),
                                positions=positions, causal=False)
        h = h + a
        y = F.apply_ffn(lp["mlp"], cfg,
                        C.apply_norm(cfg.norm_type, lp["ln2"], h))
        return h + y, None

    if remat:
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = xscan(body, x, params["enc"])
    return C.apply_norm(cfg.norm_type, params["enc_ln_out"], x)


def decode_train(params: dict, cfg: ArchConfig, x: jax.Array,
                 enc_out: jax.Array, positions: jax.Array,
                 *, remat: bool = True) -> jax.Array:
    """Teacher-forced decoder pass.  x: (B, S_dec, D) token embeddings."""
    pos_emb = params["dec_pos"][:x.shape[1]].astype(x.dtype)
    x = x + pos_emb[None]

    def body(h, lp):
        a = A.attention_forward(lp["attn"], cfg,
                                C.apply_norm(cfg.norm_type, lp["ln1"], h),
                                positions=positions)
        h = h + a
        xa = A.attention_forward(lp["xattn"], cfg,
                                 C.apply_norm(cfg.norm_type, lp["ln_x"], h),
                                 positions=positions, kv_src=enc_out)
        h = h + xa
        y = F.apply_ffn(lp["mlp"], cfg,
                        C.apply_norm(cfg.norm_type, lp["ln2"], h))
        return h + y, None

    if remat:
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = xscan(body, x, params["dec"])
    return x


# ---------------------------------------------------------------------------
# decode (serving): self-attn cache + precomputed cross K/V
# ---------------------------------------------------------------------------

def init_encdec_cache(params: dict, cfg: ArchConfig, batch: int,
                      max_len: int, enc_len: int) -> dict:
    self_c = T.tree_stack([A.init_attn_cache(cfg, batch, max_len)
                           for _ in range(cfg.num_layers)])
    dt = cfg.activation_dtype
    cross = {
        "k": jnp.zeros((cfg.num_layers, batch, enc_len, cfg.num_kv_heads,
                        cfg.head_dim), dt),
        "v": jnp.zeros((cfg.num_layers, batch, enc_len, cfg.num_kv_heads,
                        cfg.head_dim), dt),
    }
    return {"self": self_c, "cross": cross}


def precompute_cross_kv(params: dict, cfg: ArchConfig, enc_out: jax.Array
                        ) -> dict:
    """Cross-attention K/V from encoder output, per decoder layer."""
    b, s, _ = enc_out.shape
    dt = cfg.activation_dtype

    def one(lp):
        k = LN.apply_linear(lp["xattn"]["wk"], enc_out, cfg.quant, dtype=dt)
        v = LN.apply_linear(lp["xattn"]["wv"], enc_out, cfg.quant, dtype=dt)
        return (k.reshape(b, s, cfg.num_kv_heads, cfg.head_dim),
                v.reshape(b, s, cfg.num_kv_heads, cfg.head_dim))

    ks, vs = jax.lax.map(one, params["dec"])
    return {"k": ks, "v": vs}


def decode_step(params: dict, cfg: ArchConfig, x: jax.Array, cache: dict,
                idx: jax.Array):
    """One-token decoder step.  x: (B, 1, D) embedded token."""
    pos_emb = jax.lax.dynamic_index_in_dim(params["dec_pos"], idx, 0,
                                           keepdims=True)
    x = x + pos_emb[None].astype(x.dtype)

    def body(h, inp):
        lp, self_c, ck, cv = inp
        a, new_c = A.attention_decode(
            lp["attn"], cfg, C.apply_norm(cfg.norm_type, lp["ln1"], h),
            self_c, idx)
        h = h + a
        xa = A.cross_attention_decode(
            lp["xattn"], cfg, C.apply_norm(cfg.norm_type, lp["ln_x"], h),
            ck, cv)
        h = h + xa
        y = F.apply_ffn(lp["mlp"], cfg,
                        C.apply_norm(cfg.norm_type, lp["ln2"], h))
        return h + y, new_c

    x, new_self = xscan(
        body, x, (params["dec"], cache["self"], cache["cross"]["k"],
                  cache["cross"]["v"]))
    return x, {"self": new_self, "cross": cache["cross"]}
