"""Decoder-stack assembly: pattern-segmented, scan-stacked layers.

Depth is folded into ``lax.scan`` over *layer groups* so HLO size and
compile time are O(1) in depth (MaxText-style).  A group is one pass
through ``cfg.attention_pattern`` (e.g. gemma-2's ("local", "global"),
recurrentgemma's ("rec", "rec", "attn")); leftover layers that do not
fill a full period form a trailing segment.

Layer kinds: 'global' | 'local' (attention), 'rec' (RG-LRU), 'ssm'
(Mamba-2).  Every kind except 'ssm' is followed by an FFN/MoE sub-block
(Mamba-2 blocks are the whole layer, d_ff == 0).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as A
from repro.models import common as C
from repro.models import ffn as F
from repro.models import moe as M
from repro.models import rglru as R
from repro.models import ssm as S
from repro.utils import tree as T
from repro.utils.flags import xscan


def segments_of(cfg: ArchConfig) -> list[tuple[tuple[str, ...], int]]:
    """[(pattern, n_groups), ...] covering exactly num_layers layers."""
    period = cfg.pattern_period
    n_full, leftover = divmod(cfg.num_layers, period)
    segs: list[tuple[tuple[str, ...], int]] = []
    if n_full:
        segs.append((cfg.attention_pattern, n_full))
    if leftover:
        segs.append((cfg.attention_pattern[:leftover], 1))
    return segs


# ---------------------------------------------------------------------------
# per-layer init / apply
# ---------------------------------------------------------------------------

def _has_ffn(cfg: ArchConfig, kind: str) -> bool:
    return kind != "ssm" and (cfg.d_ff > 0 or cfg.moe is not None)


def init_layer(key: jax.Array, cfg: ArchConfig, kind: str) -> dict:
    ks = jax.random.split(key, 4)
    p: dict = {"ln1": C.init_norm(cfg.norm_type, cfg.d_model)}
    if kind in ("global", "local"):
        p["attn"] = A.init_attention(ks[0], cfg)
    elif kind == "rec":
        p["rec"] = R.init_rglru_block(ks[0], cfg)
    elif kind == "ssm":
        p["ssm"] = S.init_mamba2(ks[0], cfg)
    else:
        raise ValueError(kind)
    if _has_ffn(cfg, kind):
        p["ln2"] = C.init_norm(cfg.norm_type, cfg.d_model)
        p["mlp"] = (M.init_moe(ks[1], cfg) if cfg.moe is not None
                    else F.init_ffn(ks[1], cfg))
    return p


def apply_layer(params: dict, cfg: ArchConfig, kind: str, x: jax.Array,
                positions: jax.Array) -> jax.Array:
    h = C.apply_norm(cfg.norm_type, params["ln1"], x)
    if kind in ("global", "local"):
        mix = A.attention_forward(params["attn"], cfg, h,
                                  positions=positions, kind=kind)
    elif kind == "rec":
        mix = R.rglru_block_forward(params["rec"], cfg, h)
    else:
        mix = S.mamba2_forward(params["ssm"], cfg, h)
    x = x + mix
    if "mlp" in params:
        h2 = C.apply_norm(cfg.norm_type, params["ln2"], x)
        y = (M.apply_moe(params["mlp"], cfg, h2) if cfg.moe is not None
             else F.apply_ffn(params["mlp"], cfg, h2))
        x = x + y
    return x


# ---------------------------------------------------------------------------
# stack init / forward
# ---------------------------------------------------------------------------

def init_stack(key: jax.Array, cfg: ArchConfig) -> list:
    """Returns a list of segments; each segment is a tuple (one entry per
    pattern position) of pytrees stacked over the segment's groups."""
    stack = []
    base = 0
    for pattern, n in segments_of(cfg):
        seg = []
        for pos, kind in enumerate(pattern):
            layers = [
                init_layer(jax.random.fold_in(key,
                                              base + g * len(pattern) + pos),
                           cfg, kind)
                for g in range(n)
            ]
            seg.append(T.tree_stack(layers))
        base += n * len(pattern)
        stack.append(tuple(seg))
    return stack


def stack_forward(stack: list, cfg: ArchConfig, x: jax.Array,
                  positions: jax.Array, *, remat: bool = True) -> jax.Array:
    for (pattern, n), seg_params in zip(segments_of(cfg), stack):

        def group_body(h, group_params, _pattern=pattern):
            for pos, kind in enumerate(_pattern):
                h = apply_layer(group_params[pos], cfg, kind, h, positions)
            return h, None

        body = group_body
        if remat:
            body = jax.checkpoint(
                group_body,
                policy=jax.checkpoint_policies.nothing_saveable)
        x, _ = xscan(body, x, seg_params)
    return x


# ---------------------------------------------------------------------------
# caches (decode)
# ---------------------------------------------------------------------------

def init_cache(cfg: ArchConfig, batch: int, max_len: int) -> list:
    """Cache pytree mirroring the stack segmentation."""
    cache = []
    for pattern, n in segments_of(cfg):
        seg = []
        for kind in pattern:
            if kind in ("global", "local"):
                one = A.init_attn_cache(cfg, batch, max_len, kind)
            elif kind == "rec":
                one = R.init_rglru_cache(cfg, batch)
            else:
                one = S.init_mamba2_cache(cfg, batch)
            seg.append(T.tree_stack([one] * n))
        cache.append(tuple(seg))
    return cache


def apply_layer_decode(params: dict, cfg: ArchConfig, kind: str,
                       x: jax.Array, cache: dict, idx: jax.Array):
    h = C.apply_norm(cfg.norm_type, params["ln1"], x)
    if kind in ("global", "local"):
        mix, new_cache = A.attention_decode(params["attn"], cfg, h, cache,
                                            idx, kind=kind)
    elif kind == "rec":
        mix, new_cache = R.rglru_block_decode(params["rec"], cfg, h, cache)
    else:
        mix, new_cache = S.mamba2_decode(params["ssm"], cfg, h, cache)
    x = x + mix
    if "mlp" in params:
        h2 = C.apply_norm(cfg.norm_type, params["ln2"], x)
        y = (M.apply_moe(params["mlp"], cfg, h2) if cfg.moe is not None
             else F.apply_ffn(params["mlp"], cfg, h2))
        x = x + y
    return x, new_cache


def stack_decode(stack: list, cache: list, cfg: ArchConfig, x: jax.Array,
                 idx: jax.Array):
    """One-token decode through the whole stack.  x: (B, 1, D)."""
    new_cache_all = []
    for (pattern, n), seg_params, seg_cache in zip(segments_of(cfg), stack,
                                                   cache):

        def group_body(h, inp, _pattern=pattern):
            group_params, group_cache = inp
            new_caches = []
            for pos, kind in enumerate(_pattern):
                h, nc = apply_layer_decode(group_params[pos], cfg, kind, h,
                                           group_cache[pos], idx)
                new_caches.append(nc)
            return h, tuple(new_caches)

        x, new_seg_cache = xscan(group_body, x,
                                 (seg_params, seg_cache))
        new_cache_all.append(new_seg_cache)
    return x, new_cache_all


# ---------------------------------------------------------------------------
# prefill (full sequence -> caches + last hidden)
# ---------------------------------------------------------------------------

def _ring_from_full(k: jax.Array, window: int) -> jax.Array:
    """Convert full-sequence K/V (B, S, ...) to the decode ring layout.

    Works for value tensors (B, S, H, D) and scale tensors (B, S, H)."""
    bsz, s = k.shape[:2]
    w = min(window, s)
    k_last = k[:, s - w:]
    slots = (s - w + jnp.arange(w)) % window
    ring = jnp.zeros((bsz, window, *k.shape[2:]), k.dtype)
    return ring.at[:, slots].set(k_last)


def apply_layer_prefill(params: dict, cfg: ArchConfig, kind: str,
                        x: jax.Array, positions: jax.Array, max_len: int):
    h = C.apply_norm(cfg.norm_type, params["ln1"], x)
    if kind in ("global", "local"):
        mix, (k, v) = A.attention_forward(params["attn"], cfg, h,
                                          positions=positions, kind=kind,
                                          return_kv=True)
        if cfg.kv_cache_dtype == "int8":
            kq, ks = A._kv_quantize(k)
            vq, vs = A._kv_quantize(v)
            parts = {"k": kq, "v": vq, "k_scale": ks, "v_scale": vs}
        else:
            parts = {"k": k, "v": v}
        if kind == "local":
            size = min(max_len, cfg.window_size)
            new_cache = {n: _ring_from_full(t, size)
                         for n, t in parts.items()}
        else:
            new_cache = {}
            for n, t in parts.items():
                pad = [(0, 0), (0, max_len - t.shape[1])] \
                    + [(0, 0)] * (t.ndim - 2)
                new_cache[n] = jnp.pad(t, pad)
    elif kind == "rec":
        mix, new_cache = R.rglru_block_forward(params["rec"], cfg, h,
                                               return_cache=True)
    else:
        mix, new_cache = S.mamba2_forward(params["ssm"], cfg, h,
                                          return_cache=True)
    x = x + mix
    if "mlp" in params:
        h2 = C.apply_norm(cfg.norm_type, params["ln2"], x)
        y = (M.apply_moe(params["mlp"], cfg, h2) if cfg.moe is not None
             else F.apply_ffn(params["mlp"], cfg, h2))
        x = x + y
    return x, new_cache


def stack_prefill(stack: list, cfg: ArchConfig, x: jax.Array,
                  positions: jax.Array, max_len: int):
    cache_all = []
    for (pattern, n), seg_params in zip(segments_of(cfg), stack):

        def group_body(h, group_params, _pattern=pattern):
            caches = []
            for pos, kind in enumerate(_pattern):
                h, c = apply_layer_prefill(group_params[pos], cfg, kind, h,
                                           positions, max_len)
                caches.append(c)
            return h, tuple(caches)

        x, seg_cache = xscan(group_body, x, seg_params)
        cache_all.append(seg_cache)
    return x, cache_all
