"""Decoder-stack assembly: pattern-segmented, scan-stacked layers.

Depth is folded into ``lax.scan`` over *layer groups* so HLO size and
compile time are O(1) in depth (MaxText-style).  A group is one pass
through ``cfg.attention_pattern`` (e.g. gemma-2's ("local", "global"),
recurrentgemma's ("rec", "rec", "attn")); leftover layers that do not
fill a full period form a trailing segment.

Layer kinds: 'global' | 'local' (attention), 'rec' (RG-LRU), 'ssm'
(Mamba-2).  Every kind except 'ssm' is followed by an FFN/MoE sub-block
(Mamba-2 blocks are the whole layer, d_ff == 0).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as A
from repro.models import common as C
from repro.models import ffn as F
from repro.models import moe as M
from repro.models import rglru as R
from repro.models import ssm as S
from repro.utils import tree as T
from repro.utils.flags import xscan


def segments_of(cfg: ArchConfig) -> list[tuple[tuple[str, ...], int]]:
    """[(pattern, n_groups), ...] covering exactly num_layers layers."""
    period = cfg.pattern_period
    n_full, leftover = divmod(cfg.num_layers, period)
    segs: list[tuple[tuple[str, ...], int]] = []
    if n_full:
        segs.append((cfg.attention_pattern, n_full))
    if leftover:
        segs.append((cfg.attention_pattern[:leftover], 1))
    return segs


# ---------------------------------------------------------------------------
# per-layer init / apply
# ---------------------------------------------------------------------------

def _has_ffn(cfg: ArchConfig, kind: str) -> bool:
    return kind != "ssm" and (cfg.d_ff > 0 or cfg.moe is not None)


def init_layer(key: jax.Array, cfg: ArchConfig, kind: str) -> dict:
    ks = jax.random.split(key, 4)
    p: dict = {"ln1": C.init_norm(cfg.norm_type, cfg.d_model)}
    if kind in ("global", "local"):
        p["attn"] = A.init_attention(ks[0], cfg)
    elif kind == "rec":
        p["rec"] = R.init_rglru_block(ks[0], cfg)
    elif kind == "ssm":
        p["ssm"] = S.init_mamba2(ks[0], cfg)
    else:
        raise ValueError(kind)
    if _has_ffn(cfg, kind):
        p["ln2"] = C.init_norm(cfg.norm_type, cfg.d_model)
        p["mlp"] = (M.init_moe(ks[1], cfg) if cfg.moe is not None
                    else F.init_ffn(ks[1], cfg))
    return p


def apply_layer(params: dict, cfg: ArchConfig, kind: str, x: jax.Array,
                positions: jax.Array) -> jax.Array:
    h = C.apply_norm(cfg.norm_type, params["ln1"], x)
    if kind in ("global", "local"):
        mix = A.attention_forward(params["attn"], cfg, h,
                                  positions=positions, kind=kind)
    elif kind == "rec":
        mix = R.rglru_block_forward(params["rec"], cfg, h)
    else:
        mix = S.mamba2_forward(params["ssm"], cfg, h)
    x = x + mix
    if "mlp" in params:
        h2 = C.apply_norm(cfg.norm_type, params["ln2"], x)
        y = (M.apply_moe(params["mlp"], cfg, h2) if cfg.moe is not None
             else F.apply_ffn(params["mlp"], cfg, h2))
        x = x + y
    return x


# ---------------------------------------------------------------------------
# stack init / forward
# ---------------------------------------------------------------------------

def init_stack(key: jax.Array, cfg: ArchConfig) -> list:
    """Returns a list of segments; each segment is a tuple (one entry per
    pattern position) of pytrees stacked over the segment's groups."""
    stack = []
    base = 0
    for pattern, n in segments_of(cfg):
        seg = []
        for pos, kind in enumerate(pattern):
            layers = [
                init_layer(jax.random.fold_in(key,
                                              base + g * len(pattern) + pos),
                           cfg, kind)
                for g in range(n)
            ]
            seg.append(T.tree_stack(layers))
        base += n * len(pattern)
        stack.append(tuple(seg))
    return stack


def stack_forward(stack: list, cfg: ArchConfig, x: jax.Array,
                  positions: jax.Array, *, remat: bool = True) -> jax.Array:
    for (pattern, n), seg_params in zip(segments_of(cfg), stack):

        def group_body(h, group_params, _pattern=pattern):
            for pos, kind in enumerate(_pattern):
                h = apply_layer(group_params[pos], cfg, kind, h, positions)
            return h, None

        body = group_body
        if remat:
            body = jax.checkpoint(
                group_body,
                policy=jax.checkpoint_policies.nothing_saveable)
        x, _ = xscan(body, x, seg_params)
    return x


# ---------------------------------------------------------------------------
# caches (decode)
# ---------------------------------------------------------------------------

def init_cache(cfg: ArchConfig, batch: int, max_len: int) -> list:
    """Cache pytree mirroring the stack segmentation."""
    cache = []
    for pattern, n in segments_of(cfg):
        seg = []
        for kind in pattern:
            if kind in ("global", "local"):
                one = A.init_attn_cache(cfg, batch, max_len, kind)
            elif kind == "rec":
                one = R.init_rglru_cache(cfg, batch)
            else:
                one = S.init_mamba2_cache(cfg, batch)
            seg.append(T.tree_stack([one] * n))
        cache.append(tuple(seg))
    return cache


def apply_layer_decode(params: dict, cfg: ArchConfig, kind: str,
                       x: jax.Array, cache: dict, idx: jax.Array):
    h = C.apply_norm(cfg.norm_type, params["ln1"], x)
    if kind in ("global", "local"):
        mix, new_cache = A.attention_decode(params["attn"], cfg, h, cache,
                                            idx, kind=kind)
    elif kind == "rec":
        mix, new_cache = R.rglru_block_decode(params["rec"], cfg, h, cache)
    else:
        mix, new_cache = S.mamba2_decode(params["ssm"], cfg, h, cache)
    x = x + mix
    if "mlp" in params:
        h2 = C.apply_norm(cfg.norm_type, params["ln2"], x)
        y = (M.apply_moe(params["mlp"], cfg, h2) if cfg.moe is not None
             else F.apply_ffn(params["mlp"], cfg, h2))
        x = x + y
    return x, new_cache


def stack_decode(stack: list, cache: list, cfg: ArchConfig, x: jax.Array,
                 idx: jax.Array):
    """One-token decode through the whole stack.  x: (B, 1, D)."""
    new_cache_all = []
    for (pattern, n), seg_params, seg_cache in zip(segments_of(cfg), stack,
                                                   cache):

        def group_body(h, inp, _pattern=pattern):
            group_params, group_cache = inp
            new_caches = []
            for pos, kind in enumerate(_pattern):
                h, nc = apply_layer_decode(group_params[pos], cfg, kind, h,
                                           group_cache[pos], idx)
                new_caches.append(nc)
            return h, tuple(new_caches)

        x, new_seg_cache = xscan(group_body, x,
                                 (seg_params, seg_cache))
        new_cache_all.append(new_seg_cache)
    return x, new_cache_all


# ---------------------------------------------------------------------------
# prefill (full sequence -> caches + last hidden)
# ---------------------------------------------------------------------------

def _ring_from_full(k: jax.Array, window: int) -> jax.Array:
    """Convert full-sequence K/V (B, S, ...) to the decode ring layout.

    Works for value tensors (B, S, H, D) and scale tensors (B, S, H)."""
    bsz, s = k.shape[:2]
    w = min(window, s)
    k_last = k[:, s - w:]
    slots = (s - w + jnp.arange(w)) % window
    ring = jnp.zeros((bsz, window, *k.shape[2:]), k.dtype)
    return ring.at[:, slots].set(k_last)


def apply_layer_prefill(params: dict, cfg: ArchConfig, kind: str,
                        x: jax.Array, positions: jax.Array, max_len: int):
    h = C.apply_norm(cfg.norm_type, params["ln1"], x)
    if kind in ("global", "local"):
        mix, (k, v) = A.attention_forward(params["attn"], cfg, h,
                                          positions=positions, kind=kind,
                                          return_kv=True)
        if cfg.kv_cache_dtype == "int8":
            kq, ks = A._kv_quantize(k)
            vq, vs = A._kv_quantize(v)
            parts = {"k": kq, "v": vq, "k_scale": ks, "v_scale": vs}
        else:
            parts = {"k": k, "v": v}
        if kind == "local":
            size = min(max_len, cfg.window_size)
            new_cache = {n: _ring_from_full(t, size)
                         for n, t in parts.items()}
        else:
            new_cache = {}
            for n, t in parts.items():
                pad = [(0, 0), (0, max_len - t.shape[1])] \
                    + [(0, 0)] * (t.ndim - 2)
                new_cache[n] = jnp.pad(t, pad)
    elif kind == "rec":
        mix, new_cache = R.rglru_block_forward(params["rec"], cfg, h,
                                               return_cache=True)
    else:
        mix, new_cache = S.mamba2_forward(params["ssm"], cfg, h,
                                          return_cache=True)
    x = x + mix
    if "mlp" in params:
        h2 = C.apply_norm(cfg.norm_type, params["ln2"], x)
        y = (M.apply_moe(params["mlp"], cfg, h2) if cfg.moe is not None
             else F.apply_ffn(params["mlp"], cfg, h2))
        x = x + y
    return x, new_cache


def stack_prefill(stack: list, cfg: ArchConfig, x: jax.Array,
                  positions: jax.Array, max_len: int):
    cache_all = []
    for (pattern, n), seg_params in zip(segments_of(cfg), stack):

        def group_body(h, group_params, _pattern=pattern):
            caches = []
            for pos, kind in enumerate(_pattern):
                h, c = apply_layer_prefill(group_params[pos], cfg, kind, h,
                                           positions, max_len)
                caches.append(c)
            return h, tuple(caches)

        x, seg_cache = xscan(group_body, x, seg_params)
        cache_all.append(seg_cache)
    return x, cache_all


# ---------------------------------------------------------------------------
# packed binary-LM forward (the XNOR-popcount serving workload)
# ---------------------------------------------------------------------------
#
# The Espresso treatment applied to the decoder stack: every projection
# (Q/K/V/O, FFN up/down, LM head) is a sign-binarized XNOR-popcount GEMM
# over 32-per-word packed operands, the FFN up-projection keeps the fused
# BN-sign-repack epilogue (its int32 activation never leaves the kernel),
# and attention runs through the flash-style blocked binary kernel
# (``kernels.ops.binary_attention``) — no (Sq, Skv) score matrix in HBM.
# The residual stream and the embedding table stay float (the "frontend
# stays fixed-precision" convention, mirroring the BCNN bit-plane first
# layer); norms are dropped because every projection input is immediately
# sign-binarized, which is scale-invariant.
#
# Layer kinds map as: 'global' -> causal attention, 'local' -> causal
# sliding-window attention (cfg.window_size); 'rec'/'ssm' layers are
# *served* as sliding-window attention too — the binary analogue of their
# bounded-state recurrence — so every registry config has a packed
# serving form (documented in docs/architecture.md).

from repro.core import binary_layers as L          # noqa: E402
from repro.kernels import ops as kops              # noqa: E402


def _lm_d_ff(cfg: ArchConfig) -> int:
    if cfg.d_ff > 0:
        return cfg.d_ff
    if cfg.moe is not None:
        return cfg.moe.d_ff_expert
    return cfg.d_model


def init_binary_lm(key: jax.Array, cfg: ArchConfig) -> dict:
    """Float weights for :func:`pack_transformer` (one matrix per
    projection, (out, in) layout like every packed GEMM operand)."""
    d, hq, hkv, hd = (cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                      cfg.head_dim)
    f = _lm_d_ff(cfg)
    ks = iter(jax.random.split(key, 2 + 6 * cfg.num_layers))

    def mat(k, n, m):
        return jax.random.normal(k, (n, m), jnp.float32)

    blocks = []
    for _ in range(cfg.num_layers):
        blocks.append({
            "wq": mat(next(ks), hq * hd, d),
            "wk": mat(next(ks), hkv * hd, d),
            "wv": mat(next(ks), hkv * hd, d),
            "wo": mat(next(ks), d, hq * hd),
            "w1": mat(next(ks), f, d),
            "bn1": L.init_batchnorm(f),
            "w2": mat(next(ks), d, f),
        })
    return {"embed": jax.random.normal(next(ks),
                                       (cfg.vocab_size, d), jnp.float32),
            "head": mat(next(ks), cfg.vocab_size, d),
            "blocks": blocks}


def pack_transformer(params: dict, cfg: ArchConfig, *,
                     max_len: int = 16) -> dict:
    """One-time weight packing for the binary-LM serving forward.

    Returns the ``packed_kind == 'transformer'`` tree: per-layer packed
    projections (uint32 words, zero-bit tails), the folded BN-sign
    threshold for the fused FFN up-projection, the float embedding
    table, and a ``meta`` dict of the static shapes/mask knobs the
    forward needs (``seq_len`` fixes the serving example shape).
    """
    d, hq, hkv, hd = (cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                      cfg.head_dim)
    f = _lm_d_ff(cfg)
    kinds = tuple(cfg.layer_kind(i) for i in range(cfg.num_layers))
    blocks = []
    for lp in params["blocks"]:
        blocks.append({
            "wq": L.pack_binary_dense({"w": lp["wq"]}),
            "wk": L.pack_binary_dense({"w": lp["wk"]}),
            "wv": L.pack_binary_dense({"w": lp["wv"]}),
            "wo": L.pack_binary_dense({"w": lp["wo"]}),
            "w1": L.pack_binary_dense({"w": lp["w1"]}),
            "fold1": L.fold_bn_sign(lp["bn1"]),
            "w2": L.pack_binary_dense({"w": lp["w2"]}),
        })
    return {"blocks": blocks,
            "embed": params["embed"].astype(jnp.float32),
            "head": L.pack_binary_dense({"w": params["head"]}),
            "meta": {"name": cfg.name, "d_model": d, "num_heads": hq,
                     "num_kv_heads": hkv, "head_dim": hd, "d_ff": f,
                     "vocab_size": cfg.vocab_size, "seq_len": max_len,
                     "window_size": cfg.window_size,
                     "attn_softcap": cfg.attn_softcap, "kinds": kinds}}


def transformer_forward_packed(packed: dict, tokens: jax.Array, *,
                               backend: str = "auto",
                               dense_stack: str = "auto") -> jax.Array:
    """Packed binary-LM forward: ``tokens`` (B, S) integer ids (uint8
    from the serving pool is fine) -> last-token logits (B, vocab)
    float32.

    Every projection routes through the dense megakernel dispatchers
    (``binary_matmul_packed`` / ``binary_matmul_bn_sign_packed`` — the
    batch takes the GEMV or GEMM grid per ``kernels.ops.dispatch_batch``)
    and attention through ``binary_attention``; on the pallas backend
    that is the full XNOR-popcount serving path.  ``dense_stack`` is
    accepted for signature parity with the bcnn/bmlp forwards (the
    per-layer FFN is a single fused stage, so there is no stack to make
    resident); it validates like everywhere else.
    """
    if dense_stack not in ("auto", "resident", "layered"):
        raise ValueError(f"unknown dense_stack {dense_stack!r}")
    meta = packed["meta"]
    d, hq, hkv, hd, f = (meta["d_model"], meta["num_heads"],
                         meta["num_kv_heads"], meta["head_dim"],
                         meta["d_ff"])
    b, s = tokens.shape
    x = packed["embed"][tokens.astype(jnp.int32)]        # (B, S, D) f32

    for blk, kind in zip(packed["blocks"], meta["kinds"]):
        window = None if kind == "global" else meta["window_size"]
        xp = kops.bitpack(x.reshape(b * s, d), backend=backend)
        q = kops.binary_matmul_packed(xp, blk["wq"]["w_packed"],
                                      k_true=d, backend=backend)
        k = kops.binary_matmul_packed(xp, blk["wk"]["w_packed"],
                                      k_true=d, backend=backend)
        v = kops.binary_matmul_packed(xp, blk["wv"]["w_packed"],
                                      k_true=d, backend=backend)
        attn = kops.binary_attention(
            q.reshape(b, s, hq, hd).astype(jnp.float32),
            k.reshape(b, s, hkv, hd).astype(jnp.float32),
            v.reshape(b, s, hkv, hd).astype(jnp.float32) * (1.0 / d),
            causal=True, window=window, attn_softcap=meta["attn_softcap"],
            backend=backend)
        ap = kops.bitpack(attn.reshape(b * s, hq * hd), backend=backend)
        o = kops.binary_matmul_packed(ap, blk["wo"]["w_packed"],
                                      k_true=hq * hd, backend=backend)
        x = x + o.reshape(b, s, d).astype(jnp.float32) * (1.0 / (hq * hd))
        # FFN: fused up-projection (GEMM + folded-BN sign + re-bitpack —
        # the int32 (B*S, d_ff) activation never leaves the kernel),
        # then the packed down-projection on the packed activation.
        hp = kops.bitpack(x.reshape(b * s, d), backend=backend)
        h1 = kops.binary_matmul_bn_sign_packed(
            hp, blk["w1"]["w_packed"], blk["fold1"]["tau"],
            blk["fold1"]["flip"], k_true=d, backend=backend)
        y = kops.binary_matmul_packed(h1, blk["w2"]["w_packed"],
                                      k_true=f, backend=backend)
        x = x + y.reshape(b, s, d).astype(jnp.float32) * (1.0 / f)

    lp = kops.bitpack(x[:, -1], backend=backend)         # (B, Dw)
    logits = kops.binary_matmul_packed(lp, packed["head"]["w_packed"],
                                       k_true=d, backend=backend)
    return logits.astype(jnp.float32)
