"""Top-level model API: init / forward / loss / prefill / decode.

Single entry point used by the trainer, the server, the dry-run, and the
smoke tests.  Handles all 10 assigned families:

* decoder-only LMs (dense / moe / ssm / hybrid / vlm-backbone) through
  ``transformer.py``;
* encoder-decoder (whisper) through ``encdec.py``;
* stub frontends: if ``batch["embeds"]`` is present it bypasses the token
  embedding (precomputed patch/frame embeddings, per the assignment).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import common as C
from repro.models import encdec as ED
from repro.models import linear as LN
from repro.models import transformer as TF
from repro.utils import tree as T
from repro.utils.flags import xscan

LOSS_CHUNK = 512


def init_model(key: jax.Array, cfg: ArchConfig) -> dict:
    ks = jax.random.split(key, 4)
    p: dict = {
        "embed": C.init_embedding(ks[0], cfg.vocab_size, cfg.d_model),
        "ln_out": C.init_norm(cfg.norm_type, cfg.d_model),
    }
    if cfg.encoder_layers:
        p["encdec"] = ED.init_encdec_stack(ks[1], cfg)
    else:
        p["stack"] = TF.init_stack(ks[1], cfg)
    if not cfg.tie_embeddings:
        p["head"] = LN.init_linear(ks[2], cfg.d_model, cfg.vocab_size)
    return p


def _embed_in(params: dict, cfg: ArchConfig, batch: dict) -> jax.Array:
    if batch.get("embeds") is not None:
        return batch["embeds"].astype(cfg.activation_dtype)
    x = C.embed(params["embed"], batch["tokens"], cfg.activation_dtype)
    return x * jnp.asarray(cfg.d_model ** 0.5, cfg.activation_dtype)


def _logits(params: dict, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    x = C.apply_norm(cfg.norm_type, params["ln_out"], x)
    if cfg.tie_embeddings:
        logits = C.unembed(params["embed"], x, cfg.activation_dtype)
    else:
        logits = LN.apply_linear(params["head"], x, cfg.quant,
                                 dtype=cfg.activation_dtype)
    return C.softcap(logits, cfg.logit_softcap)


def forward(params: dict, cfg: ArchConfig, batch: dict, *,
            remat: bool = True) -> jax.Array:
    """Full-sequence forward -> final hidden states (B, S, D).

    batch: {"tokens": (B, S) int32} and/or {"embeds": (B, S, D)}; for
    enc-dec additionally {"enc_embeds": (B, S_enc, D)}.
    """
    x = _embed_in(params, cfg, batch)
    b, s = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None],
                                 (b, s))
    if cfg.encoder_layers:
        enc_out = ED.encode(params["encdec"], cfg, batch["enc_embeds"],
                            remat=remat)
        x = ED.decode_train(params["encdec"], cfg, x, enc_out, positions,
                            remat=remat)
    else:
        x = TF.stack_forward(params["stack"], cfg, x, positions,
                             remat=remat)
    return x


def loss_fn(params: dict, cfg: ArchConfig, batch: dict) -> jax.Array:
    """Next-token cross-entropy, chunked over the sequence so the

    (tokens, vocab) logits tensor never exceeds LOSS_CHUNK rows per step
    (vocabs here reach 256k — DESIGN.md §5)."""
    x = forward(params, cfg, batch)
    labels = batch["labels"]
    b, s = labels.shape
    chunk = min(LOSS_CHUNK, s)
    n = -(-s // chunk)
    s_p = n * chunk
    x = jnp.pad(x, ((0, 0), (0, s_p - s), (0, 0)))
    lab = jnp.pad(labels, ((0, 0), (0, s_p - s)), constant_values=-1)
    xc = jnp.moveaxis(x.reshape(b, n, chunk, -1), 1, 0)
    lc = jnp.moveaxis(lab.reshape(b, n, chunk), 1, 0)

    def chunk_loss(carry, inp):
        xs, ls = inp
        logits = _logits(params, cfg, xs).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(logits,
                                  jnp.clip(ls, 0)[..., None],
                                  axis=-1)[..., 0]
        valid = (ls >= 0).astype(jnp.float32)
        nll = (lse - tgt) * valid
        return (carry[0] + nll.sum(), carry[1] + valid.sum()), None

    (tot, cnt), _ = xscan(chunk_loss, (jnp.float32(0.),
                                       jnp.float32(0.)), (xc, lc))
    return tot / jnp.maximum(cnt, 1.0)


def logits_fn(params: dict, cfg: ArchConfig, batch: dict) -> jax.Array:
    """(B, S, V) logits — smoke tests / small models only."""
    return _logits(params, cfg, forward(params, cfg, batch, remat=False))


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def init_cache(params: dict, cfg: ArchConfig, batch: int, max_len: int,
               enc_len: int | None = None) -> dict:
    if cfg.encoder_layers:
        return ED.init_encdec_cache(params["encdec"], cfg, batch, max_len,
                                    enc_len or max_len)
    return {"stack": TF.init_cache(cfg, batch, max_len)}


def prefill(params: dict, cfg: ArchConfig, batch: dict, max_len: int):
    """Full-sequence prefill -> (last-token logits, cache)."""
    x = _embed_in(params, cfg, batch)
    b, s = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None],
                                 (b, s))
    if cfg.encoder_layers:
        enc_out = ED.encode(params["encdec"], cfg, batch["enc_embeds"])
        pos_emb = params["encdec"]["dec_pos"][:s].astype(x.dtype)
        # teacher-forced pass for cache is decode_step-driven; for the
        # backbone dry-run we expose encoder prefill only.
        x = ED.decode_train(params["encdec"], cfg, x, enc_out, positions)
        cache = None
        logits = _logits(params, cfg, x[:, -1:])
        return logits, cache
    x, cache = TF.stack_prefill(params["stack"], cfg, x, positions, max_len)
    logits = _logits(params, cfg, x[:, -1:])
    return logits, {"stack": cache}


def decode_step(params: dict, cfg: ArchConfig, tokens: jax.Array,
                cache: dict, idx: jax.Array, *,
                enc_out: jax.Array | None = None):
    """One new token for every sequence.  tokens: (B, 1) int32; ``idx`` is

    the absolute position being written (scalar).  Returns (logits
    (B, 1, V), new_cache)."""
    x = C.embed(params["embed"], tokens, cfg.activation_dtype)
    x = x * jnp.asarray(cfg.d_model ** 0.5, cfg.activation_dtype)
    if cfg.encoder_layers:
        x, new_cache = ED.decode_step(params["encdec"], cfg, x, cache, idx)
    else:
        x, new_stack = TF.stack_decode(params["stack"], cache["stack"], cfg,
                                       x, idx)
        new_cache = {"stack": new_stack}
    return _logits(params, cfg, x), new_cache
