"""Mamba-2 block — SSD (state-space duality) chunked algorithm.

Faithful to Dao & Gu, arXiv:2405.21060 ("minimal mamba2" formulation):
  zxbcdt = in_proj(u)                         # [z | x | B | C | dt]
  x,B,C <- causal conv1d (width d_conv) + silu
  dt    <- softplus(dt + dt_bias);   A = -exp(A_log)   (per head)
  y     = SSD(x * dt, A * dt, B, C)  + D * x
  out   = out_proj( rmsnorm(y * silu(z)) )

The SSD scan runs chunk-by-chunk (lax.scan over S/chunk steps) carrying
the (B, H, P, N) inter-chunk state — O(S * chunk) memory instead of the
naive O(S^2) attention-dual.  Decode is the constant-memory recurrence.

Paper-technique note (DESIGN.md §7): in/out projections are quant-aware
Linears (binarizable); the selective recurrence itself is NOT binarized —
sign-quantizing Δ/A/B/C collapses selectivity.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import common as C
from repro.models import linear as LN
from repro.utils.flags import xscan


def _dims(cfg: ArchConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    nheads = d_inner // s.head_dim
    conv_dim = d_inner + 2 * s.ngroups * s.d_state
    return s, d_inner, nheads, conv_dim


def init_mamba2(key: jax.Array, cfg: ArchConfig) -> dict:
    s, d_inner, nheads, conv_dim = _dims(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 10)
    lo, hi = s.a_init_range
    a = jnp.exp(jax.random.uniform(ks[2], (nheads,),
                                   minval=jnp.log(lo), maxval=jnp.log(hi)))
    p = {
        "A_log": jnp.log(a),
        "D": jnp.ones((nheads,)),
        "dt_bias": jnp.zeros((nheads,)),
        "norm": C.init_rmsnorm(d_inner),
        "out_proj": LN.init_linear(ks[3], d_inner, d),
    }
    gn = s.ngroups * s.d_state
    if s.fused_proj:
        d_in_proj = 2 * d_inner + 2 * gn + nheads
        p["in_proj"] = LN.init_linear(ks[0], d, d_in_proj)
        p["conv_w"] = jax.random.normal(ks[1], (s.d_conv, conv_dim)) * 0.1
        p["conv_b"] = jnp.zeros((conv_dim,))
    else:
        # §Perf split form: boundaries align with TP shards (docstring)
        # TP-shardable variants carry distinct names so the sharding
        # rules can treat them differently from the fused form.
        p["out_proj_tp"] = p.pop("out_proj")
        p["norm_tp"] = p.pop("norm")
        p["z_proj"] = LN.init_linear(ks[0], d, d_inner)
        p["x_proj"] = LN.init_linear(ks[4], d, d_inner)
        p["b_proj"] = LN.init_linear(ks[5], d, gn)
        p["c_proj"] = LN.init_linear(ks[6], d, gn)
        p["dt_proj"] = LN.init_linear(ks[7], d, nheads)
        p["conv_w_x"] = jax.random.normal(ks[1], (s.d_conv, d_inner)) * 0.1
        p["conv_b_x"] = jnp.zeros((d_inner,))
        p["conv_w_b"] = jax.random.normal(ks[8], (s.d_conv, gn)) * 0.1
        p["conv_b_b"] = jnp.zeros((gn,))
        p["conv_w_c"] = jax.random.normal(ks[9], (s.d_conv, gn)) * 0.1
        p["conv_b_c"] = jnp.zeros((gn,))
    return p


def _split_zxbcdt(cfg: ArchConfig, zxbcdt: jax.Array):
    s, d_inner, nheads, _ = _dims(cfg)
    gn = s.ngroups * s.d_state
    z, xbc, dt = jnp.split(zxbcdt, [d_inner, 2 * d_inner + 2 * gn], axis=-1)
    return z, xbc, dt


def _conv1d(xbc: jax.Array, w: jax.Array, b: jax.Array,
            init_state: jax.Array | None = None):
    """Causal depthwise conv along S.  xbc: (B, S, C); w: (K, C).

    Returns (y, final_state) where final_state = last K-1 inputs."""
    k = w.shape[0]
    if init_state is None:
        init_state = jnp.zeros((xbc.shape[0], k - 1, xbc.shape[-1]),
                               xbc.dtype)
    xp = jnp.concatenate([init_state, xbc], axis=1)
    y = sum(xp[:, i:i + xbc.shape[1], :] * w[i] for i in range(k)) + b
    return y, xp[:, -(k - 1):, :]


def _segsum(a: jax.Array) -> jax.Array:
    """a: (..., L).  out[..., i, j] = sum_{k=j+1..i} a_k  (i >= j), -inf
    above the diagonal."""
    L = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]      # i row, j col
    mask = jnp.tril(jnp.ones((L, L), bool), 0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x: jax.Array, a: jax.Array, b: jax.Array, c: jax.Array,
                chunk: int, init_state: jax.Array | None = None):
    """SSD chunked scan.

    x: (B, S, H, P) — inputs (already multiplied by dt)
    a: (B, S, H)    — log-decay per step (A * dt, negative)
    b: (B, S, G, N) — input projections (dt NOT applied; folded into x)
    c: (B, S, G, N) — output projections
    Returns (y (B,S,H,P), final_state (B,H,P,N)).
    """
    bsz, s, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    hpg = h // g                                   # heads per group

    def to_chunks(t):
        return t.reshape(bsz, nc, chunk, *t.shape[2:])

    xc, ac, bc, cc = map(to_chunks, (x, a, b, c))
    ac = jnp.moveaxis(ac, -1, 2)                   # (B, nc, H, L)

    if init_state is None:
        init_state = jnp.zeros((bsz, h, p, n), jnp.float32)

    def step(state, inp):
        xl, al, bl, cl = inp                       # (B,L,H,P),(B,H,L),(B,L,G,N)
        a_cs = jnp.cumsum(al, axis=-1)             # (B,H,L)
        L = jnp.exp(_segsum(al))                   # (B,H,L,L)
        # intra-chunk (the "attention dual"): heads grouped g -> repeat
        bl_h = jnp.repeat(bl, hpg, axis=2)         # (B,L,H,N)
        cl_h = jnp.repeat(cl, hpg, axis=2)
        scores = jnp.einsum("blhn,bshn->bhls", cl_h.astype(jnp.float32),
                            bl_h.astype(jnp.float32))
        y_diag = jnp.einsum("bhls,bhls,bshp->blhp", scores, L,
                            xl.astype(jnp.float32))
        # chunk-final state: state += sum_l exp(A_sum - A_cs[l]) B_l x_l
        decay_in = jnp.exp(a_cs[..., -1:] - a_cs)  # (B,H,L)
        new_contrib = jnp.einsum("blhn,bhl,blhp->bhpn", bl_h, decay_in,
                                 xl.astype(jnp.float32))
        chunk_decay = jnp.exp(a_cs[..., -1])       # (B,H)
        # inter-chunk output: y_off[l] = C_l . (decay_to_l * state_in)
        decay_out = jnp.exp(a_cs)                  # (B,H,L)
        y_off = jnp.einsum("blhn,bhpn,bhl->blhp", cl_h, state, decay_out)
        new_state = state * chunk_decay[..., None, None] + new_contrib
        return new_state, (y_diag + y_off)

    xs = (jnp.moveaxis(xc, 1, 0), jnp.moveaxis(ac, 1, 0),
          jnp.moveaxis(bc, 1, 0), jnp.moveaxis(cc, 1, 0))
    final_state, ys = xscan(step, init_state, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(bsz, s, h, p)
    return y, final_state


def _project_conv_full(params: dict, cfg: ArchConfig, u: jax.Array,
                       init_cache: dict | None):
    """Input projections + causal conv, fused (paper-faithful) or split
    (§Perf TP-alignable) form.  Returns (z, x, b, c, dt, conv_caches)."""
    s, d_inner, nheads, conv_dim = _dims(cfg)
    dt_ = cfg.activation_dtype
    gn = s.ngroups * s.d_state
    if s.fused_proj:
        zxbcdt = LN.apply_linear(params["in_proj"], u, cfg.quant, dtype=dt_)
        z, xbc, dt = _split_zxbcdt(cfg, zxbcdt)
        conv_init = init_cache["conv"] if init_cache else None
        xbc, conv_state = _conv1d(xbc.astype(jnp.float32),
                                  params["conv_w"], params["conv_b"],
                                  conv_init)
        xbc = jax.nn.silu(xbc)
        x, b, c = jnp.split(xbc, [d_inner, d_inner + gn], axis=-1)
        return z, x, b, c, dt, {"conv": conv_state}
    z = LN.apply_linear(params["z_proj"], u, cfg.quant, dtype=dt_)
    dt = LN.apply_linear(params["dt_proj"], u, cfg.quant, dtype=dt_)
    caches = {}
    outs = {}
    for name, proj, cw, cb in (("x", "x_proj", "conv_w_x", "conv_b_x"),
                               ("b", "b_proj", "conv_w_b", "conv_b_b"),
                               ("c", "c_proj", "conv_w_c", "conv_b_c")):
        t = LN.apply_linear(params[proj], u, cfg.quant, dtype=dt_)
        init = init_cache[f"conv_{name}"] if init_cache else None
        t, st = _conv1d(t.astype(jnp.float32), params[cw], params[cb],
                        init)
        outs[name] = jax.nn.silu(t)
        caches[f"conv_{name}"] = st
    return z, outs["x"], outs["b"], outs["c"], dt, caches


def mamba2_forward(params: dict, cfg: ArchConfig, u: jax.Array, *,
                   init_cache: dict | None = None, return_cache: bool = False):
    """Full-sequence forward.  u: (B, S, D) -> (B, S, D)."""
    s, d_inner, nheads, conv_dim = _dims(cfg)
    dt_ = cfg.activation_dtype
    bsz, slen, _ = u.shape
    z, x, b, c, dt, conv_caches = _project_conv_full(params, cfg, u,
                                                     init_cache)
    x = x.reshape(bsz, slen, nheads, s.head_dim)
    b = b.reshape(bsz, slen, s.ngroups, s.d_state)
    c = c.reshape(bsz, slen, s.ngroups, s.d_state)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    a = -jnp.exp(params["A_log"])                   # (H,), negative
    pad = (-slen) % s.chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    ssm_init = init_cache["state"] if init_cache else None
    y, state = ssd_chunked(x * dt[..., None], a * dt, b, c, s.chunk,
                           init_state=ssm_init)
    y = y[:, :slen]
    x = x[:, :slen]
    y = y + x.astype(jnp.float32) * params["D"][:, None]
    y = y.reshape(bsz, slen, d_inner)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = C.apply_rmsnorm(params.get("norm", params.get("norm_tp")),
                        y.astype(dt_))
    out = LN.apply_linear(params.get("out_proj",
                                     params.get("out_proj_tp")), y,
                          cfg.quant, dtype=dt_)
    if return_cache:
        return out, {**conv_caches, "state": state}
    return out


def init_mamba2_cache(cfg: ArchConfig, batch: int) -> dict:
    s, d_inner, nheads, conv_dim = _dims(cfg)
    gn = s.ngroups * s.d_state
    cache = {"state": jnp.zeros((batch, nheads, s.head_dim, s.d_state),
                                jnp.float32)}
    if s.fused_proj:
        cache["conv"] = jnp.zeros((batch, s.d_conv - 1, conv_dim),
                                  jnp.float32)
    else:
        cache["conv_x"] = jnp.zeros((batch, s.d_conv - 1, d_inner),
                                    jnp.float32)
        cache["conv_b"] = jnp.zeros((batch, s.d_conv - 1, gn), jnp.float32)
        cache["conv_c"] = jnp.zeros((batch, s.d_conv - 1, gn), jnp.float32)
    return cache


def mamba2_decode(params: dict, cfg: ArchConfig, u: jax.Array, cache: dict):
    """Single-token recurrence.  u: (B, 1, D).  O(1) state update:

    state = state * exp(dt*A) + dt * B x;  y = C . state + D x."""
    s, d_inner, nheads, conv_dim = _dims(cfg)
    dt_ = cfg.activation_dtype
    bsz = u.shape[0]
    gn = s.ngroups * s.d_state
    new_caches = {}
    if s.fused_proj:
        zxbcdt = LN.apply_linear(params["in_proj"], u, cfg.quant, dtype=dt_)
        z, xbc, dt = _split_zxbcdt(cfg, zxbcdt)
        xbc = xbc.astype(jnp.float32)
        conv_in = jnp.concatenate([cache["conv"], xbc], axis=1)
        y_conv = (conv_in * params["conv_w"][None]).sum(
            axis=1, keepdims=True) + params["conv_b"]
        new_caches["conv"] = conv_in[:, 1:, :]
        xbc1 = jax.nn.silu(y_conv)[:, 0]            # (B, conv_dim)
        x, b, c = jnp.split(xbc1, [d_inner, d_inner + gn], axis=-1)
    else:
        z = LN.apply_linear(params["z_proj"], u, cfg.quant, dtype=dt_)
        dt = LN.apply_linear(params["dt_proj"], u, cfg.quant, dtype=dt_)
        parts = {}
        for name, proj, cw, cb in (("x", "x_proj", "conv_w_x",
                                    "conv_b_x"),
                                   ("b", "b_proj", "conv_w_b",
                                    "conv_b_b"),
                                   ("c", "c_proj", "conv_w_c",
                                    "conv_b_c")):
            t = LN.apply_linear(params[proj], u, cfg.quant,
                                dtype=dt_).astype(jnp.float32)
            conv_in = jnp.concatenate([cache[f"conv_{name}"], t], axis=1)
            y_conv = (conv_in * params[cw][None]).sum(
                axis=1, keepdims=True) + params[cb]
            new_caches[f"conv_{name}"] = conv_in[:, 1:, :]
            parts[name] = jax.nn.silu(y_conv)[:, 0]
        x, b, c = parts["x"], parts["b"], parts["c"]
    x = x.reshape(bsz, nheads, s.head_dim)
    b = b.reshape(bsz, s.ngroups, s.d_state)
    c = c.reshape(bsz, s.ngroups, s.d_state)
    dt1 = jax.nn.softplus(dt.astype(jnp.float32)[:, 0] + params["dt_bias"])
    a = -jnp.exp(params["A_log"])
    decay = jnp.exp(dt1 * a)                        # (B, H)
    hpg = nheads // s.ngroups
    b_h = jnp.repeat(b, hpg, axis=1)                # (B, H, N)
    c_h = jnp.repeat(c, hpg, axis=1)
    dx = (dt1[..., None] * x)                       # (B, H, P)
    new_state = cache["state"] * decay[..., None, None] \
        + jnp.einsum("bhp,bhn->bhpn", dx, b_h)
    y = jnp.einsum("bhpn,bhn->bhp", new_state, c_h) \
        + x * params["D"][:, None]
    y = y.reshape(bsz, 1, d_inner)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = C.apply_rmsnorm(params.get("norm", params.get("norm_tp")),
                        y.astype(dt_))
    out = LN.apply_linear(params.get("out_proj",
                                     params.get("out_proj_tp")), y,
                          cfg.quant, dtype=dt_)
    return out, {**new_caches, "state": new_state}
