"""Shared model components: norms, rotary embeddings, softcaps, embeddings.

Everything is functional: ``init_*(key, ...) -> params``, pure apply fns.
Dtype policy: params are stored fp32 (master) and cast to ``cfg.dtype``
(bf16 by default) inside apply; norms accumulate in fp32.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int) -> dict:
    return {"scale": jnp.zeros((d,), jnp.float32)}     # gemma-style (1+scale)


def apply_rmsnorm(params: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps) * (1.0 + params["scale"])
    return y.astype(dtype)


def init_layernorm(d: int) -> dict:
    return {"scale": jnp.ones((d,), jnp.float32),
            "bias": jnp.zeros((d,), jnp.float32)}


def apply_layernorm(params: dict, x: jax.Array, eps: float = 1e-5
                    ) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    mu = x32.mean(-1, keepdims=True)
    var = ((x32 - mu) ** 2).mean(-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps) * params["scale"] + params["bias"]
    return y.astype(dtype)


def init_norm(kind: str, d: int) -> dict:
    return init_rmsnorm(d) if kind == "rmsnorm" else init_layernorm(d)


def apply_norm(kind: str, params: dict, x: jax.Array) -> jax.Array:
    return (apply_rmsnorm if kind == "rmsnorm" else apply_layernorm)(params, x)


# ---------------------------------------------------------------------------
# rotary position embeddings — standard / fractional (chatglm) / M-RoPE
# ---------------------------------------------------------------------------

def rope_freqs(rot_dim: int, base: float = 10000.0) -> jax.Array:
    """Inverse frequencies for ``rot_dim`` rotary dims (rot_dim even)."""
    return 1.0 / (base ** (jnp.arange(0, rot_dim, 2, jnp.float32) / rot_dim))


def apply_rope(x: jax.Array, positions: jax.Array, *, fraction: float = 1.0,
               base: float = 10000.0) -> jax.Array:
    """Neox-style rotary embedding over the leading ``fraction`` of head_dim.

    ``x``: (B, S, H, D); ``positions``: (B, S) int32.
    ``fraction=0.5`` is the ChatGLM "2d/partial" convention: only the first
    half of head_dim rotates, the rest passes through.
    """
    d = x.shape[-1]
    rot = int(d * fraction)
    rot -= rot % 2
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    inv = rope_freqs(rot, base)                                  # (rot/2,)
    ang = positions[..., None].astype(jnp.float32) * inv         # (B,S,rot/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x_rot, 2, axis=-1)
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    out = jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)
    if x_pass.shape[-1]:
        out = jnp.concatenate([out, x_pass.astype(x.dtype)], axis=-1)
    return out


def apply_mrope(x: jax.Array, positions_3d: jax.Array, *,
                sections: tuple[int, int, int] = (16, 24, 24),
                base: float = 10000.0) -> jax.Array:
    """Qwen2-VL multimodal RoPE: the (D/2) frequency dims are split into

    (temporal, height, width) sections, each rotated by its own position
    stream.  ``positions_3d``: (3, B, S).  For pure-text input all three
    streams are the sequence index, which reduces M-RoPE to standard RoPE
    — the property tests rely on this identity.
    """
    d = x.shape[-1]
    half = d // 2
    assert sum(sections) == half, (sections, d)
    inv = rope_freqs(d, base)                                    # (half,)
    # section id per frequency dim
    sec = jnp.concatenate([jnp.full((s,), i, jnp.int32)
                           for i, s in enumerate(sections)])
    pos = positions_3d.astype(jnp.float32)                       # (3, B, S)
    pos_per_freq = pos[sec]                                      # (half,B,S)
    ang = jnp.moveaxis(pos_per_freq, 0, -1) * inv                # (B,S,half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x, 2, axis=-1)
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


def sinusoidal_positions(max_len: int, d: int) -> jax.Array:
    """Whisper-style fixed sinusoidal embeddings (max_len, d)."""
    pos = jnp.arange(max_len, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, jnp.float32)[None, :]
    ang = pos / (10000.0 ** (dim / d))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# softcap + misc
# ---------------------------------------------------------------------------

def softcap(x: jax.Array, cap: float | None) -> jax.Array:
    """Gemma-2 logit soft-capping: cap * tanh(x / cap)."""
    if cap is None:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


# ---------------------------------------------------------------------------
# embeddings / unembedding
# ---------------------------------------------------------------------------

def init_embedding(key: jax.Array, vocab: int, d: int) -> dict:
    return {"table": jax.random.normal(key, (vocab, d), jnp.float32) * 0.02}


def embed(params: dict, tokens: jax.Array, dtype=jnp.bfloat16) -> jax.Array:
    return params["table"].astype(dtype)[tokens]


def unembed(params: dict, x: jax.Array, dtype=jnp.bfloat16) -> jax.Array:
    return jnp.einsum("...d,vd->...v", x, params["table"].astype(dtype))


def init_dense(key: jax.Array, d_in: int, d_out: int, *,
               scale: float | None = None) -> dict:
    scale = scale if scale is not None else d_in ** -0.5
    return {"w": jax.random.normal(key, (d_in, d_out), jnp.float32) * scale}


def dense(params: dict, x: jax.Array, dtype=None) -> jax.Array:
    dtype = dtype or x.dtype
    return jnp.einsum("...d,df->...f", x, params["w"].astype(dtype))
