"""Pytree helpers for scan-stacked layer parameters."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_stack(trees: list):
    """Stack a list of identically-structured pytrees along a new axis 0."""
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *trees)


def tree_index(tree, i: int):
    """Static-index axis 0 of every leaf."""
    return jax.tree.map(lambda x: x[i], tree)


def tree_dynamic_index(tree, i):
    """Dynamic-index axis 0 of every leaf (traced index)."""
    return jax.tree.map(
        lambda x: jax.lax.dynamic_index_in_dim(x, i, 0, keepdims=False),
        tree)


def tree_bytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize
               for x in jax.tree.leaves(tree)
               if hasattr(x, "size"))


def tree_count(tree) -> int:
    return sum(x.size for x in jax.tree.leaves(tree) if hasattr(x, "size"))
