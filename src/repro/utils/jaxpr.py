"""Jaxpr inspection helpers shared by the benchmarks, the test suite,
and the telemetry probes.

The kernel subsystem's evidence ("the bit-plane conv is ONE launch",
"the patch matrix never hits HBM") is op-count-level: it comes from
walking a traced jaxpr, recursing into nested (pjit) bodies.  ONE
recursive traversal (:func:`iter_eqns`) backs every consumer —
:func:`pallas_launches` (kernel name + grid per launch, what the
telemetry cost probes record), the :func:`pallas_grids` /
:func:`count_pallas_calls` views over it, and
:func:`max_intermediate_bytes` (the largest HBM intermediate, the
fused-epilogue evidence) — so the recursion rule cannot drift between
them.  ``pallas_call`` bodies are never descended into: everything
inside one is a single launch's VMEM-resident work, not an HBM
intermediate or a separate launch.
"""
from __future__ import annotations

import dataclasses

import jax

try:                                   # jax >= 0.6 moved these aliases
    from jax.extend.core import ClosedJaxpr, Jaxpr
except ImportError:                    # jax <= 0.5
    from jax.core import ClosedJaxpr, Jaxpr


def subjaxprs(param):
    """Yield every jaxpr nested inside one eqn param (lists included)."""
    if isinstance(param, ClosedJaxpr):
        yield param.jaxpr
    elif isinstance(param, Jaxpr):
        yield param
    elif isinstance(param, (list, tuple)):
        for e in param:
            yield from subjaxprs(e)


def iter_eqns(jaxpr):
    """Yield every eqn in ``jaxpr``, recursing into nested jaxprs (jit /
    scan / cond bodies) but NOT into ``pallas_call`` kernel bodies — a
    kernel's internal eqns are one launch's VMEM work, not separate
    launches or HBM intermediates."""
    for eqn in jaxpr.eqns:
        yield eqn
        if eqn.primitive.name == "pallas_call":
            continue
        for p in eqn.params.values():
            for sub in subjaxprs(p):
                yield from iter_eqns(sub)


@dataclasses.dataclass(frozen=True)
class PallasLaunch:
    """One traced ``pallas_call``: the kernel's name and launch grid."""
    kernel: str
    grid: tuple[int, ...]


def _kernel_name(eqn) -> str:
    info = eqn.params.get("name_and_src_info")
    if info is not None and getattr(info, "name", None):
        return str(info.name)
    name = eqn.params.get("name")           # older jax spelling
    return str(name) if name else "pallas_call"


def pallas_launches(fn, *args) -> list[PallasLaunch]:
    """Every pallas_call in ``fn``'s jaxpr, in trace order, with its
    kernel name and launch grid — the unit the telemetry cost probes
    (``telemetry/probes.py``) record and regression-gate."""
    closed = jax.make_jaxpr(fn)(*args)
    return [PallasLaunch(kernel=_kernel_name(eqn),
                         grid=tuple(eqn.params["grid_mapping"].grid))
            for eqn in iter_eqns(closed.jaxpr)
            if eqn.primitive.name == "pallas_call"]


def pallas_grids(fn, *args) -> list[tuple[int, ...]]:
    """Launch grid of every pallas_call in ``fn``'s jaxpr, in trace order.

    The serving subsystem's GEMV-vs-GEMM evidence is launch-*shape*
    level: a batch ≤ 8 dense flush must lower to the N-major 1-D GEMV
    grid and a large flush to the 3-D (M, N, K) blocked GEMM grid
    (``kernels.ops.dispatch_batch``).
    """
    return [launch.grid for launch in pallas_launches(fn, *args)]


def count_pallas_calls(fn, *args) -> int:
    """Number of pallas_call primitives in ``fn``'s jaxpr — the
    kernel-launch count of the traced fn, recursing into jit bodies."""
    return len(pallas_launches(fn, *args))


def max_intermediate_bytes(fn, *args) -> tuple[int, tuple[int, ...]]:
    """(bytes, shape) of the largest intermediate any eqn produces —
    the HBM high-water evidence for the fused epilogues (an eqn output
    is an HBM-visible array at jaxpr level; pallas_call bodies are
    excluded, their internals live in VMEM)."""
    closed = jax.make_jaxpr(fn)(*args)
    best_bytes, best_shape = 0, ()
    for eqn in iter_eqns(closed.jaxpr):
        for v in eqn.outvars:
            aval = v.aval
            if hasattr(aval, "shape") and hasattr(aval, "dtype"):
                nbytes = int(aval.size) * aval.dtype.itemsize
                if nbytes > best_bytes:
                    best_bytes, best_shape = nbytes, tuple(aval.shape)
    return best_bytes, best_shape
