"""Jaxpr inspection helpers shared by the benchmarks and the test suite.

The kernel subsystem's evidence ("the bit-plane conv is ONE launch",
"the patch matrix never hits HBM") is op-count-level: it comes from
walking a traced jaxpr, recursing into nested (pjit) bodies.  Both the
Table-3 benchmark and the property suite need the same walk, so it
lives here.
"""
from __future__ import annotations

import jax

try:                                   # jax >= 0.6 moved these aliases
    from jax.extend.core import ClosedJaxpr, Jaxpr
except ImportError:                    # jax <= 0.5
    from jax.core import ClosedJaxpr, Jaxpr


def subjaxprs(param):
    """Yield every jaxpr nested inside one eqn param (lists included)."""
    if isinstance(param, ClosedJaxpr):
        yield param.jaxpr
    elif isinstance(param, Jaxpr):
        yield param
    elif isinstance(param, (list, tuple)):
        for e in param:
            yield from subjaxprs(e)


def pallas_grids(fn, *args) -> list[tuple[int, ...]]:
    """Launch grid of every pallas_call in ``fn``'s jaxpr, in trace order.

    The serving subsystem's GEMV-vs-GEMM evidence is launch-*shape*
    level: a batch ≤ 8 dense flush must lower to the N-major 1-D GEMV
    grid and a large flush to the 3-D (M, N, K) blocked GEMM grid
    (``kernels.ops.dispatch_batch``).  Recurses into jit bodies like
    :func:`count_pallas_calls`.
    """
    grids: list[tuple[int, ...]] = []

    def walk(jaxpr):
        for eqn in jaxpr.eqns:
            if eqn.primitive.name == "pallas_call":
                grids.append(tuple(eqn.params["grid_mapping"].grid))
                continue
            for p in eqn.params.values():
                for sub in subjaxprs(p):
                    walk(sub)

    walk(jax.make_jaxpr(fn)(*args).jaxpr)
    return grids


def count_pallas_calls(fn, *args) -> int:
    """Number of pallas_call primitives in ``fn``'s jaxpr — the
    kernel-launch count of the traced fn, recursing into jit bodies."""
    closed = jax.make_jaxpr(fn)(*args)

    def walk(jaxpr):
        n = 0
        for eqn in jaxpr.eqns:
            if eqn.primitive.name == "pallas_call":
                n += 1
                continue
            for p in eqn.params.values():
                for sub in subjaxprs(p):
                    n += walk(sub)
        return n

    return walk(closed.jaxpr)
