"""Back-compat shim: the jaxpr traversal moved to
``repro.analysis.graph`` (the shared core under every static pass —
see ``docs/analysis.md``).  Existing call sites keep importing
``pallas_launches``/``pallas_grids``/``max_intermediate_bytes`` etc.
from here; new code should import from ``repro.analysis``.
"""
from repro.analysis.graph import (CALL_PRIMITIVES, PallasLaunch,
                                  call_subjaxpr, count_pallas_calls,
                                  iter_eqns, kernel_name,
                                  max_intermediate_bytes, pallas_eqns,
                                  pallas_grids, pallas_launches, subjaxprs)

# Older private spelling, kept for any external consumers.
_kernel_name = kernel_name

__all__ = [
    "CALL_PRIMITIVES", "PallasLaunch", "call_subjaxpr",
    "count_pallas_calls", "iter_eqns", "kernel_name",
    "max_intermediate_bytes", "pallas_eqns", "pallas_grids",
    "pallas_launches", "subjaxprs",
]
