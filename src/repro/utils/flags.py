"""Analysis-mode flag: unroll every sequential scan so the compiled HLO

carries the TRUE op counts.  XLA's HloCostAnalysis visits a while-loop
body ONCE, so a scanned program under-reports FLOPs/bytes by the trip
count; for §Roofline we re-lower the cell with ``analysis_mode()`` active
and every ``xscan`` fully unrolled (and every collective materialized per
layer).  Compile is slower — used for the roofline cells, not the 40-cell
lowering sweep.
"""
from __future__ import annotations

import contextlib
import contextvars

import jax

_ANALYSIS = contextvars.ContextVar("repro_analysis_mode", default=False)


def in_analysis_mode() -> bool:
    return _ANALYSIS.get()


@contextlib.contextmanager
def analysis_mode(on: bool = True):
    tok = _ANALYSIS.set(on)
    try:
        yield
    finally:
        _ANALYSIS.reset(tok)


def xscan(f, init, xs, length: int | None = None):
    """lax.scan that fully unrolls in analysis mode."""
    if in_analysis_mode():
        return jax.lax.scan(f, init, xs, length=length, unroll=True)
    return jax.lax.scan(f, init, xs, length=length)


def xmap_seq(f, xs):
    """Sequential map (lax.map) that unrolls in analysis mode."""
    if in_analysis_mode():
        def body(carry, x):
            return carry, f(x)
        _, ys = jax.lax.scan(body, None, xs, unroll=True)
        return ys
    return jax.lax.map(f, xs)
