"""Compiled-HLO text inspection: collective ops and their wire bytes.

Shared by the multi-pod dry-run (`launch/dryrun.py`) and the sharded
packed-forward verifier (`distributed/verify_sharded.py`).  It lives
here rather than in dryrun because importing dryrun has a side effect —
it forces 512 fake devices via XLA_FLAGS — that the 8-device verifier
process must not inherit.
"""
from __future__ import annotations

import re

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8,
                "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
                "s8": 1, "u8": 1, "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[^\]]*\][^ ]*))\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-device wire-byte model from the partitioned module:

    all-gather / all-to-all / collective-permute: output bytes;
    reduce-scatter: input bytes ~= output * k (approximated by output
    bytes of the pre-scatter operand — we use output*1 as lower bound,
    noted); all-reduce: 2x bytes (reduce-scatter + all-gather ring)."""
    per_kind: dict[str, float] = {}
    for m in _COLL_RE.finditer(hlo_text):
        ty, kind = m.group(1), m.group(2)
        b = _shape_bytes(ty)
        factor = 2.0 if kind == "all-reduce" else 1.0
        per_kind[kind] = per_kind.get(kind, 0.0) + b * factor
    per_kind["total"] = sum(v for k, v in per_kind.items())
    return per_kind


def collective_kinds(hlo_text: str) -> dict[str, int]:
    """Occurrence count per collective kind in the partitioned module."""
    kinds: dict[str, int] = {}
    for m in _COLL_RE.finditer(hlo_text):
        kinds[m.group(2)] = kinds.get(m.group(2), 0) + 1
    return kinds
