"""Packed-weight checkpoints: save/restore the serving cache's trees.

A ``pack_bcnn``/``pack_bmlp`` tree is MIXED: array leaves (packed words,
folded tau/flip, corrections, pool-mask words) interleave with statics
(plan geometry ints, ``None`` pool masks, the spec dataclass).  Statics
cannot round-trip through ``npz`` — and must not: they are derived from
the model config, which the restoring process already has.  So a packed
checkpoint saves ONLY the array leaves, keyed by tree path, and restore
grafts them into a caller-supplied template tree (``demo_model`` /
``pack_*`` output of the same config), re-placing each leaf under the
restore-time mesh via ``distributed.sharding.shard_packed`` — the
elastic warm-restart path: the survivor mesh's own divisibility plan
decides the new placement, same reshard-on-restore contract as
``load_checkpoint``.

Layout reuses :func:`repro.checkpoint.save_checkpoint`'s atomic
``step_<N>/arrays.npz + meta.json`` scheme (tmp + rename), so
``latest_step`` and crash-safety apply unchanged; ``meta.extra`` tags
the tree kind for a cheap mismatch check at restore.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.checkpoint.checkpointer import load_checkpoint, save_checkpoint


def _array_leaves(tree) -> dict[str, np.ndarray]:
    """{'/'-joined path: host array} for every array leaf (statics and
    None leaves skipped) — same path scheme as the checkpointer's."""
    leaves, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in leaves:
        if not isinstance(leaf, (jax.Array, np.ndarray)):
            continue
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = np.asarray(leaf)
    return out


def save_packed_checkpoint(ckpt_dir: str, step: int, packed,
                           extra: dict | None = None) -> str:
    """Write the array leaves of a packed tree (atomic, step-tagged)."""
    from repro.models.cnn import packed_kind
    arrays = _array_leaves(packed)
    meta = {"packed_kind": packed_kind(packed), "n_arrays": len(arrays)}
    meta.update(extra or {})
    return save_checkpoint(ckpt_dir, step, arrays, extra=meta)


def load_packed_checkpoint(ckpt_dir: str, step: int, template, *,
                           mesh=None):
    """Graft a packed checkpoint's arrays into ``template``.

    ``template`` is a freshly built packed tree of the SAME config (its
    statics are kept verbatim; its array leaves are replaced by the
    checkpointed values).  With ``mesh`` the restored tree is placed by
    ``shard_packed`` under that mesh — restore-onto-survivors in one
    call.  Raises ``KeyError`` if the checkpoint is missing a leaf the
    template has (config mismatch), ``ValueError`` on kind mismatch.
    """
    import json
    import os

    from repro.models.cnn import packed_kind

    # kind check BEFORE grafting: a config mismatch must fail as such,
    # not as a missing-array KeyError halfway through the restore
    meta_path = os.path.join(ckpt_dir, f"step_{step:08d}", "meta.json")
    with open(meta_path) as f:
        got_kind = json.load(f)["extra"].get("packed_kind")
    want_kind = packed_kind(template)
    if got_kind is not None and got_kind != want_kind:
        raise ValueError(f"packed checkpoint kind {got_kind!r} != "
                         f"template kind {want_kind!r}")
    tmpl_arrays = _array_leaves(template)
    saved, meta = load_checkpoint(ckpt_dir, step, tmpl_arrays)

    def graft(path, leaf):
        if not isinstance(leaf, (jax.Array, np.ndarray)):
            return leaf
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        return saved[key]

    restored = jax.tree_util.tree_map_with_path(graft, template)
    if mesh is not None:
        from repro.distributed.sharding import shard_packed
        restored = shard_packed(restored, mesh)
    return restored, meta
