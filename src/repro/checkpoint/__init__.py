from repro.checkpoint.checkpointer import (save_checkpoint, load_checkpoint,
                                           latest_step, AsyncCheckpointer)
from repro.checkpoint.packed import (save_packed_checkpoint,
                                     load_packed_checkpoint)

__all__ = ["save_checkpoint", "load_checkpoint", "latest_step",
           "AsyncCheckpointer", "save_packed_checkpoint",
           "load_packed_checkpoint"]
