"""Checkpointing: atomic, step-tagged, reshard-on-restore.

Layout:  <dir>/step_<N>/arrays.npz  +  <dir>/step_<N>/meta.json
Writes go to ``step_<N>.tmp`` and are renamed into place — a crash mid-
write never corrupts the latest checkpoint (restart safety).  Restore
takes an optional sharding tree and ``jax.device_put``s each leaf, so a
job restarted on a *different mesh shape* (elastic scaling) reshards
transparently.

Single-host container: arrays are gathered to host numpy.  On a real
multi-host pod the same API would write per-process shards (the path
structure already namespaces by step); noted in DESIGN.md §5.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = np.asarray(leaf)
    return out


def save_checkpoint(ckpt_dir: str, step: int, tree, extra: dict | None = None
                    ) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    arrays = _flatten(tree)
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    meta = {"step": step, "time": time.time(), "extra": extra or {},
            "n_arrays": len(arrays)}
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def load_checkpoint(ckpt_dir: str, step: int, template,
                    shardings=None) -> tuple:
    """Restore into the structure of ``template``; optional sharding tree

    (reshard-on-restore / elastic scaling)."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    leaves, treedef = jax.tree_util.tree_flatten_with_path(template)
    out_leaves = []
    shard_leaves = (jax.tree.leaves(shardings) if shardings is not None
                    else [None] * len(leaves))
    for (p, leaf), sh in zip(leaves, shard_leaves):
        key = "/".join(str(getattr(q, "key", getattr(q, "idx", q)))
                       for q in p)
        arr = data[key]
        if sh is not None:
            arr = jax.device_put(arr, sh)
        out_leaves.append(arr)
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(template), out_leaves)
    return tree, meta


class AsyncCheckpointer:
    """Overlap checkpoint writes with training (one in-flight save).

    A save that raises in the worker thread is NOT silently lost: the
    exception is recorded and re-raised from the next :meth:`wait` —
    and, because :meth:`save` waits for the in-flight write first, from
    the next ``save`` as well.  A supervisor restarting from "the last
    checkpoint" therefore finds out the last checkpoint never landed
    instead of restoring something older than it believes.
    """

    def __init__(self, ckpt_dir: str):
        self.ckpt_dir = ckpt_dir
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None

    def save(self, step: int, tree, extra: dict | None = None) -> None:
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)   # snapshot on host

        def work():
            try:
                save_checkpoint(self.ckpt_dir, step, host_tree, extra)
            except BaseException as e:       # surfaced by wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
