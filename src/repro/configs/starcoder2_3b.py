"""starcoder2-3b [dense] — GQA kv=2, RoPE [arXiv:2402.19173; hf]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-3b",
    family="dense",
    num_layers=30,
    d_model=3072,
    num_heads=24,
    num_kv_heads=2,
    head_dim=128,
    d_ff=12288,
    vocab_size=49152,
    ffn_type="gelu",
    rope_style="standard",
    rope_base=100000.0,          # starcoder2 long-context base
    norm_type="layernorm",
)
