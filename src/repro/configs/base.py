"""Architecture + shape configuration schema.

Every assigned architecture is a frozen ``ArchConfig``; reduced smoke
variants are produced by ``ArchConfig.reduced()``.  The paper's binary
technique plugs in through ``quant`` (see ``repro.core.quantize``).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace

import jax.numpy as jnp

from repro.core.quantize import QuantConfig, QuantMode


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    shared_experts: int = 0          # llama4 has 1 shared expert
    capacity_factor: float = 1.25
    router_jitter: float = 0.0


@dataclass(frozen=True)
class SSMConfig:                     # Mamba-2 / SSD (arXiv:2405.21060)
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    ngroups: int = 1
    chunk: int = 256
    a_init_range: tuple[float, float] = (1.0, 16.0)
    # True  = paper-faithful fused in_proj ([z|x|B|C|dt] one matmul) —
    #         the five blocks interleave on one axis, so TP sharding
    #         misaligns and the resolver replicates mamba over 'model'.
    # False = §Perf variant: five separate projections + split conv;
    #         every tensor then shards cleanly (heads over 'model').
    fused_proj: bool = True


@dataclass(frozen=True)
class RGLRUConfig:                   # Griffin / RecurrentGemma (2402.19427)
    lru_width: int = 0               # 0 -> d_model
    conv_width: int = 4
    c_exponent: float = 8.0          # a = exp(-c * softplus(Λ) * r)


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense|ssm|moe|vlm|audio|hybrid
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # attention
    attention_pattern: tuple[str, ...] = ("global",)   # cycled over layers
    window_size: int = 4096          # for 'local' layers
    rope_style: str = "standard"     # standard|partial|mrope|none
    rope_fraction: float = 1.0
    rope_base: float = 10000.0
    qk_norm: bool = False
    attn_softcap: float | None = None
    logit_softcap: float | None = None
    learned_positions: bool = False  # whisper decoder
    max_position: int = 1 << 20

    # ffn
    ffn_type: str = "swiglu"         # swiglu|geglu|gelu|relu2|silu|none
    norm_type: str = "rmsnorm"

    # family extras
    moe: MoEConfig | None = None
    ssm: SSMConfig | None = None
    rglru: RGLRUConfig | None = None
    encoder_layers: int = 0          # >0 -> encoder-decoder (whisper)
    frontend: str | None = None      # 'audio_stub' | 'vision_stub'

    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    quant: QuantConfig = field(default_factory=QuantConfig)
    # KV-cache storage: 'bf16' | 'int8' (per-(token, head) absmax scale —
    # the paper's pack-the-memory-bound-operand idea applied to the KV
    # cache; beyond-paper, see EXPERIMENTS.md §Perf cell A v4)
    kv_cache_dtype: str = "bf16"
    # sub-quadratic? (drives long_500k applicability, DESIGN.md §7)
    subquadratic: bool = False

    @property
    def pattern_period(self) -> int:
        return len(self.attention_pattern)

    @property
    def activation_dtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32

    def layer_kind(self, i: int) -> str:
        return self.attention_pattern[i % self.pattern_period]

    def reduced(self) -> "ArchConfig":
        """Smoke-test variant: same family/wiring, tiny dims."""
        changes: dict = dict(
            num_layers=max(2 * self.pattern_period, 2),
            d_model=64,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 2) or 1,
            head_dim=16,
            d_ff=128 if self.d_ff else 0,
            vocab_size=256,
            window_size=8,
            max_position=4096,
        )
        if self.encoder_layers:
            changes["encoder_layers"] = 2
        if self.moe:
            changes["moe"] = replace(self.moe, num_experts=4,
                                     top_k=min(self.moe.top_k, 2),
                                     d_ff_expert=32)
        if self.ssm:
            changes["ssm"] = replace(self.ssm, d_state=16, head_dim=8,
                                     chunk=8)
        if self.rglru:
            changes["rglru"] = replace(self.rglru, lru_width=64)
        return replace(self, **changes)

    # ---- parameter counting (for roofline MODEL_FLOPS) ------------------
    def param_counts(self) -> dict[str, float]:
        d, f, L = self.d_model, self.d_ff, self.num_layers
        hq, hkv, hd = self.num_heads, self.num_kv_heads, self.head_dim
        attn_p = d * hd * (hq + 2 * hkv) + hq * hd * d
        if self.ffn_type in ("swiglu", "geglu"):
            ffn_p = 3 * d * f
        elif self.ffn_type == "none":
            ffn_p = 0
        else:
            ffn_p = 2 * d * f
        per_layer_active = 0.0
        per_layer_total = 0.0
        n_attn_layers = sum(1 for i in range(L)
                            if self.layer_kind(i) in ("global", "local"))
        n_rec_layers = L - n_attn_layers
        if self.ssm:
            s = self.ssm
            d_in = s.expand * d
            nheads = d_in // s.head_dim
            ssm_p = (d * (2 * d_in + 2 * s.ngroups * s.d_state + nheads)
                     + d_in * d)
            per_layer_total = per_layer_active = ssm_p
            total = L * ssm_p
            active = total
        elif self.rglru:
            w = self.rglru.lru_width or d
            rec_p = 2 * d * w + w * d + 2 * w * w // 1  # gates + in/out proj
            per_attn = attn_p + ffn_p
            per_rec = rec_p + ffn_p
            total = n_attn_layers * per_attn + n_rec_layers * per_rec
            active = total
        elif self.moe:
            m = self.moe
            if self.ffn_type in ("swiglu", "geglu"):
                e_p = 3 * d * m.d_ff_expert
            else:
                e_p = 2 * d * m.d_ff_expert
            router_p = d * m.num_experts
            per_layer_total = attn_p + router_p + \
                (m.num_experts + m.shared_experts) * e_p
            per_layer_active = attn_p + router_p + \
                (m.top_k + m.shared_experts) * e_p
            total = L * per_layer_total
            active = L * per_layer_active
        else:
            total = active = L * (attn_p + ffn_p)
        if self.encoder_layers:
            enc = self.encoder_layers * (attn_p + ffn_p)
            cross = self.encoder_layers and L * (d * hd * (hq + 2 * hkv)
                                                 + hq * hd * d)
            total += enc + cross
            active += enc + cross
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return {"total": float(total + emb), "active": float(active + emb),
                "body_total": float(total), "body_active": float(active)}


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # 'train' | 'prefill' | 'decode'
