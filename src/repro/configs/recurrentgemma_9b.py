"""recurrentgemma-9b [hybrid] — RG-LRU + local attention, 2 recurrent :
1 attention [arXiv:2402.19427]."""
from repro.configs.base import ArchConfig, RGLRUConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,               # 12 x (rec, rec, attn) + (rec, rec)
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,              # MQA
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    ffn_type="geglu",
    rope_style="standard",
    attention_pattern=("rec", "rec", "local"),
    window_size=2048,
    rglru=RGLRUConfig(lru_width=4096, conv_width=4, c_exponent=8.0),
    norm_type="rmsnorm",
    tie_embeddings=True,
    subquadratic=True,           # bounded window + constant LRU state
)
