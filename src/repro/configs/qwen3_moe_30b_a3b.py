"""qwen3-moe-30b-a3b [moe] — 128 experts top-8, QK-norm
[hf:Qwen/Qwen3-30B-A3B]."""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=768,                    # per-expert width
    vocab_size=151936,
    ffn_type="swiglu",
    rope_style="standard",
    rope_base=1000000.0,
    qk_norm=True,                # qwen3 RMS-norms q and k per head
    moe=MoEConfig(num_experts=128, top_k=8, d_ff_expert=768,
                  shared_experts=0, capacity_factor=1.25),
    norm_type="rmsnorm",
)
