"""whisper-base [audio] — enc-dec transformer backbone; conv frontend STUB
(precomputed frame embeddings) [arXiv:2212.04356].

6L here means 6 encoder + 6 decoder layers (whisper-base layout)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-base",
    family="audio",
    num_layers=6,                # decoder layers
    encoder_layers=6,
    d_model=512,
    num_heads=8,
    num_kv_heads=8,              # kv=8 == MHA per the assignment
    head_dim=64,
    d_ff=2048,
    vocab_size=51865,
    ffn_type="gelu",
    rope_style="none",           # sinusoidal enc / learned dec positions
    learned_positions=True,
    norm_type="layernorm",
    frontend="audio_stub",
    max_position=1 << 16,
)
