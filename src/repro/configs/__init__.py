from repro.configs.base import (ArchConfig, MoEConfig, SSMConfig,
                                RGLRUConfig, ShapeConfig)
from repro.configs.registry import get_config, list_configs
from repro.configs.shapes import SHAPES, get_shape

__all__ = ["ArchConfig", "MoEConfig", "SSMConfig", "RGLRUConfig",
           "ShapeConfig", "get_config", "list_configs", "SHAPES",
           "get_shape"]
