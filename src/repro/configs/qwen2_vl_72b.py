"""qwen2-vl-72b [vlm] — M-RoPE, dynamic resolution [arXiv:2409.12191; hf].

Backbone only: the vision frontend is a STUB — ``input_specs()`` provides
precomputed patch embeddings via batch["embeds"] (assignment note)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-72b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=29568,
    vocab_size=152064,
    ffn_type="swiglu",
    rope_style="mrope",          # (t, h, w) 3-section rotary
    rope_base=1000000.0,
    norm_type="rmsnorm",
    frontend="vision_stub",
)
