"""Architecture registry: ``get_config(name)`` / ``--arch <id>``."""
from __future__ import annotations

import dataclasses

from repro.configs import (chatglm3_6b, gemma2_9b, llama4_maverick_400b,
                           mamba2_1_3b, nemotron_4_15b, qwen2_vl_72b,
                           qwen3_moe_30b_a3b, recurrentgemma_9b,
                           starcoder2_3b, whisper_base)
from repro.configs.base import ArchConfig
from repro.core.quantize import QuantConfig, QuantMode

_REGISTRY: dict[str, ArchConfig] = {
    c.name: c for c in [
        nemotron_4_15b.CONFIG,
        chatglm3_6b.CONFIG,
        gemma2_9b.CONFIG,
        starcoder2_3b.CONFIG,
        mamba2_1_3b.CONFIG,
        llama4_maverick_400b.CONFIG,
        qwen3_moe_30b_a3b.CONFIG,
        qwen2_vl_72b.CONFIG,
        whisper_base.CONFIG,
        recurrentgemma_9b.CONFIG,
    ]
}

ARCH_IDS = tuple(_REGISTRY)


def get_config(name: str, *, quant: str | None = None,
               reduced: bool = False) -> ArchConfig:
    """Look up an architecture; ``quant`` in {float, binary_weight, binary}
    applies the paper's technique (DESIGN.md §3)."""
    cfg = _REGISTRY[name]
    if quant is not None:
        cfg = dataclasses.replace(cfg, quant=QuantConfig(
            mode=QuantMode(quant)))
    if reduced:
        cfg = cfg.reduced()
    return cfg


def list_configs() -> tuple[str, ...]:
    return ARCH_IDS
