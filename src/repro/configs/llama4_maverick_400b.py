"""llama4-maverick-400b-a17b [moe] — 128 experts top-1 + 1 shared expert,
early fusion [hf:meta-llama/Llama-4]."""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,                   # per-expert width
    vocab_size=202048,
    ffn_type="swiglu",
    rope_style="standard",
    rope_base=500000.0,
    moe=MoEConfig(num_experts=128, top_k=1, d_ff_expert=8192,
                  shared_experts=1, capacity_factor=1.25),
    norm_type="rmsnorm",
)
