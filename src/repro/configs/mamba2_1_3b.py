"""mamba2-1.3b [ssm] — SSD (state-space duality), attention-free
[arXiv:2405.21060]."""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="mamba2-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=0,                 # attention-free
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,                      # no FFN: the mamba block is the layer
    vocab_size=50280,
    ffn_type="none",
    rope_style="none",
    attention_pattern=("ssm",),
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, ngroups=1,
                  chunk=256),
    norm_type="rmsnorm",
    tie_embeddings=True,
    subquadratic=True,           # long_500k applies (constant state)
)
