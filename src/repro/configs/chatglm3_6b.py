"""chatglm3-6b [dense] — RoPE 2d (partial rotary), GQA kv=2
[arXiv:2406.12793; hf]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="chatglm3-6b",
    family="dense",
    num_layers=28,
    d_model=4096,
    num_heads=32,
    num_kv_heads=2,
    head_dim=128,
    d_ff=13696,
    vocab_size=65024,
    ffn_type="swiglu",
    rope_style="partial",        # ChatGLM "2d" RoPE: rotate half of head_dim
    rope_fraction=0.5,
    norm_type="rmsnorm",
)
