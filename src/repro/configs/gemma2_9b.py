"""gemma2-9b [dense] — local+global alternating attention, logit softcap
[arXiv:2408.00118; hf]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-9b",
    family="dense",
    num_layers=42,
    d_model=3584,
    num_heads=16,
    num_kv_heads=8,
    head_dim=256,
    d_ff=14336,
    vocab_size=256000,
    ffn_type="geglu",
    rope_style="standard",
    attention_pattern=("local", "global"),   # 1:1 alternation, local first
    window_size=4096,
    attn_softcap=50.0,
    logit_softcap=30.0,
    tie_embeddings=True,
    norm_type="rmsnorm",
)
