"""Assigned input-shape set (LM transformer shapes, seq_len x global_batch).

``decode_*`` / ``long_*`` lower ``serve_step`` (one new token against a KV
cache of seq_len); ``train_*`` lowers ``train_step``; ``prefill_*`` lowers
``prefill_step``.
"""
from repro.configs.base import ShapeConfig

SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", seq_len=4096, global_batch=256,
                            kind="train"),
    "prefill_32k": ShapeConfig("prefill_32k", seq_len=32768, global_batch=32,
                               kind="prefill"),
    "decode_32k": ShapeConfig("decode_32k", seq_len=32768, global_batch=128,
                              kind="decode"),
    "long_500k": ShapeConfig("long_500k", seq_len=524288, global_batch=1,
                             kind="decode"),
}


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]
