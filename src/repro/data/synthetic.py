"""Deterministic synthetic data pipeline.

Offline container: no downloads.  Streams are reproducible functions of
(seed, step) so a restarted job resumes bit-identically mid-epoch — the
property the fault-tolerance tests rely on.  Provides:

* token streams with learnable structure (orderk Markov-ish mixing so a
  real model actually reduces loss),
* image batches shaped like MNIST / CIFAR-10 for the paper's nets,
* sharded global-batch placement helpers.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenStreamConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0


def token_batch(cfg: TokenStreamConfig, step: int) -> dict:
    """Deterministic (tokens, labels) for ``step``.

    Structure: tokens follow x[t+1] = (a * x[t] + b_t) % V with slowly
    varying b — next-token prediction is learnable, so smoke-training
    shows a falling loss."""
    key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)
    b, s, v = cfg.global_batch, cfg.seq_len, cfg.vocab_size
    k1, k2, k3 = jax.random.split(key, 3)
    x0 = jax.random.randint(k1, (b, 1), 0, v)
    a = jax.random.randint(k2, (b, 1), 1, 8)
    drift = jax.random.randint(k3, (b, 1), 0, 4)

    def step_fn(x, t):
        nxt = (a[:, 0] * x + drift[:, 0] + t % 3) % v
        return nxt, nxt

    _, seq = jax.lax.scan(step_fn, x0[:, 0], jnp.arange(s))
    toks = jnp.concatenate([x0, seq.T], axis=1)       # (B, S+1)
    return {"tokens": toks[:, :-1].astype(jnp.int32),
            "labels": toks[:, 1:].astype(jnp.int32)}


def embed_batch(key: jax.Array, batch: int, seq: int, d: int,
                dtype=jnp.bfloat16) -> jax.Array:
    """Stub-frontend embeddings (vision/audio) — unit-variance."""
    return jax.random.normal(key, (batch, seq, d), dtype)


def image_batch(key: jax.Array, batch: int, hw: tuple[int, int], c: int
                ) -> jax.Array:
    """uint8 images shaped like MNIST/CIFAR for the paper's nets."""
    return jax.random.randint(key, (batch, *hw, c), 0, 256,
                              dtype=jnp.int32).astype(jnp.uint8)


class TokenLoader:
    """Stateful iterator over ``token_batch`` with checkpointable cursor."""

    def __init__(self, cfg: TokenStreamConfig, start_step: int = 0):
        self.cfg = cfg
        self.step = start_step

    def __iter__(self):
        return self

    def __next__(self) -> dict:
        batch = token_batch(self.cfg, self.step)
        self.step += 1
        return batch

    def state_dict(self) -> dict:
        return {"step": self.step}

    def load_state_dict(self, d: dict) -> None:
        self.step = int(d["step"])
