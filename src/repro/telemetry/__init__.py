"""Telemetry: the repo's one observability layer (dependency-free).

Three pieces (see ``docs/observability.md`` for the full taxonomy):

* :mod:`repro.telemetry.metrics` — counters / gauges / histograms with
  snapshot, reset, and merge (``MetricsRegistry``).
* :mod:`repro.telemetry.trace` — nestable spans with Chrome
  ``trace_event`` export, near-zero cost when disabled (``Tracer``).
* :mod:`repro.telemetry.probes` — STATIC cost probes (kernel-launch
  counts, grids, collective bytes) gated against
  ``experiments/PROBES_baseline.json``.  Imported explicitly (it pulls
  in jax + the models); never imported from here.

:class:`Telemetry` bundles a registry + tracer; the serving layer owns
one per ``PackedInferenceServer`` (isolated, testable), while
module-level hot seams that have no object to hang telemetry on
(``kernels.ops.dispatch_batch``, the sharded-forward gathers) write to
the process-wide :func:`default` instance.
"""
from __future__ import annotations

from repro.telemetry.metrics import (LATENCY_BUCKETS_S, Counter, Gauge,
                                     Histogram, MetricsRegistry,
                                     log_spaced_buckets)
from repro.telemetry.trace import Tracer

__all__ = ["Counter", "Gauge", "Histogram", "LATENCY_BUCKETS_S",
           "MetricsRegistry", "Telemetry", "Tracer", "default",
           "log_spaced_buckets", "set_default"]


class Telemetry:
    """One metrics registry + one tracer, wired together.

    The registry is always live (a counter bump is a few dict/int ops);
    the tracer starts disabled and costs one attribute check per span
    until :meth:`enable_tracing` is called.
    """

    def __init__(self, *, metrics: MetricsRegistry | None = None,
                 tracer: Tracer | None = None):
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.tracer = tracer if tracer is not None else Tracer()

    def span(self, name: str, **args):
        return self.tracer.span(name, **args)

    def enable_tracing(self) -> "Telemetry":
        self.tracer.enable()
        return self


_default = Telemetry()


def default() -> Telemetry:
    """The process-wide instance used by module-level seams (kernel
    dispatch counters, sharded gather counters, trace-time stage spans)."""
    return _default


def set_default(tel: Telemetry) -> Telemetry:
    """Swap the process-wide instance (tests); returns the previous one."""
    global _default
    prev, _default = _default, tel
    return prev
