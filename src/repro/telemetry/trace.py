"""Span tracer with Chrome ``trace_event`` export (Perfetto-loadable).

    tracer = Tracer(enabled=True)
    with tracer.span("flush", batch=4):
        with tracer.span("pack"):
            ...
    tracer.export("trace.json")        # open in https://ui.perfetto.dev

Design points:

* **Near-zero cost when disabled** — ``span()`` returns one shared
  no-op context manager without allocating; the only work on the
  disabled path is an attribute check.  The serving layer leaves its
  tracer disabled by default (``BENCH_serve.json`` carries the measured
  enabled-vs-disabled overhead).
* **Nestable** — spans are emitted as Chrome ``"ph": "X"`` (complete)
  events with microsecond ``ts``/``dur``; Perfetto reconstructs nesting
  per thread from the timestamps, so plain ``with`` nesting renders as
  a flame stack.
* **Bounded** — at ``max_events`` the tracer stops recording and counts
  drops (``tracer.dropped``) instead of growing without bound; a
  long-running server cannot leak its trace buffer.
* **Explicit-time spans** — ``add_complete(name, t0_ns, t1_ns)`` emits
  a span whose endpoints were captured earlier with ``now_ns()``; the
  serving queue uses it for per-flush queue-wait spans (submit time →
  flush start) without holding a context manager open across calls.

The wall clock is ``time.perf_counter_ns`` (injectable for tests) and
is independent of any simulated serving clock.
"""
from __future__ import annotations

import json
import threading
import time
from typing import Callable


class _NoopSpan:
    """Shared disabled-path context manager: no allocation per span."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NOOP = _NoopSpan()


class _Span:
    __slots__ = ("_tracer", "_name", "_args", "_t0")

    def __init__(self, tracer: "Tracer", name: str, args: dict):
        self._tracer = tracer
        self._name = name
        self._args = args

    def __enter__(self):
        self._t0 = self._tracer._clock()
        return self

    def __exit__(self, *exc):
        self._tracer.add_complete(self._name, self._t0,
                                  self._tracer._clock(), **self._args)
        return False


class Tracer:
    """Collects Chrome-trace events; see module docstring."""

    def __init__(self, *, enabled: bool = False, max_events: int = 200_000,
                 clock_ns: Callable[[], int] = time.perf_counter_ns):
        self.enabled = enabled
        self.max_events = max_events
        self._clock = clock_ns
        self._lock = threading.Lock()
        self._events: list[dict] = []
        self.dropped = 0

    # -- recording ---------------------------------------------------------

    def enable(self) -> None:
        self.enabled = True

    def disable(self) -> None:
        self.enabled = False

    def now_ns(self) -> int:
        """Tracer-clock timestamp for later :meth:`add_complete`."""
        return self._clock()

    def span(self, name: str, **args):
        """Context manager timing its body.  Disabled tracer: a shared
        no-op (near-zero cost)."""
        if not self.enabled:
            return _NOOP
        return _Span(self, name, args)

    def add_complete(self, name: str, t0_ns: int, t1_ns: int,
                     **args) -> None:
        """Emit one complete ("X") span from explicit tracer-clock
        endpoints (no-op while disabled)."""
        if not self.enabled:
            return
        self._append({"name": name, "ph": "X", "ts": t0_ns / 1e3,
                      "dur": max(0.0, (t1_ns - t0_ns) / 1e3),
                      "pid": 0, "tid": threading.get_ident() % 100_000,
                      **({"args": args} if args else {})})

    def instant(self, name: str, **args) -> None:
        """Point-in-time event ("i" phase)."""
        if not self.enabled:
            return
        self._append({"name": name, "ph": "i", "s": "t",
                      "ts": self._clock() / 1e3, "pid": 0,
                      "tid": threading.get_ident() % 100_000,
                      **({"args": args} if args else {})})

    def _append(self, event: dict) -> None:
        with self._lock:
            if len(self._events) >= self.max_events:
                self.dropped += 1
                return
            self._events.append(event)

    # -- export ------------------------------------------------------------

    @property
    def events(self) -> list[dict]:
        with self._lock:
            return list(self._events)

    def span_names(self) -> list[str]:
        return sorted({e["name"] for e in self.events})

    def clear(self) -> None:
        with self._lock:
            self._events.clear()
            self.dropped = 0

    def chrome_trace(self) -> dict:
        """The Chrome ``trace_event`` JSON object (Perfetto-loadable)."""
        return {"traceEvents": self.events, "displayTimeUnit": "ms"}

    def export(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f)
