"""Metrics registry: counters, gauges, and log-bucketed histograms.

Dependency-free and thread-safe — the serving queue mutates counters
from whatever thread drives ``step()`` while a reporter thread can
``snapshot()`` concurrently.  Three instrument kinds:

* :class:`Counter` — monotone event count (``serve.cache.hits``,
  ``serve.route.gemv``).
* :class:`Gauge` — last-written value (``serve.queue_depth``).
* :class:`Histogram` — fixed log-spaced buckets (default: the shared
  latency ladder :data:`LATENCY_BUCKETS_S`, 1 µs … 100 s, 4 buckets per
  decade).  Bucket edges are FIXED at construction so snapshots from
  different processes/runs merge exactly (``MetricsRegistry.merge``).

``snapshot()`` returns plain dicts (JSON-able — the ``telemetry``
section of ``experiments/BENCH_serve.json`` is one), ``reset()`` zeroes
every instrument in place, and ``merge()`` folds another snapshot in:
counters/histograms add, gauges take the merged-in value.

Metric NAMES are dotted paths; the taxonomy the repo emits is listed in
``docs/observability.md``.
"""
from __future__ import annotations

import threading


def log_spaced_buckets(lo: float = 1e-6, hi: float = 100.0,
                       per_decade: int = 4) -> tuple[float, ...]:
    """Log-spaced bucket upper edges covering [lo, hi].

    Edges are generated as exact powers ``lo * 10**(i/per_decade)`` and
    rounded to 6 significant digits so two processes always agree on
    them bit-for-bit (merge compatibility).
    """
    if lo <= 0 or hi <= lo or per_decade < 1:
        raise ValueError(f"bad bucket spec ({lo}, {hi}, {per_decade})")
    edges = []
    i = 0
    while True:
        e = float(f"{lo * 10 ** (i / per_decade):.6g}")
        edges.append(e)
        if e >= hi:
            return tuple(edges)
        i += 1


#: The one latency bucket ladder every histogram in the repo defaults
#: to: 1 µs … 100 s, 4 buckets per decade (33 buckets + overflow).
LATENCY_BUCKETS_S = log_spaced_buckets(1e-6, 100.0, 4)


class Counter:
    """Monotone counter.  ``inc`` rejects negative deltas."""

    def __init__(self, lock: threading.Lock):
        self._lock = lock
        self._value = 0

    def inc(self, n: int = 1) -> None:
        if n < 0:
            raise ValueError(f"counter increment must be >= 0, got {n}")
        with self._lock:
            self._value += n

    @property
    def value(self) -> int:
        return self._value

    def _snapshot(self) -> dict:
        return {"type": "counter", "value": self._value}

    def _reset(self) -> None:
        with self._lock:
            self._value = 0

    def _merge(self, snap: dict) -> None:
        with self._lock:
            self._value += int(snap["value"])


class Gauge:
    """Last-written value (float)."""

    def __init__(self, lock: threading.Lock):
        self._lock = lock
        self._value = 0.0

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)

    @property
    def value(self) -> float:
        return self._value

    def _snapshot(self) -> dict:
        return {"type": "gauge", "value": self._value}

    def _reset(self) -> None:
        with self._lock:
            self._value = 0.0

    def _merge(self, snap: dict) -> None:
        with self._lock:
            self._value = float(snap["value"])


class Histogram:
    """Fixed-bucket histogram with exact count/sum/min/max.

    ``buckets`` are UPPER edges; one implicit overflow bucket catches
    everything above the last edge.  ``percentile(q)`` is nearest-rank
    over the bucket counts and returns the covering bucket's upper edge
    (the exact observed max for the overflow bucket) — an upper bound on
    the true percentile, same spirit as Prometheus ``histogram_quantile``
    but rank-based so a single observation reports itself exactly when
    it lands alone in a bucket ladder.
    """

    def __init__(self, lock: threading.Lock,
                 buckets: tuple[float, ...] = LATENCY_BUCKETS_S):
        if not buckets or list(buckets) != sorted(set(buckets)):
            raise ValueError(f"buckets must be sorted and unique: {buckets}")
        self._lock = lock
        self.buckets = tuple(float(b) for b in buckets)
        self._counts = [0] * (len(self.buckets) + 1)
        self.count = 0
        self.sum = 0.0
        self.min = None
        self.max = None

    def observe(self, v: float) -> None:
        v = float(v)
        with self._lock:
            i = self._bucket_index(v)
            self._counts[i] += 1
            self.count += 1
            self.sum += v
            self.min = v if self.min is None else min(self.min, v)
            self.max = v if self.max is None else max(self.max, v)

    def _bucket_index(self, v: float) -> int:
        lo, hi = 0, len(self.buckets)
        while lo < hi:                       # first edge >= v
            mid = (lo + hi) // 2
            if self.buckets[mid] >= v:
                hi = mid
            else:
                lo = mid + 1
        return lo                            # == len(buckets) -> overflow

    @property
    def counts(self) -> tuple[int, ...]:
        return tuple(self._counts)

    def percentile(self, q: float) -> float:
        if self.count == 0:
            raise ValueError("percentile of an empty histogram")
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"q must be in [0, 1], got {q}")
        rank = min(self.count - 1, int(self.count * q))
        seen = 0
        for i, c in enumerate(self._counts):
            seen += c
            if seen > rank:
                if i < len(self.buckets):
                    return self.buckets[i]
                return self.max              # overflow: exact observed max
        raise AssertionError("unreachable: counts/count disagree")

    def _snapshot(self) -> dict:
        with self._lock:
            return {"type": "histogram", "count": self.count,
                    "sum": self.sum, "min": self.min, "max": self.max,
                    "buckets": list(self.buckets),
                    "counts": list(self._counts)}

    def _reset(self) -> None:
        with self._lock:
            self._counts = [0] * (len(self.buckets) + 1)
            self.count = 0
            self.sum = 0.0
            self.min = None
            self.max = None

    def _merge(self, snap: dict) -> None:
        if list(snap["buckets"]) != list(self.buckets):
            raise ValueError(
                "cannot merge histograms with different bucket edges")
        with self._lock:
            for i, c in enumerate(snap["counts"]):
                self._counts[i] += int(c)
            self.count += int(snap["count"])
            self.sum += float(snap["sum"])
            for k, pick in (("min", min), ("max", max)):
                other = snap[k]
                if other is None:
                    continue
                mine = getattr(self, k)
                setattr(self, k, other if mine is None
                        else pick(mine, other))


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class MetricsRegistry:
    """Named instruments, created on first touch.

    ``counter(name)`` / ``gauge(name)`` / ``histogram(name)`` get-or-
    create; asking for an existing name with a different kind raises
    (one name, one meaning).  All instruments share the registry's lock.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: dict[str, object] = {}

    def _get(self, name: str, kind: str, factory):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = factory()
                self._metrics[name] = m
            elif not isinstance(m, _KINDS[kind]):
                raise TypeError(
                    f"metric {name!r} already registered as "
                    f"{type(m).__name__}, not {kind}")
            return m

    def counter(self, name: str) -> Counter:
        return self._get(name, "counter", lambda: Counter(self._lock))

    def gauge(self, name: str) -> Gauge:
        return self._get(name, "gauge", lambda: Gauge(self._lock))

    def histogram(self, name: str,
                  buckets: tuple[float, ...] = LATENCY_BUCKETS_S
                  ) -> Histogram:
        return self._get(name, "histogram",
                         lambda: Histogram(self._lock, buckets))

    def value(self, name: str):
        """Convenience read: counter/gauge value, histogram count; 0 for
        a name nothing has touched yet (absence == nothing happened)."""
        m = self._metrics.get(name)
        if m is None:
            return 0
        return m.count if isinstance(m, Histogram) else m.value

    def names(self) -> list[str]:
        return sorted(self._metrics)

    def snapshot(self) -> dict[str, dict]:
        """JSON-able {name: {"type": ..., ...}} of every instrument."""
        with self._lock:
            items = list(self._metrics.items())
        return {name: m._snapshot() for name, m in items}

    def reset(self) -> None:
        with self._lock:
            items = list(self._metrics.values())
        for m in items:
            m._reset()

    def merge(self, snapshot: dict[str, dict]) -> None:
        """Fold another registry's ``snapshot()`` into this one:
        counters and histograms add, gauges take the merged value.
        Instruments absent here are created with the snapshot's kind."""
        for name, snap in snapshot.items():
            kind = snap["type"]
            if kind == "histogram":
                m = self.histogram(name, tuple(snap["buckets"]))
            elif kind == "gauge":
                m = self.gauge(name)
            elif kind == "counter":
                m = self.counter(name)
            else:
                raise ValueError(f"unknown metric type {kind!r} ({name})")
            m._merge(snap)
