"""Static cost probes: standing regression gates on what a forward
*lowers to*, independent of wall-clock noise.

Each probe cell traces a registered model config and records only
machine-independent facts:

* kernel-launch count + per-launch kernel name and grid shape
  (``analysis.pallas_launches`` — the per-PR "traces to exactly 1
  pallas_call" asserts, turned into a committed baseline);
* the GEMV-vs-GEMM route ``kernels.ops.dispatch_batch`` picks for the
  cell's batch;
* the largest HBM intermediate (bytes + shape) — the fused-epilogue
  contract that packed activations never unpack between stages;
* for sharded cells: per-device collective wire bytes and kinds from
  the compiled HLO (``analysis.collectives.analyze_hlo``) on a
  forced-8-CPU (4, 2) mesh — all-gather-only, byte-stable.

This module is a thin consumer of the static-analysis subsystem
(``repro.analysis`` — see ``docs/analysis.md``); the deeper invariants
(packedness dataflow, VMEM budgets, lint) are gated separately by
``python -m repro.analysis --check``.

The canonical cells cover the shared demo configs
(``models.cnn.demo_model(smoke=True)`` — the same shapes the serving
CLI and bench use) at serving-relevant batches.  CI runs

    PYTHONPATH=src python -m repro.telemetry.probes --check

and fails on ANY drift against ``experiments/PROBES_baseline.json``;
after an intentional kernel/grid/collective change, regenerate with
``--write`` and commit the diff (see ``docs/observability.md``).

Importing this module never mutates the environment.  The sharded
cells need 8 devices: ``main()`` re-execs itself in a subprocess with
``REPRO_PROBES_FORCE_DEVICES=8`` when the current process has fewer
(the env knob below must act before jax's first import, which is only
guaranteed in the fresh process).
"""
from __future__ import annotations

import os
import sys

if os.environ.get("REPRO_PROBES_FORCE_DEVICES") and "jax" not in sys.modules:
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") +
        " --xla_force_host_platform_device_count=" +
        os.environ["REPRO_PROBES_FORCE_DEVICES"])

import argparse
import json
import subprocess

import numpy as np

SHARDED_MESH = (4, 2)
SHARDED_DEVICES = SHARDED_MESH[0] * SHARDED_MESH[1]


def repo_root() -> str:
    return os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))))


BASELINE_PATH = os.path.join("experiments", "PROBES_baseline.json")


# ---------------------------------------------------------------------------
# probe cells
# ---------------------------------------------------------------------------

def probe_forward(packed, batch: int, *, backend: str = "pallas",
                  dense_stack: str = "auto") -> dict:
    """Static cost report for one packed forward at one batch size.

    Pure tracing — no kernel executes (``jax.make_jaxpr``), so the
    pallas backend is cheap to probe even off-TPU.
    """
    from repro.analysis import max_intermediate_bytes, pallas_launches
    from repro.kernels import ops as kops
    from repro.models import cnn

    fwd = cnn.make_packed_forward(packed, backend=backend,
                                  dense_stack=dense_stack)
    x = np.zeros((batch, *cnn.packed_input_shape(packed)), np.uint8)
    launches = pallas_launches(lambda a: fwd(a), x)
    nbytes, shape = max_intermediate_bytes(lambda a: fwd(a), x)
    return {
        "kind": cnn.packed_kind(packed), "batch": batch, "backend": backend,
        "launch_count": len(launches),
        "launches": [{"kernel": ln.kernel, "grid": list(ln.grid)}
                     for ln in launches],
        "route": kops.dispatch_batch(batch,
                                     cnn.packed_dense_kw_words(packed)),
        "max_intermediate_bytes": int(nbytes),
        "max_intermediate_shape": list(shape),
    }


def probe_sharded(packed, batch: int, *,
                  mesh_shape: tuple[int, int] = SHARDED_MESH) -> dict:
    """Collective-traffic report for one packed forward on a (data,
    model) mesh: wire bytes + collective kinds from the compiled HLO,
    plus the per-stage shard plan.  Requires ``prod(mesh_shape)``
    devices (CI forces host devices; see module docstring)."""
    from repro.analysis.collectives import analyze_hlo
    from repro.distributed import sharding as SH
    from repro.launch.mesh import make_mesh
    from repro.models import cnn

    mesh = make_mesh(mesh_shape, ("data", "model"))
    fwd = SH.make_sharded_forward(packed, mesh, backend="jnp")
    x = np.zeros((batch, *cnn.packed_input_shape(packed)), np.uint8)
    kinds, by_kind = analyze_hlo(fwd.lower(x).compile().as_text())
    return {
        "kind": fwd.kind, "mesh": list(mesh_shape), "batch": batch,
        "shard_plan": {k: list(v) for k, v in fwd.shard_plan.items()},
        "collective_bytes": float(by_kind.get("total", 0.0)),
        "collective_kinds": kinds,
    }


def _demo_packed(kind: str):
    from repro.analysis.report import demo_packed

    return demo_packed(kind)


def standard_report(*, sharded: bool = True) -> dict:
    """The committed probe cells: both demo networks and the reduced
    gemma2 binary LM at the GEMV (≤ 8) and GEMM (> 8) serving batches,
    plus the (4, 2)-mesh collective cells (bmlp/bcnn only — the
    sharding rules don't cover the transformer workload).  Keys are
    stable — they ARE the baseline diff surface."""
    cells = {}
    for kind in ("bmlp", "bcnn", "transformer"):
        packed = _demo_packed(kind)
        for batch in (1, 8, 32):
            cells[f"{kind}/b{batch}"] = probe_forward(packed, batch)
        if sharded and kind != "transformer":
            cells[f"sharded/{kind}_{SHARDED_MESH[0]}x{SHARDED_MESH[1]}"] = \
                probe_sharded(packed, batch=8)
    return {"schema": 1, "cells": cells}


# ---------------------------------------------------------------------------
# baseline diff (shared with the analysis baseline gate)
# ---------------------------------------------------------------------------

from repro.analysis.report import diff_reports  # noqa: E402  (re-export)


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def _respawn_with_devices(argv: list[str]) -> int:
    env = dict(os.environ)
    env["REPRO_PROBES_FORCE_DEVICES"] = str(SHARDED_DEVICES)
    env.pop("XLA_FLAGS", None)          # the child derives its own
    env["PYTHONPATH"] = (os.path.join(repo_root(), "src") + os.pathsep +
                         env.get("PYTHONPATH", ""))
    proc = subprocess.run(
        [sys.executable, "-m", "repro.telemetry.probes", *argv],
        env=env, cwd=repo_root())
    return proc.returncode


def main(argv: list[str] | None = None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--write", action="store_true",
                    help="regenerate the committed baseline")
    ap.add_argument("--check", action="store_true",
                    help="diff against the baseline; exit 1 on drift")
    ap.add_argument("--json", action="store_true",
                    help="print the full report as JSON")
    ap.add_argument("--no-sharded", action="store_true",
                    help="skip the collective cells (no 8-device need)")
    ap.add_argument("--baseline",
                    default=os.path.join(repo_root(), BASELINE_PATH))
    args = ap.parse_args(argv)

    sharded = not args.no_sharded
    if sharded:
        import jax
        if len(jax.devices()) < SHARDED_DEVICES and \
                not os.environ.get("REPRO_PROBES_FORCE_DEVICES"):
            return _respawn_with_devices(argv)

    report = standard_report(sharded=sharded)
    if args.json:
        print(json.dumps(report, indent=1))
    if args.write:
        os.makedirs(os.path.dirname(args.baseline), exist_ok=True)
        with open(args.baseline, "w") as f:
            json.dump(report, f, indent=1, sort_keys=True)
        print(f"wrote {len(report['cells'])} probe cells -> "
              f"{args.baseline}")
    if args.check:
        with open(args.baseline) as f:
            baseline = json.load(f)
        if args.no_sharded:                 # compare only what we probed
            baseline = {"schema": baseline["schema"],
                        "cells": {k: v
                                  for k, v in baseline["cells"].items()
                                  if k in report["cells"]}}
        drift = diff_reports(baseline, report)
        if drift:
            print(f"PROBE DRIFT vs {args.baseline} "
                  f"({len(drift)} differences):")
            for line in drift:
                print(f"  {line}")
            print("If intentional, regenerate: "
                  "PYTHONPATH=src python -m repro.telemetry.probes --write")
            return 1
        print(f"probes match baseline ({len(report['cells'])} cells)")
    if not (args.json or args.write or args.check):
        for name, cell in report["cells"].items():
            if "launch_count" in cell:
                print(f"{name}: {cell['launch_count']} launches "
                      f"route={cell['route']} "
                      f"max_intermediate={cell['max_intermediate_bytes']}B "
                      f"{cell['max_intermediate_shape']}")
            else:
                print(f"{name}: collectives={cell['collective_kinds']} "
                      f"{cell['collective_bytes']:.0f}B "
                      f"plan={cell['shard_plan']}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
