import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware:

    with mesh:
        lowered  = jax.jit(step, in_shardings=..., out_shardings=...)\
                      .lower(**input_specs(arch))
        compiled = lowered.compile()
        compiled.memory_analysis()   # fits?
        compiled.cost_analysis()     # FLOPs/bytes for the roofline

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch starcoder2-3b \
        --shape decode_32k [--multi-pod] [--quant binary] [--out DIR]
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]

Results land in JSON (one file per cell) read by benchmarks/roofline.py
and EXPERIMENTS.md.  NOTE: the XLA_FLAGS line above must execute before
ANY other import touches jax — keep it the first statement.
"""
import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config, get_shape, list_configs
from repro.configs.base import ArchConfig, ShapeConfig
from repro.distributed import sharding as SH
from repro.launch import specs as SP
from repro.launch.mesh import make_production_mesh
from repro.models import model as M
from repro.train import serve as SV
from repro.train import trainer as TR

# --------------------------------------------------------------------------
# cell applicability (DESIGN.md §7)
# --------------------------------------------------------------------------


def cell_skip_reason(cfg: ArchConfig, shape: ShapeConfig) -> str | None:
    if shape.name == "long_500k" and not cfg.subquadratic:
        return ("pure full-attention arch: long_500k requires "
                "sub-quadratic attention (unbounded KV at 524288); run "
                "for SSM/hybrid only")
    return None


# --------------------------------------------------------------------------
# per-kind lowering
# --------------------------------------------------------------------------


def _microbatches(cfg: ArchConfig, shape: ShapeConfig) -> int:
    """Grad-accum depth so one microbatch of activations fits HBM.

    Napkin: live set ~ L_scan_carry + per-layer saved inputs =
    B_mb*S*D*2bytes * L; target <= ~2 GB/chip => B_mb*S*D*L <= 1e9/chip.
    """
    tokens = shape.global_batch * shape.seq_len
    act_bytes_per_chip = tokens * cfg.d_model * 2 * cfg.num_layers // 256
    target = 4 << 30
    mb = 1
    while mb < shape.global_batch and act_bytes_per_chip // mb > target:
        mb *= 2
    return min(mb, 8)


def build_train_step(cfg: ArchConfig, shape: ShapeConfig, mesh,
                     opts: dict | None = None):
    opts = opts or {}
    tc = TR.TrainConfig(microbatches=_microbatches(cfg, shape),
                        grads_bf16=opts.get("grads_bf16", False))
    step = TR.make_train_step(cfg, tc)
    state_shape = jax.eval_shape(
        lambda: TR.init_train_state(jax.random.PRNGKey(0), cfg, tc))
    batch = SP.train_batch_specs(cfg, shape)

    fsdp = opts.get("fsdp")
    if fsdp is None:
        fsdp = True                       # baseline: always FSDP
    elif fsdp == "auto":
        fsdp = SH.should_fsdp(cfg, mesh)
    fsdp = bool(fsdp)
    pspecs = SH.param_specs(state_shape["params"], mesh, fsdp=fsdp,
                            replicate_embed=opts.get("replicate_embed",
                                                     False))
    state_specs = {"params": pspecs,
                   "opt": {"mu": pspecs, "nu": pspecs, "step": P()}}
    batch_specs = SH.batch_specs(batch, mesh)
    in_shardings = (jax.tree.map(lambda s: NamedSharding(mesh, s),
                                 state_specs,
                                 is_leaf=lambda x: isinstance(x, P)),
                    jax.tree.map(lambda s: NamedSharding(mesh, s),
                                 batch_specs,
                                 is_leaf=lambda x: isinstance(x, P)))
    fn = jax.jit(step, in_shardings=in_shardings,
                 donate_argnums=(0,))
    return fn, (state_shape, batch)


def _params_shape(cfg: ArchConfig):
    """eval_shape of the (possibly packed — paper C2) inference params.

    Deployment casts fp32 master weights to bf16 (halves serving HBM);
    packed uint32 words and integer leaves pass through."""
    from repro.core.quantize import QuantMode
    from repro.models import linear as LNmod

    def mk():
        p = M.init_model(jax.random.PRNGKey(0), cfg)
        if cfg.quant.mode != QuantMode.FLOAT:
            p = LNmod.maybe_pack_tree(p, cfg.quant)
        return jax.tree.map(
            lambda a: a.astype(jnp.bfloat16)
            if a.dtype == jnp.float32 else a, p)

    return jax.eval_shape(mk)


def build_prefill_step(cfg: ArchConfig, shape: ShapeConfig, mesh,
                       opts: dict | None = None):
    opts = opts or {}
    step = SV.make_prefill_step(cfg, max_len=shape.seq_len)
    params_shape = _params_shape(cfg)
    batch = SP.prefill_batch_specs(cfg, shape)
    pspecs = SH.param_specs(params_shape, mesh,
                            fsdp=opts.get("fsdp", True) is not False)
    bspecs = SH.batch_specs(batch, mesh)
    in_shardings = (
        jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                     is_leaf=lambda x: isinstance(x, P)),
        jax.tree.map(lambda s: NamedSharding(mesh, s), bspecs,
                     is_leaf=lambda x: isinstance(x, P)))
    fn = jax.jit(step, in_shardings=in_shardings)
    return fn, (params_shape, batch)


def build_decode_step(cfg: ArchConfig, shape: ShapeConfig, mesh,
                      opts: dict | None = None):
    opts = opts or {}
    step = SV.make_decode_step(cfg)
    params_shape = _params_shape(cfg)
    shard_seq = shape.global_batch == 1
    cache_shape = jax.eval_shape(
        lambda: M.init_cache(
            jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                         params_shape),
            cfg, shape.global_batch, shape.seq_len))
    tokens = SP.decode_token_specs(shape)
    idx = jax.ShapeDtypeStruct((), jnp.int32)

    pspecs = SH.param_specs(params_shape, mesh,
                            fsdp=opts.get("fsdp", True) is not False)
    # production default: seq@model KV (GQA head counts rarely divide
    # TP=16; see §Perf cell A — 81x better and the cache actually fits).
    cspecs = SH.cache_specs(cache_shape, mesh, shard_seq=shard_seq,
                            kv_layout=opts.get("kv_layout", "seq_model"))
    tspecs = SH.batch_specs({"tokens": tokens}, mesh)["tokens"]
    ns = lambda tree: jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                                   is_leaf=lambda x: isinstance(x, P))
    in_shardings = (ns(pspecs), ns(cspecs), ns(tspecs),
                    NamedSharding(mesh, P()))
    fn = jax.jit(step, in_shardings=in_shardings, donate_argnums=(1,))
    return fn, (params_shape, cache_shape, tokens, idx)


# --------------------------------------------------------------------------
# collective-byte accounting from the partitioned HLO
# --------------------------------------------------------------------------

from repro.utils.hlo import collective_bytes  # noqa: F401  (re-export)


# --------------------------------------------------------------------------
# cell runner
# --------------------------------------------------------------------------


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             quant: str | None = None, out_dir: str = "experiments/dryrun",
             save_hlo: bool = False, analysis: bool = False,
             layers_override: int | None = None,
             opts: dict | None = None, tag: str = "") -> dict:
    from repro.utils.flags import analysis_mode
    opts = opts or {}
    cfg = get_config(arch, quant=quant)
    if opts.get("ssm_split"):
        import dataclasses as _dc
        if cfg.ssm is not None:
            cfg = _dc.replace(cfg, ssm=_dc.replace(cfg.ssm,
                                                   fused_proj=False))
    if opts.get("kv_int8"):
        cfg = dataclasses.replace(cfg, kv_cache_dtype="int8")
    if layers_override is not None:
        cfg = dataclasses.replace(cfg, num_layers=layers_override)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    record: dict = {
        "arch": arch, "shape": shape_name,
        "mesh": "x".join(map(str, mesh.devices.shape)),
        "axes": mesh.axis_names, "quant": quant or "float",
        "kind": shape.kind, "analysis": analysis,
        "layers_override": layers_override,
        "num_layers": cfg.num_layers,
        "opts": opts, "tag": tag,
    }
    skip = cell_skip_reason(cfg, shape)
    if skip:
        record["status"] = "skipped"
        record["skip_reason"] = skip
        _save(record, out_dir)
        return record

    builders = {"train": build_train_step, "prefill": build_prefill_step,
                "decode": build_decode_step}
    t0 = time.monotonic()
    with mesh, analysis_mode(analysis):
        fn, args = builders[shape.kind](cfg, shape, mesh, opts)
        lowered = fn.lower(*args)
        t_lower = time.monotonic() - t0
        t0 = time.monotonic()
        compiled = lowered.compile()
        t_compile = time.monotonic() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)

    record.update({
        "status": "ok",
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "flops_per_device": float(cost.get("flops", 0.0)),
        "bytes_per_device": float(cost.get("bytes accessed", 0.0)),
        "collective_bytes_per_device": coll,
        "memory": _mem_dict(mem),
        "param_counts": cfg.param_counts(),
    })
    if save_hlo:
        record["hlo_path"] = _save_hlo(hlo, record, out_dir)
    _save(record, out_dir)
    return record


def _mem_dict(mem) -> dict:
    keys = ["argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes"]
    out = {}
    for k in keys:
        v = getattr(mem, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def _cell_id(record: dict) -> str:
    base = (f"{record['arch']}__{record['shape']}__{record['mesh']}"
            f"__{record['quant']}")
    if record.get("analysis"):
        base += "__analysis"
    if record.get("layers_override"):
        base += f"__L{record['layers_override']}"
    if record.get("tag"):
        base += f"__{record['tag']}"
    return base


def _save(record: dict, out_dir: str) -> None:
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, _cell_id(record) + ".json")
    with open(path, "w") as f:
        json.dump(record, f, indent=1)
    print(f"[dryrun] {record['arch']:28s} {record['shape']:12s} "
          f"{record['mesh']:9s} {record['quant']:13s} "
          f"-> {record['status']}"
          + ("" if record["status"] != "ok" else
             f"  lower {record['lower_s']}s compile {record['compile_s']}s"
             f"  flops/dev {record['flops_per_device']:.3e}"))


def _save_hlo(hlo: str, record: dict, out_dir: str) -> str:
    path = os.path.join(out_dir, _cell_id(record) + ".hlo.txt")
    with open(path, "w") as f:
        f.write(hlo)
    return path


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_configs())
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--quant", default=None,
                    choices=[None, "float", "binary_weight", "binary"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--analysis", action="store_true",
                    help="unroll scans for faithful HLO op counts "
                         "(slow compile; roofline cells)")
    args = ap.parse_args()

    from repro.configs.shapes import SHAPES
    cells: list[tuple[str, str]] = []
    if args.all:
        for a in list_configs():
            for s in SHAPES:
                cells.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells.append((args.arch, args.shape))

    failures = []
    for a, s in cells:
        try:
            run_cell(a, s, multi_pod=args.multi_pod, quant=args.quant,
                     out_dir=args.out, save_hlo=args.save_hlo,
                     analysis=args.analysis)
        except Exception as e:  # noqa: BLE001 — report all cell failures
            failures.append((a, s, repr(e)))
            print(f"[dryrun] {a} {s} FAILED: {e!r}")
    if failures:
        raise SystemExit(f"{len(failures)} cells failed: "
                         + ", ".join(f"{a}/{s}" for a, s, _ in failures))


if __name__ == "__main__":
    main()
