"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch starcoder2-3b \
        --reduced --steps 50 [--quant binary] [--data 2 --model 1] \
        [--microbatches 2] [--ckpt-dir /tmp/ckpt] [--compress-grads]

Full-size configs target the production mesh (launch/dryrun.py proves
lowering); on this CPU container use --reduced for a real end-to-end run.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint import latest_step, load_checkpoint, save_checkpoint
from repro.configs import get_config
from repro.data.synthetic import TokenStreamConfig, token_batch
from repro.distributed import sharding as SH
from repro.launch.mesh import make_host_mesh
from repro.train import trainer as TR


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--quant", default=None)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--data", type=int, default=1)
    ap.add_argument("--model", type=int, default=1)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--lr", type=float, default=1e-3)
    args = ap.parse_args()

    cfg = get_config(args.arch, quant=args.quant, reduced=args.reduced)
    tc = TR.TrainConfig(microbatches=args.microbatches,
                        compress_grads=args.compress_grads, lr=args.lr,
                        warmup=5, total_steps=args.steps)
    mesh = make_host_mesh(args.data, args.model)
    print(f"mesh {dict(mesh.shape)} arch {cfg.name} quant "
          f"{cfg.quant.mode.value}")

    state = TR.init_train_state(jax.random.PRNGKey(0), cfg, tc)
    pspecs = SH.param_specs(state["params"], mesh)
    state_specs = {"params": pspecs,
                   "opt": {"mu": pspecs, "nu": pspecs, "step": P()}}
    if tc.compress_grads:
        state_specs["ef_error"] = pspecs
    ns = lambda tree: jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                                   is_leaf=lambda x: isinstance(x, P))
    state = jax.device_put(state, ns(state_specs))

    dcfg = TokenStreamConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                             global_batch=args.batch)
    step_fn = jax.jit(TR.make_train_step(cfg, tc), donate_argnums=(0,))

    start = 0
    if args.ckpt_dir:
        last = latest_step(args.ckpt_dir)
        if last is not None:
            state, meta = load_checkpoint(args.ckpt_dir, last, state,
                                          ns(state_specs))
            start = int(meta["step"]) + 1
            print(f"restored step {last}")

    t0 = time.monotonic()
    with mesh:
        for i in range(start, args.steps):
            batch = token_batch(dcfg, i)
            bspecs = SH.batch_specs(batch, mesh)
            batch = jax.device_put(batch, ns(bspecs))
            state, metrics = step_fn(state, batch)
            if i % 5 == 0 or i == args.steps - 1:
                print(f"step {i:4d} loss {float(metrics['loss']):.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"lr {float(metrics['lr']):.2e}")
            if args.ckpt_dir and (i + 1) % args.ckpt_every == 0:
                save_checkpoint(args.ckpt_dir, i, state)
    dt = time.monotonic() - t0
    print(f"done: {args.steps - start} steps in {dt:.1f}s "
          f"({(args.steps - start) / max(dt, 1e-9):.2f} it/s)")


if __name__ == "__main__":
    main()
