"""Production mesh builders.

Functions, not module-level constants — importing this module never
touches jax device state (the dry-run sets XLA_FLAGS before first init).
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]) -> Mesh:
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Small mesh over whatever devices exist (tests)."""
    n = len(jax.devices())
    data = min(data, n)
    model = min(model, n // data) or 1
    return jax.make_mesh((data, model), ("data", "model"))
