"""Packed-inference serving launcher (the Espresso prediction phase).

Builds a reduced BCNN/BMLP with random weights, registers it with the
``train.serve.PackedInferenceServer`` (pack + fold BN ONCE via the
weight cache), replays a deterministic arrival trace against the
continuous-batching queue, and prints per-request p50/p99 latency,
throughput, and the GEMV/GEMM route of every flush:

    PYTHONPATH=src python -m repro.launch.serve --model bmlp \
        --requests 32 --max-batch 8 --deadline-ms 5

    # a (data, model) mesh behind the queue (forced host devices):
    PYTHONPATH=src python -m repro.launch.serve --model bcnn --mesh 2,2

    # CI smoke: tiny shapes, few requests
    PYTHONPATH=src python -m repro.launch.serve --model bmlp --smoke

The old LM prefill/decode demo lives in ``examples/serve_binary_lm.py``
(the ``BatchedServer`` driver).
"""
from __future__ import annotations

import os
import sys

# Forced host devices must be set before ANY jax import (same pattern as
# distributed/verify_sharded.py): pre-scan argv for --mesh, in both the
# space-separated ("--mesh 2,2") and equals ("--mesh=2,2") forms.
def _prescan_mesh(argv: list[str]) -> str | None:
    for i, a in enumerate(argv):
        if a == "--mesh" and i + 1 < len(argv):
            return argv[i + 1]
        if a.startswith("--mesh="):
            return a.split("=", 1)[1]
    return None


_shape = _prescan_mesh(sys.argv)
if _shape is not None:
    try:
        _n = 1
        for _d in _shape.split(","):
            _n *= int(_d)
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "") +
            f" --xla_force_host_platform_device_count={_n}")
    except ValueError:
        pass                                    # argparse will complain

import argparse
import json
import statistics
import time

import numpy as np

from repro.models import cnn
from repro.train import serve as SV


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", choices=("bcnn", "bmlp"), default="bmlp")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--deadline-ms", type=float, default=5.0)
    ap.add_argument("--arrival-ms", type=float, default=0.0,
                    help="inter-arrival gap (0 = back-to-back)")
    ap.add_argument("--backend", default="jnp",
                    help="'jnp' | 'pallas' | 'ref' | 'auto' "
                         "(pallas runs interpret-mode off-TPU)")
    ap.add_argument("--mesh", default=None,
                    help="data,model mesh behind the queue, e.g. 2,2")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized shapes and request count")
    ap.add_argument("--metrics", action="store_true",
                    help="print the server's telemetry metrics snapshot "
                         "as JSON after the run")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="enable span tracing and write a Chrome "
                         "trace_event JSON (open in Perfetto / "
                         "chrome://tracing)")
    args = ap.parse_args()
    if args.smoke:
        args.requests = min(args.requests, 12)

    params, spec, kind = cnn.demo_model(args.model, smoke=args.smoke)
    srv = SV.PackedInferenceServer(max_batch=args.max_batch,
                                   default_deadline=args.deadline_ms / 1e3)
    if args.trace_out:
        srv.telemetry.enable_tracing()
    t0 = time.monotonic()
    mesh = None
    if args.mesh:
        try:
            shape = tuple(int(d) for d in args.mesh.split(","))
            if len(shape) != 2 or any(d < 1 for d in shape):
                raise ValueError(args.mesh)
        except ValueError:
            ap.error(f"--mesh must be 'data,model' positive ints, "
                     f"got {args.mesh!r}")
        from repro.launch.mesh import make_mesh
        mesh = make_mesh(shape, ("data", "model"))
    srv.register("demo", params, spec, kind=kind, backend=args.backend,
                 mesh=mesh)
    eng = srv.engine()
    print(f"registered {kind} (packed once in {time.monotonic() - t0:.2f}s)"
          f" buckets={eng.buckets} batch_multiple={eng.batch_multiple}"
          f" route@1={srv.route_for(1)} route@{args.max_batch}="
          f"{srv.route_for(args.max_batch)}")

    rng = np.random.default_rng(0)
    xs = rng.integers(0, 256, (args.requests, *eng.example_shape),
                      dtype=np.uint8)
    t0 = time.monotonic()
    # Collect completions from the step() returns, NOT from srv.served:
    # served is bounded observability history (truncated to the mailbox
    # cap), so percentiles over it silently drop the oldest requests
    # once --requests exceeds the cap.
    done = []
    for i in range(args.requests):
        srv.submit(xs[i])
        if args.arrival_ms:
            time.sleep(args.arrival_ms / 1e3)
        done += srv.step()
    while srv.pending():
        done += srv.step()
        time.sleep(args.deadline_ms / 4e3)
    wall = time.monotonic() - t0

    lats = sorted(r.latency for r in done)
    p50 = statistics.median(lats)
    p99 = SV.latency_percentile(lats, 0.99)
    print(f"served {len(done)} requests in {wall:.2f}s "
          f"({len(done) / wall:.1f} req/s)")
    print(f"latency p50={p50 * 1e3:.2f}ms p99={p99 * 1e3:.2f}ms")
    for f in srv.flushes:
        print(f"  flush batch={f.batch} bucket={f.bucket} route={f.route} "
              f"wall={f.wall_s * 1e3:.2f}ms")
    print(f"weight cache: {srv.cache.misses} pack(s), {srv.cache.hits} "
          f"hit(s); scratch pool: {srv.pool.allocations} buffer(s) for "
          f"{len(srv.flushes)} flushes")
    if args.metrics:
        print(json.dumps(srv.telemetry.metrics.snapshot(), indent=1,
                         sort_keys=True))
    if args.trace_out:
        srv.telemetry.tracer.export(args.trace_out)
        print(f"wrote {len(srv.telemetry.tracer.events)} trace events -> "
              f"{args.trace_out} (open in Perfetto / chrome://tracing)")


if __name__ == "__main__":
    main()
