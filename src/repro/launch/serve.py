"""Packed-inference serving launcher (the Espresso prediction phase).

Builds a reduced BCNN/BMLP with random weights, registers it with the
``train.serve.PackedInferenceServer`` (pack + fold BN ONCE via the
weight cache), replays a deterministic arrival trace against the
continuous-batching queue, and prints per-request p50/p99 latency,
throughput, and the GEMV/GEMM route of every flush:

    PYTHONPATH=src python -m repro.launch.serve --model bmlp \
        --requests 32 --max-batch 8 --deadline-ms 5

    # a (data, model) mesh behind the queue (forced host devices):
    PYTHONPATH=src python -m repro.launch.serve --model bcnn --mesh 2,2

    # CI smoke: tiny shapes, few requests
    PYTHONPATH=src python -m repro.launch.serve --model bmlp --smoke

    # chaos drill: scripted faults (docs/robustness.md), recovery report
    PYTHONPATH=src python -m repro.launch.serve --chaos --smoke

The old LM prefill/decode demo lives in ``examples/serve_binary_lm.py``
(the ``BatchedServer`` driver).
"""
from __future__ import annotations

import os
import sys

# Forced host devices must be set before ANY jax import (same pattern as
# distributed/verify_sharded.py): pre-scan argv for --mesh, in both the
# space-separated ("--mesh 2,2") and equals ("--mesh=2,2") forms.
def _prescan_mesh(argv: list[str]) -> str | None:
    for i, a in enumerate(argv):
        if a == "--mesh" and i + 1 < len(argv):
            return argv[i + 1]
        if a.startswith("--mesh="):
            return a.split("=", 1)[1]
    return None


_shape = _prescan_mesh(sys.argv)
if _shape is None and "--chaos" in sys.argv:
    _shape = "4,2"          # the chaos drill needs 8 devices to lose 4
if _shape is not None:
    try:
        _n = 1
        for _d in _shape.split(","):
            _n *= int(_d)
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "") +
            f" --xla_force_host_platform_device_count={_n}")
    except ValueError:
        pass                                    # argparse will complain

import argparse
import dataclasses
import json
import statistics
import time

import numpy as np

from repro.models import cnn
from repro.train import serve as SV


def run_chaos(args) -> None:
    """The chaos drill: scripted faults of every kind against one
    supervised server, then a recovery report + hard invariants.

    Phases (each installs a fresh ``FaultInjector`` so its dispatch
    indices are phase-local; the ``SimClock`` makes the whole drill
    deterministic):

    1. ``transient``   — dispatch fails twice, heals inside the retry
       budget: every request ``ok``, retries > 0.
    2. ``poison``      — one rid fails every cohort containing it:
       bisection isolates it (``error``), cohort-mates ``ok``.
    3. ``persistent``  — a whole cohort keeps failing (``error`` x4);
       the NEXT wave is untouched (failure isolation).
    4. ``slow``        — a 1 s flush stall; the following wave ages past
       ``timeout_grace`` and completes ``timeout``.
    5. ``device_loss`` — 8 -> 4 devices: elastic degrade (remesh +
       packed-checkpoint warm restore + engine rebuild under the
       queue), requeued wave served ``ok`` and bit-exact.
    6. ``device_loss@bisect`` — the loss OVERLAPS bisection: a poison
       rid splits the cohort, the loss strikes a clean bisected half,
       and the not-yet-dispatched siblings must requeue too (the
       whole-window requeue regression); degrade 4 -> 2, poison
       ``error``, everything else ``ok``.
    7. ``shed``        — queue filled to ``max_queue``; the next submit
       raises the typed ``BackpressureError``.
    8. ``recovery``    — a clean wave on the degraded mesh: all ``ok``,
       bit-exact, degraded gauge back at 0.

    Exits non-zero if any invariant fails (the CI chaos job's gate):
    retries > 0, zero requests lost (every admitted rid terminal),
    degraded gauge 0 after recovery, post-degrade rows bit-exact.
    """
    import tempfile

    import jax

    from repro.launch.mesh import make_mesh
    from repro.runtime import FaultInjector, FaultPlan, FaultSpec
    from repro.runtime.supervisor import ServingSupervisor

    assert len(jax.devices()) == 8, jax.devices()
    params, spec, kind = cnn.demo_model(args.model, smoke=True)
    clock = SV.SimClock()
    srv = SV.PackedInferenceServer(
        max_batch=8, default_deadline=args.deadline_ms / 1e3,
        max_queue=16, timeout_grace=50.0, clock=clock)
    srv.register("demo", params, spec, kind=kind, backend=args.backend,
                 mesh=make_mesh((4, 2), ("data", "model")))
    eng = srv.engine()
    sup = ServingSupervisor(srv, "demo",
                            ckpt_dir=tempfile.mkdtemp(prefix="chaos_ckpt_"),
                            backend=args.backend)
    sup.checkpoint()                     # healthy-path packed checkpoint

    rng = np.random.default_rng(0)
    xs = rng.integers(0, 256, (16, *eng.example_shape), dtype=np.uint8)
    from repro.distributed.sharding import reshard_packed
    ref_fwd = cnn.make_packed_forward(
        reshard_packed(eng.packed, None), backend="jnp")
    ref = np.asarray(ref_fwd(xs))        # single-device truth rows

    submitted: list[int] = []
    finished: dict[int, SV.ServeRequest] = {}
    shed = 0
    report: list[dict] = []

    def wave(n: int, *, plan=None, supervised=False, advance=0.006,
             phase=""):
        nonlocal finished
        inj = FaultInjector(plan).attach(srv) if plan is not None else None
        if plan is None:
            srv.flush_hook = None
        wave_rids = []
        for _ in range(n):
            i = len(submitted) % 16
            rid = srv.submit(xs[i])
            submitted.append(rid)
            wave_rids.append((rid, i))
        clock.advance(advance)
        done = sup.step() if supervised else srv.step()
        for r in done:
            finished[r.rid] = r
        statuses = {rid: finished[rid].status if rid in finished else "LOST"
                    for rid, _ in wave_rids}
        exact = all(
            finished[rid].status != "ok"
            or (np.asarray(finished[rid].result) == ref[i]).all()
            for rid, i in wave_rids)
        report.append({"phase": phase, "statuses": list(statuses.values()),
                       "bitexact": exact,
                       "injected": list(inj.injected) if inj else []})
        return [finished.get(rid) for rid, _ in wave_rids]

    print("chaos drill: 8 phases on a (4,2) mesh, SimClock-driven")
    wave(8, plan=FaultPlan.of(FaultSpec("transient", times=2)),
         phase="transient")
    poison_rid = len(submitted) + 3
    wave(8, plan=FaultPlan.of(FaultSpec("poison", rid=poison_rid)),
         phase="poison")
    wave(4, plan=FaultPlan.of(FaultSpec("persistent")), phase="persistent")
    wave(4, plan=None, phase="persistent-aftermath")
    wave(4, plan=FaultPlan.of(FaultSpec("slow", delay_s=1.0)), phase="slow")
    wave(4, plan=None, advance=0.400, phase="slow-aftermath(timeout)")
    wave(8, plan=FaultPlan.of(FaultSpec("device_loss", survivors=4)),
         supervised=True, phase="device_loss")
    # device loss overlapping bisection: with the default 3-attempt
    # budget, dispatches 0-2 fail on the full poisoned cohort and 3-5 on
    # its poisoned first half, so dispatch 6 is the first CLEAN bisected
    # pair — the armed loss fires there, with the poison pair and the
    # whole second half never dispatched.  Zero-lost then requires the
    # whole-window requeue (a per-half requeue loses the siblings).
    poison_rid2 = len(submitted) + 3
    wave(8, plan=FaultPlan.of(
            FaultSpec("poison", rid=poison_rid2),
            FaultSpec("device_loss", survivors=2, at_dispatch=6)),
         supervised=True, phase="device_loss@bisect")
    # shed: fill the queue to max_queue, the next submit must raise
    srv.flush_hook = None
    shed_rids = [srv.submit(xs[i % 16]) for i in range(16)]
    submitted.extend(shed_rids)
    try:
        srv.submit(xs[0])
        report.append({"phase": "shed", "statuses": ["NOT-RAISED"],
                       "bitexact": True, "injected": []})
    except SV.BackpressureError:
        shed += 1
        report.append({"phase": "shed", "statuses": ["shed"],
                       "bitexact": True, "injected": []})
    clock.advance(0.006)
    for r in sup.step():
        finished[r.rid] = r
    wave(8, plan=None, phase="recovery")

    m = srv.telemetry.metrics
    lost = [rid for rid in submitted
            if rid not in finished
            or finished[rid].status not in SV.TERMINAL_STATES]
    tally = {s: sum(1 for r in finished.values() if r.status == s)
             for s in SV.TERMINAL_STATES}
    tally["shed"] = shed
    invariants = {
        "retries>0": m.value("serve.retries") > 0,
        "errors>0": m.value("serve.errors") > 0,
        "timeouts>0": m.value("serve.timeouts") > 0,
        "shed>0": m.value("serve.shed") > 0,
        "degraded==2": m.value("serve.degraded") == 2,
        "degraded_state==0": m.value("serve.degraded_state") == 0,
        "zero_lost": not lost,
        "all_waves_bitexact": all(p["bitexact"] for p in report),
        "recovery_all_ok": all(
            r.status == "ok" for r in finished.values()
            if r.rid in submitted[-8:]),
        "ckpt_restore": bool(sup.events
                             and all(e.restored_from == "checkpoint"
                                     for e in sup.events)),
        "survivor_mesh": ([e.mesh_shape for e in sup.events]
                          == [(2, 2), (1, 2)]),
    }
    for p in report:
        print(f"  {p['phase']:26s} {p['statuses']}"
              f"{'' if p['bitexact'] else '  BITEXACT-FAIL'}")
    print(f"terminal tally: {tally}  (submitted={len(submitted)}, "
          f"lost={len(lost)})")
    print(f"degrade events: {[dataclasses.asdict(e) for e in sup.events]}")
    print("recovery invariants:")
    for name, ok in invariants.items():
        print(f"  [{'PASS' if ok else 'FAIL'}] {name}")
    out = {
        "tally": tally, "submitted": len(submitted),
        "lost": len(lost), "invariants": invariants, "phases": report,
        "events": [dataclasses.asdict(e) for e in sup.events],
        "metrics": {k: v for k, v in m.snapshot().items()
                    if k.startswith(("serve.", "faults."))},
    }
    if args.chaos_report:
        with open(args.chaos_report, "w") as f:
            json.dump(out, f, indent=1, sort_keys=True)
        print(f"wrote chaos report -> {args.chaos_report}")
    bad = [n for n, ok in invariants.items() if not ok]
    if bad:
        raise SystemExit(f"chaos drill FAILED: {bad}")
    print("chaos drill PASSED: server degraded, recovered, lost nothing")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", choices=("bcnn", "bmlp"), default="bmlp")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--deadline-ms", type=float, default=5.0)
    ap.add_argument("--arrival-ms", type=float, default=0.0,
                    help="inter-arrival gap (0 = back-to-back)")
    ap.add_argument("--backend", default="jnp",
                    help="'jnp' | 'pallas' | 'ref' | 'auto' "
                         "(pallas runs interpret-mode off-TPU)")
    ap.add_argument("--mesh", default=None,
                    help="data,model mesh behind the queue, e.g. 2,2")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized shapes and request count")
    ap.add_argument("--chaos", action="store_true",
                    help="run the scripted fault-injection drill "
                         "(docs/robustness.md) and print a recovery "
                         "report; exits non-zero if any recovery "
                         "invariant fails")
    ap.add_argument("--chaos-report", default=None, metavar="PATH",
                    help="write the chaos recovery report as JSON")
    ap.add_argument("--metrics", action="store_true",
                    help="print the server's telemetry metrics snapshot "
                         "as JSON after the run")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="enable span tracing and write a Chrome "
                         "trace_event JSON (open in Perfetto / "
                         "chrome://tracing)")
    args = ap.parse_args()
    if args.smoke:
        args.requests = min(args.requests, 12)
    if args.chaos:
        run_chaos(args)
        return

    params, spec, kind = cnn.demo_model(args.model, smoke=args.smoke)
    srv = SV.PackedInferenceServer(max_batch=args.max_batch,
                                   default_deadline=args.deadline_ms / 1e3)
    if args.trace_out:
        srv.telemetry.enable_tracing()
    t0 = time.monotonic()
    mesh = None
    if args.mesh:
        try:
            shape = tuple(int(d) for d in args.mesh.split(","))
            if len(shape) != 2 or any(d < 1 for d in shape):
                raise ValueError(args.mesh)
        except ValueError:
            ap.error(f"--mesh must be 'data,model' positive ints, "
                     f"got {args.mesh!r}")
        from repro.launch.mesh import make_mesh
        mesh = make_mesh(shape, ("data", "model"))
    srv.register("demo", params, spec, kind=kind, backend=args.backend,
                 mesh=mesh)
    eng = srv.engine()
    print(f"registered {kind} (packed once in {time.monotonic() - t0:.2f}s)"
          f" buckets={eng.buckets} batch_multiple={eng.batch_multiple}"
          f" route@1={srv.route_for(1)} route@{args.max_batch}="
          f"{srv.route_for(args.max_batch)}")

    rng = np.random.default_rng(0)
    xs = rng.integers(0, 256, (args.requests, *eng.example_shape),
                      dtype=np.uint8)
    t0 = time.monotonic()
    # Collect completions from the step() returns, NOT from srv.served:
    # served is bounded observability history (truncated to the mailbox
    # cap), so percentiles over it silently drop the oldest requests
    # once --requests exceeds the cap.
    done = []
    for i in range(args.requests):
        srv.submit(xs[i])
        if args.arrival_ms:
            time.sleep(args.arrival_ms / 1e3)
        done += srv.step()
    while srv.pending():
        done += srv.step()
        time.sleep(args.deadline_ms / 4e3)
    wall = time.monotonic() - t0

    lats = sorted(r.latency for r in done)
    p50 = statistics.median(lats)
    p99 = SV.latency_percentile(lats, 0.99)
    print(f"served {len(done)} requests in {wall:.2f}s "
          f"({len(done) / wall:.1f} req/s)")
    print(f"latency p50={p50 * 1e3:.2f}ms p99={p99 * 1e3:.2f}ms")
    for f in srv.flushes:
        print(f"  flush batch={f.batch} bucket={f.bucket} route={f.route} "
              f"wall={f.wall_s * 1e3:.2f}ms")
    print(f"weight cache: {srv.cache.misses} pack(s), {srv.cache.hits} "
          f"hit(s); scratch pool: {srv.pool.allocations} buffer(s) for "
          f"{len(srv.flushes)} flushes")
    if args.metrics:
        print(json.dumps(srv.telemetry.metrics.snapshot(), indent=1,
                         sort_keys=True))
    if args.trace_out:
        srv.telemetry.tracer.export(args.trace_out)
        print(f"wrote {len(srv.telemetry.tracer.events)} trace events -> "
              f"{args.trace_out} (open in Perfetto / chrome://tracing)")


if __name__ == "__main__":
    main()
