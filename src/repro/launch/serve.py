"""Serving launcher: prefill a prompt batch, decode N tokens.

    PYTHONPATH=src python -m repro.launch.serve --arch starcoder2-3b \
        --reduced --quant binary_weight --batch 4 --prompt-len 32 --new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.quantize import QuantMode
from repro.models import linear as LN
from repro.models import model as M


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--quant", default=None)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch, quant=args.quant, reduced=args.reduced)
    key = jax.random.PRNGKey(0)
    params = M.init_model(key, cfg)
    if cfg.quant.mode != QuantMode.FLOAT:
        # pack ONCE at load (paper C2) — inference uses packed weights
        params = LN.maybe_pack_tree(params, cfg.quant)

    max_len = args.prompt_len + args.new
    toks = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks}
    if cfg.encoder_layers:
        batch["enc_embeds"] = jax.random.normal(
            key, (args.batch, args.prompt_len, cfg.d_model), jnp.bfloat16)

    t0 = time.monotonic()
    logits, cache = jax.jit(
        lambda p, b: M.prefill(p, cfg, b, max_len))(params, batch)
    print(f"prefill {args.prompt_len} tokens: "
          f"{time.monotonic() - t0:.2f}s")

    decode = jax.jit(lambda p, c, t, i: M.decode_step(p, cfg, t, c, i))
    tok = jnp.argmax(logits[:, 0], axis=-1)[:, None].astype(jnp.int32)
    out = [tok]
    t0 = time.monotonic()
    for t in range(args.new - 1):
        logits, cache = decode(params, cache, tok,
                               jnp.int32(args.prompt_len + t))
        tok = jnp.argmax(logits[:, 0], axis=-1)[:, None].astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    dt = time.monotonic() - t0
    print(f"decoded {args.new - 1} steps in {dt:.2f}s "
          f"({(args.new - 1) / max(dt, 1e-9):.1f} tok/s/seq)")
    print("sample:", jnp.concatenate(out, axis=1)[0][:16].tolist())


if __name__ == "__main__":
    main()
