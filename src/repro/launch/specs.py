"""ShapeDtypeStruct stand-ins for every (arch x shape) dry-run cell.

``input_specs`` returns exactly what the lowered step function consumes —
weak-type-correct, shardable, zero device allocation.  Stub frontends
(vlm/audio) provide precomputed embedding stand-ins per the assignment.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig

SDS = jax.ShapeDtypeStruct


def train_batch_specs(cfg: ArchConfig, shape: ShapeConfig) -> dict:
    b, s = shape.global_batch, shape.seq_len
    batch: dict = {"labels": SDS((b, s), jnp.int32)}
    if cfg.frontend == "vision_stub":
        batch["embeds"] = SDS((b, s), jnp.int32)  # replaced below
        batch["embeds"] = SDS((b, s, cfg.d_model), jnp.bfloat16)
        batch["tokens"] = SDS((b, s), jnp.int32)
    else:
        batch["tokens"] = SDS((b, s), jnp.int32)
    if cfg.encoder_layers:
        batch["enc_embeds"] = SDS((b, s, cfg.d_model), jnp.bfloat16)
    return batch


def prefill_batch_specs(cfg: ArchConfig, shape: ShapeConfig) -> dict:
    b, s = shape.global_batch, shape.seq_len
    batch: dict = {}
    if cfg.frontend == "vision_stub":
        batch["embeds"] = SDS((b, s, cfg.d_model), jnp.bfloat16)
    else:
        batch["tokens"] = SDS((b, s), jnp.int32)
    if cfg.encoder_layers:
        batch["enc_embeds"] = SDS((b, s, cfg.d_model), jnp.bfloat16)
    return batch


def decode_token_specs(shape: ShapeConfig) -> jax.ShapeDtypeStruct:
    return SDS((shape.global_batch, 1), jnp.int32)


def shape_struct_tree(tree):
    """Concrete pytree -> ShapeDtypeStruct pytree (no copies needed —
    works on eval_shape output too)."""
    return jax.tree.map(lambda x: SDS(x.shape, x.dtype), tree)
