"""Fault tolerance: supervised training loop with checkpoint/restart,

heartbeats, and deadline-based straggler mitigation.

This container is single-host, so failures are *injected* (exceptions /
simulated slow steps); the control flow is the multi-host shape:

  Supervisor.run():
    restore latest checkpoint (if any) -> loop:
      step with deadline -> heartbeat -> periodic async checkpoint
    on StepFailure: restart from last checkpoint (elastic: the restore
    path reshards, so the post-restart mesh may differ)

Straggler mitigation: a step exceeding ``deadline_factor x`` the rolling
median is recorded and (in the simulated runner) re-dispatched once —
the bounded-retry analogue of backup tasks (MapReduce-style speculative
execution adapted to synchronous SPMD: in a real pod this is "replace
the slow host and re-join", here it is re-running the step closure).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

from repro.checkpoint import (AsyncCheckpointer, latest_step,
                              load_checkpoint)
from repro.telemetry import MetricsRegistry


class StepFailure(RuntimeError):
    """Raised by a step function to simulate a node failure."""


@dataclasses.dataclass
class SupervisorConfig:
    ckpt_dir: str
    ckpt_every: int = 50
    max_restarts: int = 10
    deadline_factor: float = 3.0
    min_deadline_s: float = 0.5


@dataclasses.dataclass
class SupervisorReport:
    steps_done: int = 0
    restarts: int = 0
    stragglers_redispatched: int = 0
    heartbeats: int = 0


class Supervisor:
    """Runs ``step_fn(state, step_idx) -> state, metrics`` with restart.

    ``state`` must be a pytree checkpointable by repro.checkpoint.

    Restart/straggler/heartbeat counts are mirrored into a telemetry
    metrics registry (``supervisor.*`` — pass a shared one via
    ``metrics=``, e.g. the serving registry, so one snapshot covers the
    whole process; a fresh registry is created otherwise).  The
    in-memory :class:`SupervisorReport` stays the ``run()`` return
    value; the registry is the aggregatable (snapshot/merge) view of
    the same counts, and the two are kept in lock-step by
    :meth:`_record`.
    """

    def __init__(self, cfg: SupervisorConfig, init_state_fn: Callable,
                 step_fn: Callable, shardings=None,
                 metrics: MetricsRegistry | None = None):
        self.cfg = cfg
        self.init_state_fn = init_state_fn
        self.step_fn = step_fn
        self.shardings = shardings
        self.ckpt = AsyncCheckpointer(cfg.ckpt_dir)
        self.report = SupervisorReport()
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._m_restarts = self.metrics.counter("supervisor.restarts")
        self._m_stragglers = self.metrics.counter(
            "supervisor.stragglers_redispatched")
        self._m_heartbeats = self.metrics.counter("supervisor.heartbeats")
        self._m_steps = self.metrics.gauge("supervisor.steps_done")
        self._durations: list[float] = []

    def _restore_or_init(self):
        step = latest_step(self.cfg.ckpt_dir)
        state = self.init_state_fn()
        if step is None:
            return state, 0
        state, meta = load_checkpoint(self.cfg.ckpt_dir, step, state,
                                      self.shardings)
        return state, int(meta["step"]) + 1

    def _deadline(self) -> float:
        if not self._durations:
            return float("inf")
        med = sorted(self._durations)[len(self._durations) // 2]
        return max(self.cfg.min_deadline_s,
                   self.cfg.deadline_factor * med)

    def run(self, num_steps: int) -> tuple:
        restarts = 0
        while True:
            state, start = self._restore_or_init()
            try:
                for i in range(start, num_steps):
                    t0 = time.monotonic()
                    deadline = self._deadline()
                    pre_state = state      # re-dispatch must NOT see the
                    try:                   # straggler's own update
                        state, metrics = self.step_fn(pre_state, i)
                    except StepFailure:
                        raise
                    dt = time.monotonic() - t0
                    if dt > deadline:
                        # straggler: bounded speculative re-dispatch,
                        # from the PRE-step state — the slow attempt's
                        # result is discarded, step i applies exactly
                        # once (backup-task semantics)
                        self.report.stragglers_redispatched += 1
                        self._m_stragglers.inc()
                        t0 = time.monotonic()
                        state, metrics = self.step_fn(pre_state, i)
                        dt = time.monotonic() - t0
                    self._durations.append(dt)
                    if len(self._durations) > 64:
                        self._durations.pop(0)
                    self.report.heartbeats += 1
                    self._m_heartbeats.inc()
                    self.report.steps_done = i + 1
                    self._m_steps.set(i + 1)
                    if (i + 1) % self.cfg.ckpt_every == 0:
                        self.ckpt.save(i, state)
                self.ckpt.wait()
                self.report.restarts = restarts
                return state, self.report
            except StepFailure:
                restarts += 1
                self.report.restarts = restarts
                self._m_restarts.inc()
                if restarts > self.cfg.max_restarts:
                    raise
                self.ckpt.wait()
                continue
