"""Serving supervisor: elastic degradation for the packed-inference
server.

``Supervisor`` (fault_tolerance.py) owns the TRAINING loop —
checkpoint/restart semantics around a step function.  This module owns
the SERVING loop: a :class:`ServingSupervisor` wraps a
:class:`~repro.train.serve.PackedInferenceServer` and turns
:class:`~repro.train.serve.DeviceLossError` — raised out of a flush
when a device backing the engine disappears — into elastic mesh
degradation instead of a dead server:

1. the failed window is already back at the front of the queue (the
   server requeues before re-raising — zero requests lost);
2. :func:`~repro.runtime.elastic.remesh_plan` computes the survivor
   (data, model) mesh, never growing the model degree;
3. packed weights are warm-restored — from the newest packed-weight
   checkpoint (``checkpoint.load_packed_checkpoint``, the
   reshard-on-restore path) when a ``ckpt_dir`` is configured, else
   re-placed from the live tree (``sharding.reshard_packed``); cheap
   either way: 32x-compressed packed words, not fp32 weights;
4. the engine is swapped under the queue via
   ``PackedInferenceServer.rebuild_engine`` (NO flush through the dead
   engine), and the requeued requests are served by the survivors on
   the next step — bit-exact, all-gather-only
   (``distributed/verify_sharded.py`` proves the shrunken-mesh cell).

Observability: ``serve.degraded`` counts degradations, the
``serve.degraded_state`` gauge is 1 only while a degrade is in flight
(back to 0 on recovery — the chaos CI invariant), and each event is
kept in :attr:`ServingSupervisor.events`.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax

from repro.checkpoint import (latest_step, load_packed_checkpoint,
                              save_packed_checkpoint)
from repro.distributed.sharding import reshard_packed
from repro.runtime.elastic import remesh_plan
from repro.train.serve import DeviceLossError, ServeRequest


@dataclasses.dataclass(frozen=True)
class DegradeEvent:
    """One completed elastic degradation."""
    survivors: int
    mesh_shape: tuple[int, int]
    restored_from: str            # 'checkpoint' | 'live'
    requeued: int


class ServingSupervisor:
    """Keeps one server serving through injected device loss.

    ``key`` names the registered model to supervise; ``devices`` is the
    full device list the survivor prefix is drawn from (default
    ``jax.devices()`` — in the forced-8-CPU harness, losing devices
    means building the new mesh over a PREFIX of the same list).
    ``ckpt_dir`` enables checkpoint warm-restore: call
    :meth:`checkpoint` while healthy, and degrades restore from the
    newest packed checkpoint instead of the live tree.
    """

    def __init__(self, server, key, *, ckpt_dir: str | None = None,
                 devices=None, min_model: int = 1,
                 backend: str = "auto", dense_stack: str = "auto"):
        self.server = server
        self.key = key
        self.ckpt_dir = ckpt_dir
        self.devices = list(devices if devices is not None
                            else jax.devices())
        self.min_model = min_model
        self.backend = backend
        self.dense_stack = dense_stack
        self.events: list[DegradeEvent] = []
        m = server.telemetry.metrics
        self._m_degraded = m.counter("serve.degraded")
        self._g_degraded = m.gauge("serve.degraded_state")
        self._ckpt_steps = 0

    # -- checkpointing (healthy path) ---------------------------------------

    def checkpoint(self) -> str | None:
        """Save the supervised engine's packed tree (no-op without a
        ``ckpt_dir``).  Returns the checkpoint path."""
        if self.ckpt_dir is None:
            return None
        packed = self.server.engine(self.key).packed
        path = save_packed_checkpoint(self.ckpt_dir, self._ckpt_steps,
                                      reshard_packed(packed, None))
        self._ckpt_steps += 1
        return path

    # -- supervised stepping ------------------------------------------------

    def step(self, now: float | None = None) -> list[ServeRequest]:
        """``server.step`` with device-loss recovery: on
        :class:`DeviceLossError` the mesh degrades to the survivors and
        the step is re-driven so the requeued window completes on the
        new engine."""
        try:
            return self.server.step(now)
        except DeviceLossError as e:
            self.degrade(e.survivors)
            return self.server.step(now)

    def drain(self) -> list[ServeRequest]:
        """``server.flush`` with the same recovery contract."""
        try:
            return self.server.flush()
        except DeviceLossError as e:
            self.degrade(e.survivors)
            return self.server.flush()

    # -- elastic degradation ------------------------------------------------

    def _current_model_degree(self) -> int:
        mesh = getattr(self.server.engine(self.key).fwd, "mesh", None)
        if mesh is None:
            return 1
        return int(mesh.shape.get("model", 1))

    def degrade(self, survivors: int) -> DegradeEvent:
        """Shrink to ``survivors`` devices: remesh, warm-restore packed
        weights, rebuild the engine under the queue."""
        self._m_degraded.inc()
        self._g_degraded.set(1)
        requeued = self.server.pending()
        plan = remesh_plan(survivors,
                           prefer_model=self._current_model_degree(),
                           min_model=self.min_model)
        mesh = plan.build(self.devices[:survivors])
        step = (latest_step(self.ckpt_dir)
                if self.ckpt_dir is not None else None)
        if step is not None:
            template = reshard_packed(self.server.engine(self.key).packed,
                                      None)
            packed, _ = load_packed_checkpoint(self.ckpt_dir, step,
                                               template)
            restored_from = "checkpoint"
        else:
            packed = reshard_packed(self.server.engine(self.key).packed,
                                    None)
            restored_from = "live"
        self.server.rebuild_engine(self.key, packed=packed,
                                   backend=self.backend,
                                   dense_stack=self.dense_stack,
                                   mesh=mesh)
        self._g_degraded.set(0)        # recovery complete
        event = DegradeEvent(survivors=survivors, mesh_shape=plan.shape,
                             restored_from=restored_from,
                             requeued=requeued)
        self.events.append(event)
        return event
