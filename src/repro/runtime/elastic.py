"""Elastic scaling: re-mesh on device-set change.

When a pod is lost (or added), the job restarts on a different device
count.  ``remesh_plan`` recomputes the largest valid (data, model) mesh
for the survivors under the constraint that the model-parallel degree is
preserved when possible (weights reshard cheaply along data/FSDP; moving
the TP axis means a full re-layout).  ``load_checkpoint`` with new
shardings performs the actual reshard (checkpointer docstring).
"""
from __future__ import annotations

import dataclasses

import jax
from jax.sharding import Mesh


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    shape: tuple[int, ...]
    axes: tuple[str, ...]

    def build(self, devices=None) -> Mesh:
        return jax.make_mesh(self.shape, self.axes,
                             devices=devices) if devices is not None else \
            jax.make_mesh(self.shape, self.axes)


def remesh_plan(n_devices: int, *, prefer_model: int,
                min_model: int = 1) -> MeshPlan:
    """Largest (data, model) factorization of ``n_devices`` keeping the
    model-parallel degree at ``prefer_model`` when it divides, else the
    largest power-of-two divisor of ``n_devices`` that is
    ``<= prefer_model`` (clamped to ``>= min_model``).  The degree never
    *grows* past ``prefer_model`` on a shrink — growing TP would
    re-layout every packed weight word instead of just the data axis —
    so ``min_model`` must be ``<= prefer_model``.

    Raises ``ValueError`` for a non-positive device count (an empty
    survivor set has no mesh — the supervisor must escalate, not serve),
    when ``min_model > prefer_model`` (honoring it would grow the
    degree), or when ``min_model`` cannot be honored because it does
    not divide ``n_devices``.
    """
    if n_devices < 1:
        raise ValueError(f"n_devices must be >= 1, got {n_devices}")
    if prefer_model < 1 or min_model < 1:
        raise ValueError(
            f"prefer_model/min_model must be >= 1, got "
            f"{prefer_model}/{min_model}")
    if min_model > prefer_model:
        raise ValueError(
            f"min_model={min_model} exceeds prefer_model={prefer_model} "
            f"— honoring it would grow the model degree on a shrink")
    if n_devices % prefer_model == 0:
        model = prefer_model
    else:
        model = 1
        while model * 2 <= prefer_model and n_devices % (model * 2) == 0:
            model *= 2
    if model < min_model:
        if n_devices % min_model:
            raise ValueError(
                f"cannot honor min_model={min_model}: it does not divide "
                f"n_devices={n_devices} (largest divisor <= "
                f"prefer_model={prefer_model} is {model})")
        model = min_model
    return MeshPlan((n_devices // model, model), ("data", "model"))
