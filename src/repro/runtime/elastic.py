"""Elastic scaling: re-mesh on device-set change.

When a pod is lost (or added), the job restarts on a different device
count.  ``remesh_plan`` recomputes the largest valid (data, model) mesh
for the survivors under the constraint that the model-parallel degree is
preserved when possible (weights reshard cheaply along data/FSDP; moving
the TP axis means a full re-layout).  ``load_checkpoint`` with new
shardings performs the actual reshard (checkpointer docstring).
"""
from __future__ import annotations

import dataclasses

import jax
from jax.sharding import Mesh


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    shape: tuple[int, ...]
    axes: tuple[str, ...]

    def build(self, devices=None) -> Mesh:
        return jax.make_mesh(self.shape, self.axes,
                             devices=devices) if devices is not None else \
            jax.make_mesh(self.shape, self.axes)


def remesh_plan(n_devices: int, *, prefer_model: int,
                min_model: int = 1) -> MeshPlan:
    """Largest (data, model) factorization of n_devices keeping model
    parallel degree at ``prefer_model`` when it divides, else the largest
    power-of-two divisor >= min_model."""
    model = prefer_model
    while model > min_model and n_devices % model:
        model //= 2
    model = max(model, min_model)
    data = n_devices // model
    return MeshPlan((data, model), ("data", "model"))
