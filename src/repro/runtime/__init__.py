"""Runtime: keep training and serving alive through failures.

* ``fault_tolerance`` — training-loop supervision (checkpoint/restart,
  straggler re-dispatch).
* ``elastic`` — survivor-mesh planning on device-set change.
* ``faults`` — the fault-injection harness (scripted chaos via the
  server's ``flush_hook`` seam).
* ``supervisor`` — serving-loop supervision (device loss -> elastic
  mesh degradation with packed-weight warm restore).
"""
from repro.runtime.elastic import MeshPlan, remesh_plan
from repro.runtime.fault_tolerance import (StepFailure, Supervisor,
                                           SupervisorConfig,
                                           SupervisorReport)
from repro.runtime.faults import (FAULT_KINDS, FaultInjector, FaultPlan,
                                  FaultSpec, InjectedFault,
                                  PersistentFlushError, PoisonRequestError,
                                  TransientFlushError)
from repro.runtime.supervisor import DegradeEvent, ServingSupervisor

__all__ = [
    "MeshPlan", "remesh_plan",
    "StepFailure", "Supervisor", "SupervisorConfig", "SupervisorReport",
    "FAULT_KINDS", "FaultInjector", "FaultPlan", "FaultSpec",
    "InjectedFault", "PersistentFlushError", "PoisonRequestError",
    "TransientFlushError",
    "DegradeEvent", "ServingSupervisor",
]
