"""Fault injection for the serving stack (chaos harness).

A :class:`FaultPlan` is an ordered script of :class:`FaultSpec`\\ s, each
armed at a global DISPATCH index (every dispatch attempt counts: first
tries, retries, and bisection halves alike — the retry loop is exactly
what the harness must exercise).  :class:`FaultInjector` compiles the
plan into a ``flush_hook`` for :class:`~repro.train.serve.
PackedInferenceServer` — the seam ``_flush_window`` routes every device
dispatch through — so faults fire inside the real retry/bisect/requeue
machinery, not around it.  Driven by ``SimClock`` the whole scenario is
deterministic: backoff sleeps advance the simulated clock, slow flushes
are clock jumps, and no test ever sleeps wall-time.

Fault kinds (the matrix ``tests/test_runtime_faults.py`` sweeps):

* ``transient``  — the dispatch raises :class:`TransientFlushError` for
  ``times`` attempts, then heals; with ``times <= RetryPolicy.
  max_retries`` every request still completes ``ok`` (retries > 0).
* ``persistent`` — the cohort caught at the armed dispatch is poisoned
  wholesale: any dispatch containing one of its rids keeps raising
  :class:`PersistentFlushError`, so retries exhaust, bisection drains,
  and each of its requests completes ``error`` — while later traffic is
  untouched (failure isolation).
* ``poison``     — one request (``rid``) fails every dispatch containing
  it; bisection isolates it in O(log batch) dispatches, the poison rid
  completes ``error`` and its former cohort-mates complete ``ok``.
* ``device_loss`` — the dispatch raises :class:`~repro.train.serve.
  DeviceLossError` once; the server requeues the window (zero requests
  lost) and re-raises for the :class:`~repro.runtime.supervisor.
  ServingSupervisor` to shrink the mesh.
* ``slow``       — the dispatch completes but only after ``delay_s``
  (clock jump); with ``timeout_grace`` set, requests still queued
  behind the slow flush age past their grace and complete ``timeout``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

from repro.train.serve import DeviceLossError

FAULT_KINDS = ("transient", "persistent", "poison", "device_loss", "slow")


class InjectedFault(RuntimeError):
    """Base class for all injected (simulated) failures."""


class TransientFlushError(InjectedFault):
    """A flush failure that heals after ``times`` attempts."""


class PersistentFlushError(InjectedFault):
    """A flush failure that never heals for the afflicted cohort."""


class PoisonRequestError(InjectedFault):
    """A single request that fails every batch containing it."""


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One scripted fault.

    ``at_dispatch`` is the 0-based index of the dispatch attempt that
    arms the fault (the injector counts every attempt it sees).
    ``times`` (transient) is how many attempts fail before healing;
    ``rid`` (poison) targets one request; ``survivors`` (device_loss)
    is the post-loss device count; ``delay_s`` (slow) the injected
    stall.
    """
    kind: str
    at_dispatch: int = 0
    times: int = 1
    rid: int | None = None
    survivors: int | None = None
    delay_s: float = 0.0

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(
                f"kind must be one of {FAULT_KINDS}, got {self.kind!r}")
        if self.kind == "poison" and self.rid is None:
            raise ValueError("poison fault needs a target rid")
        if self.kind == "device_loss" and self.survivors is None:
            raise ValueError("device_loss fault needs a survivor count")


@dataclasses.dataclass
class FaultPlan:
    """An ordered fault script plus the injector bookkeeping it needs."""
    faults: tuple[FaultSpec, ...] = ()

    @classmethod
    def of(cls, *faults: FaultSpec) -> "FaultPlan":
        return cls(faults=tuple(faults))


class FaultInjector:
    """Compiles a :class:`FaultPlan` into a server ``flush_hook``.

    Install with :meth:`attach` (returns self); every injected fault is
    counted in the server's metrics registry under
    ``faults.injected.<kind>`` so the chaos report can assert the
    scenario actually ran.  ``injected`` holds the full event log
    (dispatch index, kind, rids hit).
    """

    def __init__(self, plan: FaultPlan,
                 sleep: Callable[[float], Any] | None = None):
        self.plan = plan
        self._sleep = sleep
        self.dispatches = 0
        self.injected: list[dict] = []
        self._transient_left = {id(f): f.times for f in plan.faults
                                if f.kind == "transient"}
        self._poisoned_cohorts: list[tuple[FaultSpec, frozenset[int]]] = []
        self._fired: set[int] = set()     # one-shot specs already fired
        self._server = None

    def attach(self, server) -> "FaultInjector":
        """Install as ``server.flush_hook`` (inherits the server's sleep
        so SimClock-driven backoff and slow flushes share one clock)."""
        self._server = server
        if self._sleep is None:
            self._sleep = server._sleep
        server.flush_hook = self
        return self

    def _count(self, kind: str) -> None:
        if self._server is not None:
            self._server.telemetry.metrics.counter(
                f"faults.injected.{kind}").inc()

    def _raise(self, spec: FaultSpec, reqs, n: int) -> None:
        rids = [r.rid for r in reqs]
        self.injected.append(
            {"dispatch": n, "kind": spec.kind, "rids": rids})
        self._count(spec.kind)
        if spec.kind == "transient":
            raise TransientFlushError(f"injected transient @ dispatch {n}")
        if spec.kind == "persistent":
            raise PersistentFlushError(
                f"injected persistent @ dispatch {n}")
        if spec.kind == "poison":
            raise PoisonRequestError(f"injected poison rid={spec.rid}")
        if spec.kind == "device_loss":
            raise DeviceLossError(spec.survivors)
        raise AssertionError(spec.kind)

    def __call__(self, eng, buf, reqs, default):
        n = self.dispatches
        self.dispatches += 1
        rids = {r.rid for r in reqs}
        # standing faults first: poisoned cohorts / poison rids keep
        # failing regardless of dispatch index
        for spec, cohort in self._poisoned_cohorts:
            if cohort & rids:
                self._raise(spec, reqs, n)
        for spec in self.plan.faults:
            if spec.kind == "poison" and spec.rid in rids \
                    and n >= spec.at_dispatch:
                self._raise(spec, reqs, n)
        # scripted one-shots / windows keyed on the dispatch counter
        for spec in self.plan.faults:
            if spec.kind == "transient":
                left = self._transient_left[id(spec)]
                if left > 0 and n >= spec.at_dispatch:
                    self._transient_left[id(spec)] = left - 1
                    self._raise(spec, reqs, n)
            elif spec.kind == "persistent":
                if n == spec.at_dispatch and id(spec) not in self._fired:
                    self._fired.add(id(spec))
                    self._poisoned_cohorts.append((spec, frozenset(rids)))
                    self._raise(spec, reqs, n)
            elif spec.kind == "device_loss":
                if n >= spec.at_dispatch and id(spec) not in self._fired:
                    self._fired.add(id(spec))
                    self._raise(spec, reqs, n)
            elif spec.kind == "slow":
                if n == spec.at_dispatch and id(spec) not in self._fired:
                    self._fired.add(id(spec))
                    self.injected.append({"dispatch": n, "kind": "slow",
                                          "rids": sorted(rids)})
                    self._count("slow")
                    (self._sleep or time.sleep)(spec.delay_s)
        return default()
