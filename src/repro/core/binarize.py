"""Binarization primitives — the paper's §4 in JAX.

Encoding convention (paper §4.1): logical values are {-1,+1}; at the bit
level we encode  -1 -> 0,  +1 -> 1.

Packing convention (TPU adaptation of paper §4.2): values are packed into
**32-bit words** (``WORD_BITS = 32``) along the LAST axis, LSB-first:
element ``j*32 + i`` of a row occupies bit ``i`` of word ``j``.  The paper
uses 64-bit words on CUDA; TPU vector lanes are 32-bit, so 32-bit words are
the native choice (see DESIGN.md §2).

The packed dot-product identity (paper eq. 2, rewritten for XOR):

    a . b  =  K - 2 * popcount(XOR(a_packed, b_packed))

since XOR counts *mismatches* (XNOR counts matches; both forms are
equivalent: matches + mismatches = K).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

WORD_BITS = 32
WORD_DTYPE = jnp.uint32


def pad_to_multiple(x: jax.Array, multiple: int, axis: int, value=0) -> jax.Array:
    """Pad ``axis`` of ``x`` up to the next multiple of ``multiple``."""
    size = x.shape[axis]
    rem = (-size) % multiple
    if rem == 0:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, rem)
    return jnp.pad(x, pads, constant_values=value)


# ---------------------------------------------------------------------------
# sign / straight-through estimator (paper §4.1, §4.4)
# ---------------------------------------------------------------------------

def sign_pm1(x: jax.Array) -> jax.Array:
    """Paper eq. 1: sign(x) in {-1,+1} with sign(0) = +1."""
    return jnp.where(x >= 0, 1.0, -1.0).astype(x.dtype)


@jax.custom_vjp
def binarize_ste(x: jax.Array) -> jax.Array:
    """sign() with the straight-through estimator backward (paper §4.4).

    Forward: sign(x) in {-1,+1}.  Backward: pass gradient where |x| <= 1,
    zero elsewhere (Bengio et al. 2013 hard-tanh STE).
    """
    return sign_pm1(x)


def _binarize_ste_fwd(x):
    return sign_pm1(x), x


def _binarize_ste_bwd(x, g):
    return (jnp.where(jnp.abs(x) <= 1.0, g, 0.0).astype(g.dtype),)


binarize_ste.defvjp(_binarize_ste_fwd, _binarize_ste_bwd)


def clip_latent(w: jax.Array) -> jax.Array:
    """Clip latent fp weights to [-1, 1] after the optimizer step (paper §4.4)."""
    return jnp.clip(w, -1.0, 1.0)


# ---------------------------------------------------------------------------
# bit packing / unpacking (paper §4.2, TPU 32-bit words)
# ---------------------------------------------------------------------------

def packed_width(k: int) -> int:
    """Number of 32-bit words needed for k binary elements."""
    return (k + WORD_BITS - 1) // WORD_BITS


def pack_bits(x: jax.Array) -> jax.Array:
    """Pack ±1 (or any sign-interpretable) values along the last axis.

    ``x``: (..., K) real array.  Values >= 0 encode to bit 1, < 0 to bit 0.
    Returns (..., ceil(K/32)) uint32.  K is zero-*bit*-padded, i.e. padded
    logical elements encode as 0-bits; pad BOTH operands of a dot so padded
    positions XOR to 0 and contribute no mismatches.
    """
    k = x.shape[-1]
    kw = packed_width(k)
    bits = (x >= 0).astype(WORD_DTYPE)
    bits = pad_to_multiple(bits, WORD_BITS, axis=-1)
    bits = bits.reshape(*x.shape[:-1], kw, WORD_BITS)
    shifts = jnp.arange(WORD_BITS, dtype=WORD_DTYPE)
    return (bits << shifts).sum(axis=-1, dtype=WORD_DTYPE)


def unpack_bits(packed: jax.Array, k: int, dtype=jnp.float32) -> jax.Array:
    """Inverse of :func:`pack_bits`: (..., Kw) uint32 -> (..., k) ±1 values."""
    shifts = jnp.arange(WORD_BITS, dtype=WORD_DTYPE)
    bits = (packed[..., None] >> shifts) & jnp.uint32(1)
    bits = bits.reshape(*packed.shape[:-1], packed.shape[-1] * WORD_BITS)
    bits = bits[..., :k]
    return (2.0 * bits.astype(dtype) - 1.0).astype(dtype)


def packed_matmul(a_packed: jax.Array, b_packed: jax.Array, k: int,
                  *, block_kw: int | None = None) -> jax.Array:
    """Binary matmul on packed operands (paper eq. 2).

    ``a_packed``: (..., M, Kw) uint32, ``b_packed``: (N, Kw) uint32.
    Returns (..., M, N) int32 exact dot products in [-k, k].

    Pure-jnp, shardable, cost-analyzable — this is the ``binary-jnp``
    backend variant (DESIGN.md §2).  The Pallas ``binary-pallas`` variant
    lives in ``repro.kernels.binary_matmul``.
    """
    kw = a_packed.shape[-1]
    assert b_packed.shape[-1] == kw, (a_packed.shape, b_packed.shape)
    if block_kw is None or block_kw >= kw:
        x = jax.lax.population_count(a_packed[..., :, None, :]
                                     ^ b_packed[None, :, :])
        mism = x.sum(axis=-1).astype(jnp.int32)
    else:
        # Chunk the contraction to bound the (..., M, N, block) intermediate.
        nblk = (kw + block_kw - 1) // block_kw
        a_p = pad_to_multiple(a_packed, block_kw, axis=-1)
        b_p = pad_to_multiple(b_packed, block_kw, axis=-1)

        def body(i, acc):
            a_c = jax.lax.dynamic_slice_in_dim(a_p, i * block_kw, block_kw, -1)
            b_c = jax.lax.dynamic_slice_in_dim(b_p, i * block_kw, block_kw, -1)
            x = jax.lax.population_count(a_c[..., :, None, :] ^ b_c[None, :, :])
            return acc + x.sum(axis=-1).astype(jnp.int32)

        acc0 = jnp.zeros((*a_packed.shape[:-1], b_packed.shape[0]), jnp.int32)
        mism = jax.lax.fori_loop(0, nblk, body, acc0)
    return jnp.int32(k) - 2 * mism


def binary_dot_unpacked_mxu(x: jax.Array, w_packed: jax.Array, k: int,
                            dtype=jnp.bfloat16) -> jax.Array:
    """``mxu-unpack`` strategy (DESIGN.md §2): unpack packed weights to ±1

    bf16 and contract on the MXU.  ``x``: (..., k) real activations (already
    binarized or not), ``w_packed``: (N, Kw).  Returns (..., N) in ``x``'s
    promoted dtype.  On TPU the unpack is a handful of VPU bit-ops fused
    into the matmul operand; HBM traffic for weights stays 1-bit.
    """
    w = unpack_bits(w_packed, k, dtype=dtype)          # (N, k) ±1
    return jnp.matmul(x.astype(dtype), w.T)


# ---------------------------------------------------------------------------
# first-layer bit-plane decomposition (paper §4.3 / eq. 3, made exact)
# ---------------------------------------------------------------------------

def bitplanes_uint8(x: jax.Array, nbits: int = 8) -> jax.Array:
    """Split fixed-precision input into bit-planes.

    ``x``: (..., K) uint8 (or int in [0, 2^nbits)).  Returns
    (nbits, ..., K) with values in {0, 1}: plane ``i`` holds bit ``i``.
    """
    x = x.astype(jnp.uint32)
    shifts = jnp.arange(nbits, dtype=jnp.uint32)
    planes = (x[None] >> shifts.reshape(nbits, *([1] * x.ndim))) & 1
    return planes


def pack_bitplanes_uint8(x: jax.Array, nbits: int = 8) -> jax.Array:
    """Split fixed-precision input into bit-planes AND channel-pack them.

    ``x``: (..., C) uint8 (or int in [0, 2^nbits)).  Returns
    (nbits, ..., ceil(C/32)) uint32.  Plane value 1 encodes logical +1
    (bit 1) and plane value 0 encodes −1 (bit 0), so the packed word IS
    the raw plane bits — no ±1 round trip.  Bit-identical to
    ``pack_bits(2*bitplanes_uint8(x)[i] - 1)`` per plane, pure jnp bit
    ops (no kernel launch — the single-launch bit-plane conv kernel
    consumes this directly).
    """
    planes = bitplanes_uint8(x, nbits)                  # (nbits, ..., C)
    cw = packed_width(x.shape[-1])
    bits = pad_to_multiple(planes.astype(WORD_DTYPE), WORD_BITS, axis=-1)
    bits = bits.reshape(*planes.shape[:-1], cw, WORD_BITS)
    shifts = jnp.arange(WORD_BITS, dtype=WORD_DTYPE)
    return (bits << shifts).sum(axis=-1, dtype=WORD_DTYPE)


def bitplane_dot(x_uint8: jax.Array, w_pm1: jax.Array, nbits: int = 8
                 ) -> jax.Array:
    """Exact first-layer dot via bit-planes (paper §4.3, exact form).

    The paper's eq. 3 composes per-plane binary dots.  A {0,1}-valued plane
    ``p`` relates to its ±1 encoding ``p̂ = 2p - 1`` by ``p = (p̂+1)/2``, so

        x . w = Σ_i 2^i (plane_i . w)
              = Σ_i 2^(i-1) ( (planê_i ⊙ w)  +  Σ_j w_j )

    where ``⊙`` is the packed XNOR-popcount dot.  The ``Σ_j w_j`` row-sum
    correction is precomputed at pack time (same spirit as the paper's §5.2
    zero-padding correction matrix).  This function is the jnp oracle; the
    packed execution path lives in ``core.binary_layers.BitplaneDense``.

    ``x_uint8``: (..., K); ``w_pm1``: (N, K) ±1.  Returns (..., N) int32,
    exactly equal to ``x.astype(i32) @ w.T``.
    """
    planes = bitplanes_uint8(x_uint8, nbits)            # (nbits, ..., K)
    planes_pm1 = 2.0 * planes.astype(jnp.float32) - 1.0
    plane_dots = jnp.einsum('p...k,nk->p...n', planes_pm1,
                            w_pm1.astype(jnp.float32))  # (nbits, ..., N)
    w_rowsum = w_pm1.sum(axis=-1).astype(jnp.float32)   # (N,)
    weights = (2.0 ** jnp.arange(nbits, dtype=jnp.float32)) / 2.0
    out = jnp.tensordot(weights, plane_dots + w_rowsum, axes=((0,), (0,)))
    return out.astype(jnp.int32)
