"""Binary layers — the paper's §5.2 layer zoo as functional JAX modules.

Every layer is a pair of pure functions over pytree params:

* ``init_*``          -> params (latent fp weights, trainable)
* ``apply_*_float``   -> the float-sign reference path (numerically defines
                         the layer; used for training with STE)
* ``pack_*``          -> inference-time conversion: sign + bit-pack the
                         weights ONCE (paper C2), precompute the padding
                         correction (C5) and the folded BN threshold
* ``apply_*_packed``  -> the optimized path on packed params

The packed path is *exactly* integer-equivalent to the float-sign path
(the paper's "numerically equivalent to BinaryNet" claim) — enforced by
tests/test_paper_equivalence.py.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import binarize as B
from repro.kernels import binary_conv as bconv
from repro.kernels import ops as kops

Params = dict[str, Any]


# ---------------------------------------------------------------------------
# Dense (fully-connected) binary layer
# ---------------------------------------------------------------------------

def init_binary_dense(key: jax.Array, in_dim: int, out_dim: int) -> Params:
    w = jax.random.uniform(key, (out_dim, in_dim), jnp.float32, -1.0, 1.0)
    return {"w": w}


def apply_binary_dense_float(params: Params, x: jax.Array,
                             *, ste: bool = False) -> jax.Array:
    """Reference: y = sign(x) . sign(W)^T, computed in fp32.

    ``ste=True`` uses the straight-through estimator on both operands
    (training path, paper §4.4).
    """
    binarize = B.binarize_ste if ste else B.sign_pm1
    xb = binarize(x.astype(jnp.float32))
    wb = binarize(params["w"])
    return jnp.dot(xb, wb.T)


def pack_binary_dense(params: Params) -> Params:
    """One-time weight packing (paper C2)."""
    w = params["w"]
    return {"w_packed": B.pack_bits(w), "k_true": w.shape[1]}


def apply_binary_dense_packed(packed: Params, x: jax.Array, *,
                              backend: str = "auto") -> jax.Array:
    """Optimized: pack(sign(x)) then XNOR-popcount GEMM.  Returns int32."""
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    x_p = kops.bitpack(x2, backend=backend)
    out = kops.binary_matmul_packed(x_p, packed["w_packed"],
                                    k_true=packed["k_true"], backend=backend)
    return out.reshape(*lead, -1)


def pack_binary_dense_grouped(params: Params, group: int) -> Params:
    """Weight packing for *pre-packed* activations with per-group padding.

    A packed conv activation flattens to (…, G·Cw) words where each group
    of ``Cw = ceil(group/32)`` words covers ``group`` channels of one
    pixel, with zero-bit tails when ``group`` is not a multiple of 32.
    Packing W the same way ((out, G, group) -> pack -> (out, G·Cw)) keeps
    the tails zero on both operands, so they XOR to no mismatches and the
    K − 2·popcount identity stays exact.
    """
    w = params["w"]                                   # (out, G*group)
    out_dim, k = w.shape
    assert k % group == 0, (k, group)
    w_packed = B.pack_bits(w.reshape(out_dim, k // group, group)
                           ).reshape(out_dim, -1)
    return {"w_packed": w_packed, "k_true": k, "group": group}


def apply_binary_dense_prepacked(packed: Params, x_packed: jax.Array, *,
                                 backend: str = "auto") -> jax.Array:
    """XNOR-popcount GEMM on an activation that is *already* bit-packed

    (the fused-epilogue output) — no unpack/repack round trip."""
    lead = x_packed.shape[:-1]
    x2 = x_packed.reshape(-1, x_packed.shape[-1])
    out = kops.binary_matmul_packed(x2, packed["w_packed"],
                                    k_true=packed["k_true"], backend=backend)
    return out.reshape(*lead, -1)


def apply_binary_dense_bn_packed(packed: Params, folded: Params,
                                 x_packed: jax.Array, *,
                                 backend: str = "auto") -> jax.Array:
    """Fused dense GEMM + BN-sign threshold + re-bitpack: packed in,

    packed out (the dense analogue of ``apply_binary_conv2d_bn_packed``).
    The (…, N) int32 activation never appears un-packed in HBM.  Returns
    (…, ceil(N/32)) uint32.
    """
    lead = x_packed.shape[:-1]
    x2 = x_packed.reshape(-1, x_packed.shape[-1])
    out = kops.binary_matmul_bn_sign_packed(
        x2, packed["w_packed"], folded["tau"], folded["flip"],
        k_true=packed["k_true"], backend=backend)
    return out.reshape(*lead, -1)


def apply_binary_dense_stack_packed(packed_layers: list, foldeds: list,
                                    x_packed: jax.Array, *,
                                    backend: str = "auto",
                                    resident: bool | None = None
                                    ) -> jax.Array:
    """The whole hidden dense stack: each layer GEMM + folded-BN

    threshold + re-bitpack, chained without un-packed activations.  On
    the pallas backend a VMEM-resident stack runs as ONE kernel launch
    (``resident=None`` auto-decides by VMEM budget; see
    ``kernels.ops.binary_dense_stack_packed``)."""
    assert len(packed_layers) == len(foldeds), (len(packed_layers),
                                                len(foldeds))
    stages = [{"w_packed": p["w_packed"], "k_true": p["k_true"],
               "tau": f["tau"], "flip": f["flip"]}
              for p, f in zip(packed_layers, foldeds)]
    lead = x_packed.shape[:-1]
    x2 = x_packed.reshape(-1, x_packed.shape[-1])
    out = kops.binary_dense_stack_packed(stages, x2, backend=backend,
                                         resident=resident)
    return out.reshape(*lead, -1)


# ---------------------------------------------------------------------------
# First-layer bit-plane dense (paper §4.3 / C4)
# ---------------------------------------------------------------------------

def pack_bitplane_dense(params: Params, nbits: int = 8) -> Params:
    w = params["w"]
    wb = B.sign_pm1(w)
    return {
        "w_packed": B.pack_bits(w),
        "k_true": w.shape[1],
        "w_rowsum": wb.sum(axis=1).astype(jnp.int32),   # the eq.3 correction
        "nbits": nbits,
    }


def apply_bitplane_dense_packed(packed: Params, x_uint8: jax.Array, *,
                                backend: str = "auto") -> jax.Array:
    """First layer on fixed-precision input, fully binary-optimized.

    Splits x into bit-planes, runs one packed GEMM per plane against the
    SAME packed weights, and recombines  y = 1/2 * sum_i 2^i (d_i + rowsum)
    (exact integer identity; see ``core.binarize.bitplane_dot``).
    Returns (..., N) int32 == x.astype(int32) @ sign(W)^T.
    """
    nbits = packed["nbits"]
    lead = x_uint8.shape[:-1]
    x2 = x_uint8.reshape(-1, x_uint8.shape[-1])
    planes = B.bitplanes_uint8(x2, nbits)                # (nbits, M, K) {0,1}
    # Encode planes as ±1 by value>=?: bit 1 -> +1, bit 0 -> -1: pack_bits
    # packs >=0 as 1, so shift to {-1,+1} first.
    planes_pm1 = 2.0 * planes.astype(jnp.float32) - 1.0
    acc = None
    for i in range(nbits):
        x_p = kops.bitpack(planes_pm1[i], backend=backend)
        d = kops.binary_matmul_packed(x_p, packed["w_packed"],
                                      k_true=packed["k_true"],
                                      backend=backend)   # (M, N) int32
        term = (d + packed["w_rowsum"][None, :]) << i
        acc = term if acc is None else acc + term
    out = acc >> 1                                        # exact: acc is even
    return out.reshape(*lead, -1)


def apply_bitplane_dense_float(params: Params, x_uint8: jax.Array
                               ) -> jax.Array:
    """Reference: integer GEMM of raw uint8 input against sign(W)."""
    wb = B.sign_pm1(params["w"])
    return jnp.dot(x_uint8.astype(jnp.float32), wb.T)


# ---------------------------------------------------------------------------
# Binary 2D convolution (paper C5/C6): im2col on packed words + correction
# ---------------------------------------------------------------------------

def init_binary_conv2d(key: jax.Array, kh: int, kw: int, c_in: int,
                       c_out: int) -> Params:
    w = jax.random.uniform(key, (c_out, kh, kw, c_in), jnp.float32, -1, 1)
    return {"w": w}


def apply_binary_conv2d_float(params: Params, x: jax.Array, *,
                              stride: int = 1, padding: str = "SAME",
                              ste: bool = False) -> jax.Array:
    """Reference: fp conv of sign(x) with sign(W), true zero padding."""
    binarize = B.binarize_ste if ste else B.sign_pm1
    xb = binarize(x.astype(jnp.float32))
    wb = binarize(params["w"])                        # (O, KH, KW, I)
    return jax.lax.conv_general_dilated(
        xb, jnp.transpose(wb, (1, 2, 3, 0)),          # HWIO
        window_strides=(stride, stride), padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def pack_binary_conv2d(params: Params, *, input_hw: tuple[int, int],
                       stride: int = 1, padding: str = "SAME") -> Params:
    """Pack weights along channels-per-tap (paper C3) and precompute the

    zero-padding correction matrix (paper C5) — delegated to the kernel
    subsystem's plan builder (``kernels.binary_conv.make_conv_plan``),
    which every conv backend consumes.
    """
    return bconv.make_conv_plan(params["w"], input_hw=input_hw,
                                stride=stride, padding=padding)


def apply_binary_conv2d_packed(packed: Params, x_packed: jax.Array, *,
                               backend: str = "auto") -> jax.Array:
    """Optimized conv: in-kernel im2col -> XNOR popcount -> +correction.

    ``x_packed``: (B, H, W, Cw) channel-packed input (pack C with
    ``kops.bitpack`` / previous layer's packed activation).  Returns
    (B, H', W', C_out) int32.  The 'pallas' backend gathers the KH·KW
    packed taps in VMEM — the patch matrix is never materialized in HBM
    ('jnp'/'ref' keep the old host-side im2col as the oracle).
    """
    return kops.binary_conv2d_packed(packed, x_packed, backend=backend)


def apply_binary_conv2d_bn_packed(packed: Params, folded: Params,
                                  x_packed: jax.Array, *,
                                  backend: str = "auto") -> jax.Array:
    """Fused conv + BN-sign threshold + re-bitpack: packed in, packed out.

    The inter-layer activation never appears un-packed in HBM.  Returns
    (B, H', W', ceil(C_out/32)) uint32.
    """
    return kops.binary_conv2d_bn_sign_packed(packed, folded, x_packed,
                                             backend=backend)


def localize_conv_plan(plan: Params, n_shards: int) -> Params:
    """Per-shard view of a conv plan whose C_out axis is split ``n_shards``
    ways (the C_out-parallel sharded forward, XNOR-Net-style decomposition).

    The array leaves (``w_packed``, ``correction``, ``rowsum``) arrive
    already sliced by the partitioner — only the static ``c_out`` needs
    rewriting so the kernel dispatch sees the LOCAL output-channel count.
    ``k_true``, geometry, and ``cw`` are contraction-side statics and stay
    global: every shard consumes the full input.
    """
    if n_shards == 1:
        return plan
    c_out = plan["c_out"]
    assert c_out % n_shards == 0, (c_out, n_shards)
    return {**plan, "c_out": c_out // n_shards}


# ---------------------------------------------------------------------------
# First-layer bit-plane conv (paper §4.3 / C4)
# ---------------------------------------------------------------------------

def pack_bitplane_conv2d(params: Params, *, input_hw: tuple[int, int],
                         stride: int = 1, padding: str = "SAME",
                         nbits: int = 8) -> Params:
    """Conv plan for the fixed-precision first layer: per-tap weight

    packing plus the all-taps rowsum that absorbs both the {0,1}->±1
    plane shift and the zero-pad correction (the C5 correction is
    identically zero, so the plan carries none — see
    ``kernels.binary_conv.make_bitplane_conv_plan``).
    """
    return bconv.make_bitplane_conv_plan(params["w"], input_hw=input_hw,
                                         stride=stride, padding=padding,
                                         nbits=nbits)


def apply_bitplane_conv2d_packed(packed: Params, x_uint8: jax.Array, *,
                                 backend: str = "auto") -> jax.Array:
    """First conv layer on raw fixed-precision input, fully binary.

    On the pallas backend this is ONE kernel launch — the plane loop runs
    in-kernel over a VMEM-resident plane stack (previously 8 sequential
    per-plane conv launches).  Returns (B, H', W', C_out) int32 ==
    integer conv of the raw input against sign(W), true zero padding.
    """
    return kops.bitplane_conv2d_packed(packed, x_uint8, backend=backend)


# ---------------------------------------------------------------------------
# Batch-norm (inference) + sign, and the folded threshold form
# ---------------------------------------------------------------------------

def init_batchnorm(c: int) -> Params:
    return {"gamma": jnp.ones((c,)), "beta": jnp.zeros((c,)),
            "mean": jnp.zeros((c,)), "var": jnp.ones((c,))}


def apply_batchnorm(params: Params, x: jax.Array, eps: float = 1e-5
                    ) -> jax.Array:
    inv = params["gamma"] * jax.lax.rsqrt(params["var"] + eps)
    return (x.astype(jnp.float32) - params["mean"]) * inv + params["beta"]


def fold_bn_sign(params: Params, eps: float = 1e-5) -> Params:
    """Fold BN + sign into a per-channel integer threshold compare.

    sign(gamma*(x-mu)*inv_sigma + beta) == flip * sign(x - tau) with
    tau = mu - beta*sigma/gamma,  flip = sign(gamma).  (Beyond-paper BCNN
    inference optimization — removes all fp math between binary GEMMs, so
    the GEMM epilogue emits packed bits directly.)
    """
    sigma = jnp.sqrt(params["var"] + eps)
    gamma = params["gamma"]
    tau = params["mean"] - params["beta"] * sigma / gamma
    flip = jnp.where(gamma >= 0, 1.0, -1.0)
    return {"tau": tau, "flip": flip}


def apply_bn_sign_folded(folded: Params, x_int: jax.Array) -> jax.Array:
    """±1 output of sign(BN(x)) computed as a threshold compare on the raw

    integer GEMM output — no fp normalization in the inference path."""
    ge = (x_int.astype(jnp.float32) >= folded["tau"])
    pm1 = jnp.where(ge, 1.0, -1.0) * folded["flip"]
    return pm1


def apply_bn_sign_folded_packed(folded: Params, x_int: jax.Array, *,
                                backend: str = "auto") -> jax.Array:
    """Fused sign(BN(x)) + bit-pack along the channel axis (one kernel).

    Bit-identical to ``pack_bits(apply_bn_sign_folded(folded, x))`` but
    the ±1 float activation is never materialized.  Returns
    (..., ceil(C/32)) uint32."""
    return kops.bn_sign_pack(x_int, folded["tau"], folded["flip"],
                             backend=backend)


# ---------------------------------------------------------------------------
# Pooling
# ---------------------------------------------------------------------------

def maxpool2d(x: jax.Array, window: int = 2, stride: int | None = None
              ) -> jax.Array:
    stride = stride or window
    if jnp.issubdtype(x.dtype, jnp.integer):
        init = jnp.iinfo(x.dtype).min
    else:
        init = -jnp.inf
    return jax.lax.reduce_window(
        x, init, jax.lax.max,
        window_dimensions=(1, window, window, 1),
        window_strides=(1, stride, stride, 1), padding="VALID")


def pool_flip_mask(folded: Params) -> jax.Array:
    """Packed per-channel mask of ``flip > 0`` for :func:`maxpool2d_packed`."""
    return B.pack_bits(folded["flip"])


def maxpool2d_packed(x_packed: jax.Array, flip_mask: jax.Array,
                     window: int = 2, stride: int | None = None) -> jax.Array:
    """Max-pool entirely in the packed bit domain.

    The forward order conv -> maxpool(int) -> sign(BN(·)) commutes with
    thresholding because BN-sign is monotone per channel:
    ``(max_i x_i >= tau) == OR_i (x_i >= tau)``.  After the fused epilogue
    each bit is ``(x >= tau) XNOR (flip > 0)``, so pooling the *bits* is
    OR where flip > 0 and AND where flip < 0 — two bitwise reduce_windows
    and a mask select, no unpacking.  Zero-bit channel tails stay zero
    through the AND branch because the mask is zero there too.
    """
    stride = stride or window
    dims = (1, window, window, 1)
    strides = (1, stride, stride, 1)
    any_set = jax.lax.reduce_window(x_packed, jnp.uint32(0),
                                    jax.lax.bitwise_or, dims, strides,
                                    "VALID")
    all_set = jax.lax.reduce_window(x_packed, jnp.uint32(0xFFFFFFFF),
                                    jax.lax.bitwise_and, dims, strides,
                                    "VALID")
    return (any_set & flip_mask) | (all_set & ~flip_mask)
