"""Binary layers — the paper's §5.2 layer zoo as functional JAX modules.

Every layer is a pair of pure functions over pytree params:

* ``init_*``          -> params (latent fp weights, trainable)
* ``apply_*_float``   -> the float-sign reference path (numerically defines
                         the layer; used for training with STE)
* ``pack_*``          -> inference-time conversion: sign + bit-pack the
                         weights ONCE (paper C2), precompute the padding
                         correction (C5) and the folded BN threshold
* ``apply_*_packed``  -> the optimized path on packed params

The packed path is *exactly* integer-equivalent to the float-sign path
(the paper's "numerically equivalent to BinaryNet" claim) — enforced by
tests/test_paper_equivalence.py.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import binarize as B
from repro.kernels import ops as kops

Params = dict[str, Any]


# ---------------------------------------------------------------------------
# Dense (fully-connected) binary layer
# ---------------------------------------------------------------------------

def init_binary_dense(key: jax.Array, in_dim: int, out_dim: int) -> Params:
    w = jax.random.uniform(key, (out_dim, in_dim), jnp.float32, -1.0, 1.0)
    return {"w": w}


def apply_binary_dense_float(params: Params, x: jax.Array,
                             *, ste: bool = False) -> jax.Array:
    """Reference: y = sign(x) . sign(W)^T, computed in fp32.

    ``ste=True`` uses the straight-through estimator on both operands
    (training path, paper §4.4).
    """
    binarize = B.binarize_ste if ste else B.sign_pm1
    xb = binarize(x.astype(jnp.float32))
    wb = binarize(params["w"])
    return jnp.dot(xb, wb.T)


def pack_binary_dense(params: Params) -> Params:
    """One-time weight packing (paper C2)."""
    w = params["w"]
    return {"w_packed": B.pack_bits(w), "k_true": w.shape[1]}


def apply_binary_dense_packed(packed: Params, x: jax.Array, *,
                              backend: str = "auto") -> jax.Array:
    """Optimized: pack(sign(x)) then XNOR-popcount GEMM.  Returns int32."""
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    x_p = kops.bitpack(x2, backend=backend)
    out = kops.binary_matmul_packed(x_p, packed["w_packed"],
                                    k_true=packed["k_true"], backend=backend)
    return out.reshape(*lead, -1)


# ---------------------------------------------------------------------------
# First-layer bit-plane dense (paper §4.3 / C4)
# ---------------------------------------------------------------------------

def pack_bitplane_dense(params: Params, nbits: int = 8) -> Params:
    w = params["w"]
    wb = B.sign_pm1(w)
    return {
        "w_packed": B.pack_bits(w),
        "k_true": w.shape[1],
        "w_rowsum": wb.sum(axis=1).astype(jnp.int32),   # the eq.3 correction
        "nbits": nbits,
    }


def apply_bitplane_dense_packed(packed: Params, x_uint8: jax.Array, *,
                                backend: str = "auto") -> jax.Array:
    """First layer on fixed-precision input, fully binary-optimized.

    Splits x into bit-planes, runs one packed GEMM per plane against the
    SAME packed weights, and recombines  y = 1/2 * sum_i 2^i (d_i + rowsum)
    (exact integer identity; see ``core.binarize.bitplane_dot``).
    Returns (..., N) int32 == x.astype(int32) @ sign(W)^T.
    """
    nbits = packed["nbits"]
    lead = x_uint8.shape[:-1]
    x2 = x_uint8.reshape(-1, x_uint8.shape[-1])
    planes = B.bitplanes_uint8(x2, nbits)                # (nbits, M, K) {0,1}
    # Encode planes as ±1 by value>=?: bit 1 -> +1, bit 0 -> -1: pack_bits
    # packs >=0 as 1, so shift to {-1,+1} first.
    planes_pm1 = 2.0 * planes.astype(jnp.float32) - 1.0
    acc = None
    for i in range(nbits):
        x_p = kops.bitpack(planes_pm1[i], backend=backend)
        d = kops.binary_matmul_packed(x_p, packed["w_packed"],
                                      k_true=packed["k_true"],
                                      backend=backend)   # (M, N) int32
        term = (d + packed["w_rowsum"][None, :]) << i
        acc = term if acc is None else acc + term
    out = acc >> 1                                        # exact: acc is even
    return out.reshape(*lead, -1)


def apply_bitplane_dense_float(params: Params, x_uint8: jax.Array
                               ) -> jax.Array:
    """Reference: integer GEMM of raw uint8 input against sign(W)."""
    wb = B.sign_pm1(params["w"])
    return jnp.dot(x_uint8.astype(jnp.float32), wb.T)


# ---------------------------------------------------------------------------
# Binary 2D convolution (paper C5/C6): im2col on packed words + correction
# ---------------------------------------------------------------------------

def init_binary_conv2d(key: jax.Array, kh: int, kw: int, c_in: int,
                       c_out: int) -> Params:
    w = jax.random.uniform(key, (c_out, kh, kw, c_in), jnp.float32, -1, 1)
    return {"w": w}


def apply_binary_conv2d_float(params: Params, x: jax.Array, *,
                              stride: int = 1, padding: str = "SAME",
                              ste: bool = False) -> jax.Array:
    """Reference: fp conv of sign(x) with sign(W), true zero padding."""
    binarize = B.binarize_ste if ste else B.sign_pm1
    xb = binarize(x.astype(jnp.float32))
    wb = binarize(params["w"])                        # (O, KH, KW, I)
    return jax.lax.conv_general_dilated(
        xb, jnp.transpose(wb, (1, 2, 3, 0)),          # HWIO
        window_strides=(stride, stride), padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def pack_binary_conv2d(params: Params, *, input_hw: tuple[int, int],
                       stride: int = 1, padding: str = "SAME") -> Params:
    """Pack weights along channels-per-tap (paper C3) and precompute the

    zero-padding correction matrix (paper C5): since the packed kernel
    treats padded pixels as -1, the true zero-pad result is
    ``packed_result + conv(W, pad_indicator)`` — computed once per layer
    for the layer's input spatial size.
    """
    w = params["w"]                                   # (O, KH, KW, I)
    c_out, kh, kw, c_in = w.shape
    w_flat = B.sign_pm1(w).reshape(c_out, kh * kw * c_in)
    # Per-tap channel packing: (O, KH*KW, I) -> pack I -> (O, KH*KW*Iw)
    w_taps = B.sign_pm1(w).reshape(c_out, kh * kw, c_in)
    w_packed = B.pack_bits(w_taps).reshape(c_out, -1)

    h, wdt = input_hw
    if padding == "SAME":
        out_h = -(-h // stride)
        out_w = -(-wdt // stride)
        pad_h = max((out_h - 1) * stride + kh - h, 0)
        pad_w = max((out_w - 1) * stride + kw - wdt, 0)
        pads = ((pad_h // 2, pad_h - pad_h // 2),
                (pad_w // 2, pad_w - pad_w // 2))
    else:
        out_h = (h - kh) // stride + 1
        out_w = (wdt - kw) // stride + 1
        pads = ((0, 0), (0, 0))

    # Correction (C5): pad_mask is 1 on the padded ring, 0 inside.  The
    # packed conv computes sum_w*(-1) at pad taps; truth is 0, so add
    # +sum_{pad taps} w == valid-correlate(pad_mask, sum_c w).
    pad_mask = jnp.pad(jnp.zeros((h, wdt), jnp.float32), pads,
                       constant_values=1.0)
    w_tap_sum = B.sign_pm1(w).sum(axis=3)             # (O, KH, KW)
    corr = jax.lax.conv_general_dilated(
        pad_mask[None, :, :, None],
        jnp.transpose(w_tap_sum, (1, 2, 0))[:, :, None, :],  # HWIO, I=1
        window_strides=(stride, stride), padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))[0]       # (H', W', O)

    return {
        "w_packed": w_packed, "k_true": kh * kw * c_in,
        "kh": kh, "kw": kw, "c_in": c_in, "c_out": c_out,
        "stride": stride, "pads": pads,
        "out_hw": (out_h, out_w),
        "correction": corr.astype(jnp.int32),
        "w_flat_shape": w_flat.shape,
    }


def _extract_patches_packed(x_packed: jax.Array, kh: int, kw: int,
                            stride: int, pads) -> jax.Array:
    """im2col over channel-packed words (free-lift layout, paper C3/C6).

    ``x_packed``: (B, H, W, Cw) uint32.  Spatial zero-word padding encodes
    all-(-1) pixels — exactly the paper's "treat pad as -1" convention.
    Returns (B, H', W', KH*KW*Cw).
    """
    xp = jnp.pad(x_packed, ((0, 0), pads[0], pads[1], (0, 0)),
                 constant_values=0)                    # 0-words == all -1
    bsz, hp, wp, cw = xp.shape
    out_h = (hp - kh) // stride + 1
    out_w = (wp - kw) // stride + 1
    cols = []
    for di in range(kh):
        for dj in range(kw):
            sl = xp[:, di:di + out_h * stride:stride,
                    dj:dj + out_w * stride:stride, :]
            cols.append(sl)
    return jnp.concatenate(cols, axis=-1)


def apply_binary_conv2d_packed(packed: Params, x_packed: jax.Array, *,
                               backend: str = "auto") -> jax.Array:
    """Optimized conv: packed im2col -> XNOR GEMM -> +correction (int32).

    ``x_packed``: (B, H, W, Cw) channel-packed input (pack C with
    ``kops.bitpack`` / previous layer's packed activation).  The "lift"
    back to a tensor is a free reshape (paper C3).
    """
    patches = _extract_patches_packed(x_packed, packed["kh"], packed["kw"],
                                      packed["stride"], packed["pads"])
    bsz, oh, ow, kcw = patches.shape
    flat = patches.reshape(bsz * oh * ow, kcw)
    out = kops.binary_matmul_packed(flat, packed["w_packed"],
                                    k_true=packed["k_true"], backend=backend)
    out = out.reshape(bsz, oh, ow, packed["c_out"])
    return out + packed["correction"][None]


# ---------------------------------------------------------------------------
# Batch-norm (inference) + sign, and the folded threshold form
# ---------------------------------------------------------------------------

def init_batchnorm(c: int) -> Params:
    return {"gamma": jnp.ones((c,)), "beta": jnp.zeros((c,)),
            "mean": jnp.zeros((c,)), "var": jnp.ones((c,))}


def apply_batchnorm(params: Params, x: jax.Array, eps: float = 1e-5
                    ) -> jax.Array:
    inv = params["gamma"] * jax.lax.rsqrt(params["var"] + eps)
    return (x.astype(jnp.float32) - params["mean"]) * inv + params["beta"]


def fold_bn_sign(params: Params, eps: float = 1e-5) -> Params:
    """Fold BN + sign into a per-channel integer threshold compare.

    sign(gamma*(x-mu)*inv_sigma + beta) == flip * sign(x - tau) with
    tau = mu - beta*sigma/gamma,  flip = sign(gamma).  (Beyond-paper BCNN
    inference optimization — removes all fp math between binary GEMMs, so
    the GEMM epilogue emits packed bits directly.)
    """
    sigma = jnp.sqrt(params["var"] + eps)
    gamma = params["gamma"]
    tau = params["mean"] - params["beta"] * sigma / gamma
    flip = jnp.where(gamma >= 0, 1.0, -1.0)
    return {"tau": tau, "flip": flip}


def apply_bn_sign_folded(folded: Params, x_int: jax.Array) -> jax.Array:
    """±1 output of sign(BN(x)) computed as a threshold compare on the raw

    integer GEMM output — no fp normalization in the inference path."""
    ge = (x_int.astype(jnp.float32) >= folded["tau"])
    pm1 = jnp.where(ge, 1.0, -1.0) * folded["flip"]
    return pm1


# ---------------------------------------------------------------------------
# Pooling
# ---------------------------------------------------------------------------

def maxpool2d(x: jax.Array, window: int = 2, stride: int | None = None
              ) -> jax.Array:
    stride = stride or window
    if jnp.issubdtype(x.dtype, jnp.integer):
        init = jnp.iinfo(x.dtype).min
    else:
        init = -jnp.inf
    return jax.lax.reduce_window(
        x, init, jax.lax.max,
        window_dimensions=(1, window, window, 1),
        window_strides=(1, stride, stride, 1), padding="VALID")
