"""Quantization policy — how the paper's technique plugs into any model.

``QuantMode`` selects, per model or per layer, how linear maps execute:

* ``FLOAT``          — bf16/fp32 reference path (the literature config).
* ``BINARY_WEIGHT``  — 1-bit packed weights, real activations
                       (``mxu-unpack`` strategy: 32x weight-memory cut,
                       contraction still on the MXU).
* ``BINARY``         — 1-bit weights AND activations (paper-faithful
                       BinaryNet semantics: sign activation + STE,
                       XNOR-popcount dot, bit-plane first layer).

``GemmStrategy`` selects the execution strategy for binary dots on TPU
(DESIGN.md §2 — the GPU-vs-TPU inversion):

* ``VPU_XNOR``   — packed XOR+popcount on the vector unit; wins when the
                   layer is memory-bound (decode / batch-1 serving).
* ``MXU_UNPACK`` — unpack ±1 to bf16, contract on the MXU; wins when the
                   layer is compute-bound (training, prefill).
* ``AUTO``       — pick by arithmetic intensity of the call site.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass


class QuantMode(str, enum.Enum):
    FLOAT = "float"
    BINARY_WEIGHT = "binary_weight"
    BINARY = "binary"


class GemmStrategy(str, enum.Enum):
    VPU_XNOR = "vpu_xnor"
    MXU_UNPACK = "mxu_unpack"
    AUTO = "auto"


@dataclass(frozen=True)
class QuantConfig:
    mode: QuantMode = QuantMode.FLOAT
    strategy: GemmStrategy = GemmStrategy.AUTO
    # Keep the first/last layers full precision?  BinaryNet binarizes all
    # layers; Espresso's C4 makes even the first layer binary via
    # bit-planes.  For LM quality experiments, embeddings/logits usually
    # stay fp (BitNet convention) — expose the knob.
    binarize_embeddings: bool = False
    binarize_lm_head: bool = False

    def resolve_strategy(self, m: int, n: int, k: int) -> GemmStrategy:
        """AUTO rule: a GEMM with few output rows per weight byte is
        memory-bound -> VPU_XNOR; otherwise MXU_UNPACK.

        Napkin model (v5e): MXU peak 197 TFLOP/s vs VPU ~2.6 Tops/s int32
        (8x128 lanes x 2 ops x 940 MHz x 8 cores — order of magnitude).
        Unpacked bf16 GEMM moves 2*K*N weight bytes; packed moves K*N/32...
        wait, /8 bits -> K*N/8 bytes at 1 bit... K*N/8.  The crossover in M
        (rows amortizing the weight read) is
            M* ~ (peak_flops / hbm_bw) * (2 bytes / (2 flops/elt)) ~ 240
        so decode batches (M <= 256) favor the packed path purely on HBM
        bytes; large-M prefill/training favors the MXU.
        """
        del n, k
        return GemmStrategy.VPU_XNOR if m <= 256 else GemmStrategy.MXU_UNPACK
