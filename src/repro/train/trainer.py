"""Training step: loss + grad + AdamW, with microbatched gradient

accumulation (a ``lax.scan`` over microbatches so the live activation set
is one microbatch — the standard memory/throughput lever at 4k x 256
global batch), optional 1-bit gradient compression (signSGD-EF), and the
paper's latent clipping in binary mode.

``make_train_step`` returns a pure function suitable for ``jax.jit`` with
``in_shardings`` from ``repro.distributed.sharding`` and
``donate_argnums`` on (params, opt_state).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.quantize import QuantMode
from repro.models import model as M
from repro.optim import adamw as OPT
from repro.optim import compress as CMP
from repro.optim.schedule import cosine_schedule
from repro.utils.flags import in_analysis_mode, xscan


@dataclass(frozen=True)
class TrainConfig:
    microbatches: int = 1
    compress_grads: bool = False
    grads_bf16: bool = False       # mixed precision: differentiate w.r.t.
                                   # bf16 weight casts -> bf16 grad
                                   # all-reduce (half the DP wire bytes);
                                   # AdamW updates the fp32 masters.
    lr: float = 3e-4
    warmup: int = 100
    total_steps: int = 10000


def make_opt_config(cfg: ArchConfig, tc: TrainConfig) -> OPT.AdamWConfig:
    return OPT.AdamWConfig(lr=tc.lr,
                           clip_latent=cfg.quant.mode != QuantMode.FLOAT)


def init_train_state(key: jax.Array, cfg: ArchConfig,
                     tc: TrainConfig) -> dict:
    params = M.init_model(key, cfg)
    state = {"params": params, "opt": OPT.adamw_init(params)}
    if tc.compress_grads:
        state["ef_error"] = CMP.signsgd_ef_init(params)
    return state


def _split_microbatches(batch: dict, n: int) -> dict:
    def split(x):
        b = x.shape[0]
        assert b % n == 0, (b, n)
        return x.reshape(n, b // n, *x.shape[1:])
    return jax.tree.map(split, batch)


def make_train_step(cfg: ArchConfig, tc: TrainConfig):
    opt_cfg = make_opt_config(cfg, tc)

    def train_step(state: dict, batch: dict) -> tuple[dict, dict]:
        params = state["params"]
        microbatches = 1 if in_analysis_mode() else tc.microbatches
        if tc.grads_bf16:
            params = jax.tree.map(
                lambda p: p.astype(jnp.bfloat16)
                if p.dtype == jnp.float32 else p, params)

        def loss_of(p, b):
            return M.loss_fn(p, cfg, b)

        if microbatches > 1:
            micro = _split_microbatches(batch, microbatches)

            def acc_body(carry, mb):
                loss_sum, gsum = carry
                loss, g = jax.value_and_grad(loss_of)(params, mb)
                gsum = jax.tree.map(jnp.add, gsum, g)
                return (loss_sum + loss, gsum), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, p.dtype),
                              params)
            (loss_sum, gsum), _ = xscan(acc_body,
                                        (jnp.float32(0.), g0), micro)
            loss = loss_sum / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, gsum)
        else:
            loss, grads = jax.value_and_grad(loss_of)(params, batch)

        if tc.compress_grads:
            grads, new_err = CMP.signsgd_ef_compress(grads,
                                                     state["ef_error"])

        lr_scale = cosine_schedule(state["opt"]["step"], warmup=tc.warmup,
                                   total=tc.total_steps)
        new_params, new_opt, gnorm = OPT.adamw_update(
            opt_cfg, state["params"], grads, state["opt"], lr_scale)
        new_state = {"params": new_params, "opt": new_opt}
        if tc.compress_grads:
            new_state["ef_error"] = new_err
        metrics = {"loss": loss, "grad_norm": gnorm,
                   "lr": lr_scale * opt_cfg.lr}
        return new_state, metrics

    return train_step
