"""Serving layer: the Espresso prediction-phase engine + the LM driver.

Two servers live here (see ``docs/serving.md``):

* :class:`PackedInferenceServer` — the paper's whole point made
  operational: a forward-only engine over the packed BCNN/BMLP networks
  (``models/cnn.py``) with a continuous-batching request queue
  (admit/evict per step, deadline-aware flush, no head-of-line blocking
  on ragged arrivals), a packed weight cache keyed by model config
  (pack + fold BN thresholds ONCE, paper C2, reused across requests),
  and a packed-activation scratch pool so steady-state serving does
  zero repacking and zero per-flush host allocation.  Flushes of
  batch ≤ 8 lower to the PR-4 N-major GEMV grid and larger flushes to
  the fused GEMM/stack path — decided by the ONE
  ``kernels.ops.dispatch_batch`` seam the kernels themselves consult.
  A ``(data, model)`` mesh can sit behind the queue: pass
  ``mesh=`` and the engine builds on
  ``distributed.sharding.make_sharded_forward``, sizing its flush
  buckets to the mesh's ``batch_multiple``.

* :class:`BatchedServer` — the LM decode driver (continuous batching
  over a shared KV-cache slot ring); ``make_prefill_step`` /
  ``make_decode_step`` are the step factories the dry-run cells lower.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.kernels import ops as kops
from repro.models import cnn as C
from repro.models import model as M
from repro.telemetry import MetricsRegistry, Telemetry


# ---------------------------------------------------------------------------
# Packed-inference serving (Espresso prediction phase)
# ---------------------------------------------------------------------------

class BackpressureError(RuntimeError):
    """Typed admission shed: the queue is full, the request was NEVER
    admitted (no rid) — the caller sheds or retries later.  Subclasses
    ``RuntimeError`` so pre-existing callers that caught the untyped
    backpressure signal keep working."""


class DeviceLossError(RuntimeError):
    """A device backing the active engine disappeared mid-flush.

    NOT batch-local: retrying or bisecting the batch cannot help when
    the hardware under the compiled forward is gone, so the server
    requeues the in-flight window (zero requests lost) and re-raises
    for a supervisor (``runtime.ServingSupervisor``) to shrink the mesh
    and rebuild the engine on the survivors.
    """

    def __init__(self, survivors: int, msg: str | None = None):
        super().__init__(msg or f"device lost; {survivors} survivor(s)")
        self.survivors = survivors


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded exponential backoff for failing flushes.

    A cohort gets ``1 + max_retries`` dispatch attempts; the k-th retry
    sleeps ``min(max_backoff_s, backoff_base_s * backoff_factor**(k-1))``
    first.  Once the budget is spent a multi-request cohort BISECTS —
    each half gets a fresh budget — so one poison request cannot
    repeatedly kill whole cohorts: bisection isolates it in
    ``O(log batch)`` dispatches and only the singleton completes as
    ``error``.  ``DeviceLossError`` is never retried here (it is not a
    batch-local fault; see its docstring).
    """
    max_retries: int = 2
    backoff_base_s: float = 0.001
    backoff_factor: float = 2.0
    max_backoff_s: float = 0.250

    def backoff(self, attempt: int) -> float:
        """Sleep before retry ``attempt`` (1-based), capped."""
        return min(self.max_backoff_s,
                   self.backoff_base_s * self.backoff_factor
                   ** (attempt - 1))


#: Terminal request states (exactly one per admitted request):
#: served (``ok``), deadline exceeded past the grace factor
#: (``timeout``), flush failed after retries + bisection (``error``).
#: The fourth lifecycle outcome, ``shed``, never gets a rid — ``submit``
#: raises :class:`BackpressureError` before admission.
TERMINAL_STATES = ("ok", "timeout", "error")


@dataclasses.dataclass
class ServeRequest:
    """One forward request in the continuous-batching queue.

    ``x`` is a single example (shape ``models.cnn.packed_input_shape``,
    uint8); ``deadline`` is the absolute clock time by which the request
    must be flushed even if the batch is not full.  ``status`` moves
    ``pending`` → exactly one of :data:`TERMINAL_STATES`; ``result`` /
    ``completed_at`` are filled at completion (``result`` stays None and
    ``error`` carries the exception for non-``ok`` outcomes).
    """
    rid: int
    x: Any
    deadline: float
    submitted_at: float
    status: str = "pending"
    error: BaseException | None = None
    result: np.ndarray | None = None
    completed_at: float | None = None
    # tracer-clock stamp (perf_counter_ns) taken at submit when tracing
    # is enabled — the queue-wait span's start point.  The serving clock
    # may be simulated (SimClock), so it cannot anchor trace timestamps.
    trace_submit_ns: int | None = None

    @property
    def latency(self) -> float | None:
        if self.completed_at is None:
            return None
        return self.completed_at - self.submitted_at


@dataclasses.dataclass(frozen=True)
class FlushRecord:
    """Per-flush bookkeeping: how many real requests rode which bucket
    through which dense grid (``route`` ∈ {'gemv', 'gemm'}), and how
    many retry attempts the dispatch needed (0 on the healthy path)."""
    batch: int
    bucket: int
    route: str
    at: float
    wall_s: float
    retries: int = 0


class PackedModelCache:
    """Pack/fold-once cache keyed by model config (paper C2).

    ``get_or_pack(key, pack_fn)`` returns the cached packed tree for
    ``key`` or calls ``pack_fn()`` exactly once and caches the result —
    re-registering a config the server has already seen (including
    after swapping to a different model and back) never re-packs
    weights or re-folds BN thresholds.  ``invalidate(key)`` drops an
    entry when its underlying parameters changed (the ONLY correct
    response to a weight update — packed trees are derived data).
    Hit/miss/invalidation counts live in a telemetry metrics registry
    (``serve.cache.*`` — pass the server's via ``metrics=``, or a fresh
    one is created); ``hits``/``misses`` remain as read-only views.
    """

    def __init__(self, metrics: MetricsRegistry | None = None):
        self._entries: dict[Any, Any] = {}
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._hits = self.metrics.counter("serve.cache.hits")
        self._misses = self.metrics.counter("serve.cache.misses")
        self._invalidations = self.metrics.counter(
            "serve.cache.invalidations")

    @property
    def hits(self) -> int:
        return self._hits.value

    @property
    def misses(self) -> int:
        return self._misses.value

    def get_or_pack(self, key, pack_fn: Callable[[], Any]):
        if key in self._entries:
            self._hits.inc()
        else:
            self._misses.inc()
            self._entries[key] = pack_fn()
        return self._entries[key]

    def invalidate(self, key) -> bool:
        """Drop ``key``; True if it was cached."""
        dropped = self._entries.pop(key, None) is not None
        if dropped:
            self._invalidations.inc()
        return dropped

    def __contains__(self, key) -> bool:
        return key in self._entries

    def __len__(self) -> int:
        return len(self._entries)


class ActivationPool:
    """Reusable host staging buffers, one per (bucket, example shape).

    Steady-state serving writes every flush into the same preallocated
    buffer — ``allocations`` stops growing once all buckets are warm
    (asserted by ``benchmarks/serve_latency.py``), so the request path
    allocates nothing per flush.  Inter-stage activations never appear
    here at all: they stay bit-packed on device inside the jitted
    forward (the fused-epilogue contract, ``docs/kernels.md``).

    Buffer accounting lives in a telemetry metrics registry
    (``serve.pool.allocations`` / ``serve.pool.reuses`` — pass the
    server's via ``metrics=``); ``allocations`` remains a read-only
    view.
    """

    def __init__(self, metrics: MetricsRegistry | None = None):
        self._bufs: dict[tuple, np.ndarray] = {}
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._allocations = self.metrics.counter("serve.pool.allocations")
        self._reuses = self.metrics.counter("serve.pool.reuses")

    @property
    def allocations(self) -> int:
        return self._allocations.value

    def batch_buffer(self, bucket: int, example_shape: tuple[int, ...],
                     dtype=np.uint8) -> np.ndarray:
        key = (bucket, tuple(example_shape), np.dtype(dtype).str)
        buf = self._bufs.get(key)
        if buf is None:
            self._allocations.inc()
            buf = np.zeros((bucket, *example_shape), dtype)
            self._bufs[key] = buf
        else:
            self._reuses.inc()
        return buf


@dataclasses.dataclass
class _Engine:
    """One registered model: its packed tree + compiled forward + the
    static facts the queue needs to size and route flushes."""
    kind: str
    packed: Any
    fwd: Callable[[Any], jax.Array]
    example_shape: tuple[int, ...]
    kw_words: int
    batch_multiple: int
    buckets: tuple[int, ...]


def _default_buckets(max_batch: int) -> tuple[int, ...]:
    out, b = [], 1
    while b < max_batch:
        out.append(b)
        b *= 2
    out.append(max_batch)
    return tuple(sorted(set(out)))


class PackedInferenceServer:
    """Continuous-batching server over the packed BCNN/BMLP forwards.

    Queue lifecycle (``docs/serving.md``): ``submit`` admits a request
    FIFO with an absolute flush ``deadline``; every ``step`` flushes
    (a) all full ``max_batch`` windows and (b) — once the OLDEST
    pending deadline has expired — everything still queued, padded up
    to the smallest warm bucket.  Arrivals after a flush started simply
    ride the next one, so a ragged arrival can neither block earlier
    requests (they flush on their own deadline) nor be blocked by them
    (the deadline flush takes the whole queue, not just the expired
    prefix).  ``cancel`` evicts a queued request; ``max_queue`` bounds
    admission (``submit`` raises ``RuntimeError`` when full — the
    backpressure seam).

    Batches are padded to power-of-two buckets (rounded up to the
    engine's ``batch_multiple`` when a mesh sits behind the queue) so
    the compiled-forward cache stays finite; padded rows are zeros and
    their outputs are discarded — served outputs are bit-identical to
    the direct ``*_forward_packed`` call on the unpadded batch
    (``tests/test_serve_batching.py``).  Flushes of bucket ≤ 8 lower
    to the N-major GEMV grid, larger ones to the blocked GEMM / resident
    stack — the ``kernels.ops.dispatch_batch`` seam, recorded per flush
    in ``flushes``.

    Fault tolerance (``docs/robustness.md``): every admitted request
    reaches exactly ONE terminal state (:data:`TERMINAL_STATES`).  A
    flush that raises fails only its own window — it is retried under
    the bounded-backoff :class:`RetryPolicy` and then bisected so a
    poison request errors alone while its cohort is served; a request
    whose deadline is exceeded by more than ``timeout_grace`` × its
    deadline budget completes as ``timeout`` instead of being served
    stale (``timeout_grace=None``, the default, never times out —
    deadlines then only drive flush scheduling); a full queue sheds
    with :class:`BackpressureError`.  ``flush_hook`` is the
    fault-injection seam (``runtime.faults.FaultInjector``) wrapping
    the device dispatch of ``_flush_window``; on
    :class:`DeviceLossError` the window is requeued and the error
    propagates to the ``runtime.ServingSupervisor``, which degrades the
    mesh and rebuilds the engine via :meth:`rebuild_engine`.
    """

    def __init__(self, *, max_batch: int = 32,
                 buckets: tuple[int, ...] | None = None,
                 default_deadline: float = 0.010,
                 max_queue: int | None = None,
                 completed_mailbox: int = 1024,
                 clock: Callable[[], float] = time.monotonic,
                 retry: RetryPolicy | None = None,
                 timeout_grace: float | None = None,
                 sleep: Callable[[float], Any] | None = None,
                 telemetry: Telemetry | None = None):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        self.max_batch = max_batch
        self._bucket_template = (tuple(sorted(set(buckets)))
                                 if buckets else _default_buckets(max_batch))
        if self._bucket_template[-1] < max_batch:
            raise ValueError(
                f"largest bucket {self._bucket_template[-1]} smaller than "
                f"max_batch {max_batch}")
        self.default_deadline = default_deadline
        self.max_queue = max_queue
        self._clock = clock
        self.retry = retry if retry is not None else RetryPolicy()
        if timeout_grace is not None and timeout_grace < 1.0:
            raise ValueError(
                f"timeout_grace must be >= 1 (a multiple of the deadline "
                f"budget) or None, got {timeout_grace}")
        self.timeout_grace = timeout_grace
        # Backoff sleeps must not stall a simulated clock forever: when
        # the injected clock can advance (SimClock), sleeping IS
        # advancing it, so retry/backoff stays deterministic in tests.
        if sleep is not None:
            self._sleep = sleep
        elif callable(getattr(clock, "advance", None)):
            self._sleep = clock.advance
        else:
            self._sleep = time.sleep
        # The fault-injection seam: when set, `_flush_window` routes its
        # device dispatch through `flush_hook(eng, buf, reqs, default)`
        # instead of calling `default()` (= `eng.fwd(buf)`) directly.
        # `runtime.faults.FaultInjector.attach` installs itself here.
        self.flush_hook: Callable[..., Any] | None = None
        # Per-server telemetry (isolated; tracing off by default — the
        # disabled span path is one attribute check).  The cache and
        # pool write their counters into the SAME registry, so one
        # snapshot carries the whole serve.* taxonomy
        # (docs/observability.md).
        self.telemetry = telemetry if telemetry is not None else Telemetry()
        m = self.telemetry.metrics
        self._m_submitted = m.counter("serve.submitted")
        self._m_completed = m.counter("serve.completed")
        self._m_cancelled = m.counter("serve.cancelled")
        self._m_rejected = m.counter("serve.rejected")
        self._m_flushes = m.counter("serve.flushes")
        self._m_errors = m.counter("serve.errors")
        self._m_retries = m.counter("serve.retries")
        self._m_timeouts = m.counter("serve.timeouts")
        self._m_shed = m.counter("serve.shed")
        self._m_bisections = m.counter("serve.bisections")
        self._m_padded = m.counter("serve.padded_rows")
        self._m_routes = {r: m.counter(f"serve.route.{r}")
                          for r in ("gemv", "gemm")}
        self._m_depth = m.gauge("serve.queue_depth")
        self._h_latency = m.histogram("serve.request_latency_s")
        self._h_wait = m.histogram("serve.queue_wait_s")
        self._h_flush = m.histogram("serve.flush_wall_s")
        self.cache = PackedModelCache(metrics=m)
        self.pool = ActivationPool(metrics=m)
        self._engines: dict[Any, _Engine] = {}
        self._active: Any = None
        self._queue: collections.deque[ServeRequest] = collections.deque()
        # rid -> completed request, claimable via take(); bounded FIFO so
        # callers that consume step()/flush() returns directly (and never
        # claim) cannot leak the mailbox.  served/flushes are bounded the
        # same way — they are observability history, and an unbounded
        # list of requests (each holding its input and result row) would
        # be a steady-state leak in a long-running server.
        self._completed: collections.OrderedDict[int, ServeRequest] = \
            collections.OrderedDict()
        self._completed_cap = max(completed_mailbox, 2 * max_batch)
        self._next_rid = 0
        self.flushes: list[FlushRecord] = []
        self.served: list[ServeRequest] = []

    # -- model registry ----------------------------------------------------

    def register(self, key, params=None, spec=None, *, kind: str | None = None,
                 packed=None, backend: str = "auto",
                 dense_stack: str = "auto", mesh=None) -> Any:
        """Register a model config under ``key`` and activate it if the
        server is idle.

        Either pass float ``params`` + ``spec`` (+ ``kind`` 'bcnn' |
        'bmlp' | 'transformer'; for 'transformer' ``spec`` is the
        ``ArchConfig`` and ``params`` come from
        ``models.transformer.init_binary_lm``) — the weight cache packs
        + folds ONCE per key — or a pre-``pack_*`` tree via ``packed=``.  Re-registering a known key
        is a cache hit: neither the packed tree nor the compiled
        forwards are rebuilt.  ``mesh`` puts a ``(data, model)`` device
        mesh behind the queue (``make_sharded_forward``); flush buckets
        are then rounded up to the mesh's data-axis multiple.
        """
        if key not in self._engines:
            self._engines[key] = self._build_engine(
                key, params, spec, kind=kind, packed=packed,
                backend=backend, dense_stack=dense_stack, mesh=mesh)
        else:
            # touch the weight cache so a re-register is an observable hit
            self.cache.get_or_pack(key, lambda: self._engines[key].packed)
        if self._active is None:
            self._active = key
        return key

    def _build_engine(self, key, params, spec, *, kind, packed, backend,
                      dense_stack, mesh) -> _Engine:
        if packed is not None:
            packed_tree = self.cache.get_or_pack(key, lambda: packed)
        else:
            if kind not in ("bcnn", "bmlp", "transformer"):
                raise ValueError(
                    f"kind must be 'bcnn', 'bmlp', or 'transformer', "
                    f"got {kind!r}")
            if kind == "transformer":
                from repro.models import transformer as TF
                pack = TF.pack_transformer
            else:
                pack = C.pack_bcnn if kind == "bcnn" else C.pack_bmlp
            packed_tree = self.cache.get_or_pack(
                key, lambda: pack(params, spec))
        kind = C.packed_kind(packed_tree)
        if kind == "transformer" and mesh is not None:
            raise ValueError(
                "mesh serving is not supported for the transformer "
                "workload (the sharding rules cover bcnn/bmlp)")
        if mesh is not None:
            from repro.distributed.sharding import make_sharded_forward
            fwd = make_sharded_forward(packed_tree, mesh, backend=backend,
                                       dense_stack=dense_stack,
                                       telemetry=self.telemetry)
            batch_multiple = fwd.batch_multiple
        else:
            fwd = C.make_packed_forward(packed_tree, backend=backend,
                                        dense_stack=dense_stack)
            batch_multiple = 1
        buckets = tuple(sorted({_ceil_mult(b, batch_multiple)
                                for b in self._bucket_template}))
        return _Engine(kind=kind, packed=packed_tree, fwd=fwd,
                       example_shape=C.packed_input_shape(packed_tree),
                       kw_words=C.packed_dense_kw_words(packed_tree),
                       batch_multiple=batch_multiple, buckets=buckets)

    def use(self, key) -> list[ServeRequest]:
        """Switch the active model.  Pending requests were submitted
        against the current model, so they are force-flushed first; the
        completions are returned.  Compiled forwards and packed weights
        of BOTH models stay warm — swapping back is free (cache hit)."""
        if key not in self._engines:
            raise KeyError(f"unknown model key {key!r}")
        done = self.flush() if self._queue else []
        self._active = key
        return done

    def invalidate(self, key) -> list[ServeRequest]:
        """Evict ``key`` from the weight cache and engine registry (call
        after a weight update; the next ``register`` re-packs).

        Requests queued against the active model were admitted under the
        OLD weights, so invalidating it force-flushes them first (same
        contract as :meth:`use`); the completions are returned.
        """
        done = (self.flush()
                if key == self._active and self._queue else [])
        self.cache.invalidate(key)
        self._engines.pop(key, None)
        if self._active == key:
            self._active = None
        return done

    def rebuild_engine(self, key, *, packed=None, params=None, spec=None,
                       kind: str | None = None, backend: str = "auto",
                       dense_stack: str = "auto", mesh=None) -> Any:
        """Drop and rebuild the engine for ``key`` WITHOUT flushing
        pending work — the elastic-degradation seam.

        ``use``/``invalidate`` force-flush through the OLD engine first;
        after a device loss that engine's compiled forward can never
        complete, so the supervisor swaps the engine out from under the
        queue instead: the cache entry and compiled forwards are
        dropped, a new engine is built from ``packed`` (typically the
        warm-restored, resharded tree) on ``mesh``, and the still-queued
        requests are served by the NEW engine on the next step — zero
        requests lost.
        """
        if key not in self._engines:
            raise KeyError(f"unknown model key {key!r}")
        self.cache.invalidate(key)
        self._engines.pop(key)
        self._engines[key] = self._build_engine(
            key, params, spec, kind=kind, packed=packed,
            backend=backend, dense_stack=dense_stack, mesh=mesh)
        return key

    def engine(self, key=None) -> _Engine:
        """The registered engine for ``key`` (active model if None) —
        read-only introspection for tests, benchmarks, and the sharded
        verifier (packed tree, compiled forward, buckets, route facts)."""
        key = self._active if key is None else key
        if key not in self._engines:
            raise KeyError(f"unknown model key {key!r}")
        return self._engines[key]

    # -- queue -------------------------------------------------------------

    @property
    def active(self):
        return self._active

    def pending(self) -> int:
        return len(self._queue)

    def submit(self, x, *, deadline: float | None = None) -> int:
        """Admit one example FIFO; returns its rid.  ``deadline`` is
        seconds from now (``default_deadline`` if None).  Raises
        :class:`BackpressureError` when ``max_queue`` requests are
        already pending — the request is SHED, never admitted (the
        fourth lifecycle outcome; the caller backs off or retries)."""
        if self._active is None:
            raise RuntimeError("no model registered")
        if self.max_queue is not None and len(self._queue) >= self.max_queue:
            self._m_rejected.inc()
            self._m_shed.inc()
            raise BackpressureError(
                f"queue full ({self.max_queue} pending) — backpressure")
        now = self._clock()
        dl = self.default_deadline if deadline is None else deadline
        req = ServeRequest(rid=self._next_rid, x=x, deadline=now + dl,
                           submitted_at=now)
        self._next_rid += 1
        self._queue.append(req)
        self._m_submitted.inc()
        self._m_depth.set(len(self._queue))
        tr = self.telemetry.tracer
        if tr.enabled:
            req.trace_submit_ns = tr.now_ns()
            tr.instant("serve.submit", rid=req.rid)
        return req.rid

    def cancel(self, rid: int) -> bool:
        """Evict a still-queued request; True if it was pending."""
        for r in self._queue:
            if r.rid == rid:
                self._queue.remove(r)
                self._m_cancelled.inc()
                self._m_depth.set(len(self._queue))
                return True
        return False

    def step(self, now: float | None = None) -> list[ServeRequest]:
        """One scheduling step: flush every full ``max_batch`` window,
        then — if the oldest pending deadline has expired — flush the
        rest of the queue too.  Returns the requests completed by this
        step (possibly empty: a partial batch whose deadline is still
        in the future keeps waiting for riders)."""
        now = self._clock() if now is None else now
        done: list[ServeRequest] = []
        while len(self._queue) >= self.max_batch:
            done += self._flush_window(self.max_batch)
        if self._queue and min(r.deadline for r in self._queue) <= now:
            while self._queue:
                done += self._flush_window(self.max_batch)
        return done

    def flush(self) -> list[ServeRequest]:
        """Force-drain the queue regardless of deadlines (shutdown /
        model swap)."""
        done: list[ServeRequest] = []
        while self._queue:
            done += self._flush_window(self.max_batch)
        return done

    def serve(self, xs, *, deadline: float | None = None
              ) -> list[np.ndarray]:
        """Convenience: submit every example, drain, return results in
        submission order (the batch-API view of the queue).

        The drain flushes the WHOLE queue, so requests other callers had
        pending complete too; their completions stay claimable via
        :meth:`take` (they are not lost to this caller's return value).
        Own results are collected from the flush returns directly, so
        ``serve`` works for request counts beyond the mailbox cap.
        Backpressure is all-or-nothing: if the batch would overflow
        ``max_queue``, ``RuntimeError`` is raised before ANY submit, so
        a failed call never strands half its requests in the queue.
        """
        xs = list(xs)
        if self.max_queue is not None and \
                len(self._queue) + len(xs) > self.max_queue:
            self._m_rejected.inc(len(xs))   # same pair submit() bumps
            self._m_shed.inc(len(xs))
            raise BackpressureError(
                f"serve({len(xs)}) would overflow max_queue="
                f"{self.max_queue} ({len(self._queue)} pending) — "
                "backpressure")
        rids = [self.submit(x, deadline=deadline) for x in xs]
        by_rid = {r.rid: r for r in self.flush()}
        for rid in rids:                       # claimed here, not via take()
            self._completed.pop(rid, None)
        bad = [(rid, by_rid[rid].status) for rid in rids
               if by_rid[rid].status != "ok"]
        if bad:
            # the batch-API view has no per-request status channel, so a
            # non-ok outcome must raise rather than hand back None rows
            raise RuntimeError(
                f"serve(): {len(bad)} request(s) ended non-ok: {bad[:4]}"
                f"{'...' if len(bad) > 4 else ''}")
        return [np.asarray(by_rid[rid].result) for rid in rids]

    def take(self, rid: int) -> ServeRequest | None:
        """Claim a completed request by rid (None if unknown / still
        pending).  Every flush parks its completions here until claimed,
        so a caller polling ``step()`` for its own rid still gets its
        result even when ANOTHER caller's flush/serve drained the queue
        — each completion is delivered exactly once per channel."""
        return self._completed.pop(rid, None)

    def route_for(self, batch: int) -> str:
        """Which dense grid a flush of ``batch`` requests lowers to for
        the ACTIVE model ('gemv' | 'gemm') — ``kernels.ops.dispatch_batch``
        on the padded bucket and the model's widest packed-K extent.
        Raises ``RuntimeError`` when no model is active."""
        eng = self._active_engine()
        return kops.dispatch_batch(self._bucket_for(eng, batch),
                                   eng.kw_words)

    # -- flush machinery ---------------------------------------------------

    def _active_engine(self) -> _Engine:
        if self._active is None:
            raise RuntimeError("no model registered")
        return self._engines[self._active]

    def _bucket_for(self, eng: _Engine, n: int) -> int:
        for b in eng.buckets:
            if b >= n:
                return b
        return eng.buckets[-1]

    def _timed_out(self, r: ServeRequest, now: float) -> bool:
        """Deadline exceeded past the grace factor: the request is
        completed as ``timeout`` instead of served stale.  Grace is a
        multiple of the request's own deadline BUDGET (submit → flush
        deadline), so a 5 ms-deadline request with grace 4 times out
        20 ms after submission; ``timeout_grace=None`` disables.

        A non-positive budget (``submit(x, deadline=0)`` means "flush
        me NOW", not "time me out now") would make ANY later flush a
        timeout under a wall clock, so it falls back to the server's
        ``default_deadline`` as the grace base."""
        if self.timeout_grace is None:
            return False
        budget = r.deadline - r.submitted_at
        if budget <= 0.0:
            budget = self.default_deadline
        return now > r.submitted_at + self.timeout_grace * budget

    def _finish(self, r: ServeRequest, status: str, now: float, *,
                result=None, error: BaseException | None = None) -> None:
        """Move one request to its terminal state — the ONLY writer of
        ``status``, so 'exactly one terminal state per rid' holds by
        construction (re-finishing a finished request is a bug)."""
        assert status in TERMINAL_STATES, status
        assert r.status == "pending", (r.rid, r.status, status)
        r.status = status
        r.result = result
        r.error = error
        r.completed_at = now
        self._h_latency.observe(r.latency)
        if status == "ok":
            self._m_completed.inc()
        elif status == "timeout":
            self._m_timeouts.inc()
        else:
            self._m_errors.inc()
        self.served.append(r)
        del self.served[:-self._completed_cap]
        self._completed[r.rid] = r
        while len(self._completed) > self._completed_cap:
            self._completed.popitem(last=False)

    def _dispatch(self, eng: _Engine, buf, reqs: list[ServeRequest]):
        """The flush seam: everything device-side of one dispatch
        attempt.  ``flush_hook`` (fault injection, chaos testing) wraps
        the default ``eng.fwd(buf)`` call when installed."""
        if self.flush_hook is not None:
            return self.flush_hook(eng, buf, reqs, lambda: eng.fwd(buf))
        return eng.fwd(buf)

    def _serve_cohort(self, reqs: list[ServeRequest],
                      eng: _Engine) -> list[ServeRequest]:
        """Serve one cohort: pad to its bucket, dispatch with bounded
        retry/backoff, bisect on persistent failure, complete every
        request terminally.  Failure isolation contract:

        * an exception from the dispatch fails only THIS cohort — it is
          retried ``retry.max_retries`` times with exponential backoff,
          then the cohort bisects (fresh budget per half) until the
          poison singleton completes as ``error`` while its former
          cohort-mates are served;
        * :class:`DeviceLossError` short-circuits all of that: EVERY
          still-pending request of the cohort goes back to the FRONT of
          the queue — including bisection siblings that were never
          dispatched, at any recursion depth — and the error propagates
          to the supervisor (mesh shrink + engine rebuild), after which
          the requeued requests are served by the new engine.

        The requeue lives HERE, on the outermost cohort, not inside the
        bisection recursion: a per-half requeue would save only the half
        that was dispatching and silently lose its not-yet-dispatched
        siblings (no terminal state, ``take()`` returns None forever).
        """
        try:
            return self._dispatch_cohort(reqs, eng)
        except DeviceLossError:
            pending = [r for r in reqs if r.status == "pending"]
            self._queue.extendleft(reversed(pending))
            self._m_depth.set(len(self._queue))
            raise

    def _dispatch_cohort(self, reqs: list[ServeRequest],
                         eng: _Engine) -> list[ServeRequest]:
        tr = self.telemetry.tracer
        bucket = self._bucket_for(eng, len(reqs))
        t0 = self._clock()
        with tr.span("serve.pack", batch=len(reqs), bucket=bucket):
            buf = self.pool.batch_buffer(bucket, eng.example_shape)
            for i, r in enumerate(reqs):
                buf[i] = np.asarray(r.x, buf.dtype)
            buf[len(reqs):] = 0
        route = kops.dispatch_batch(bucket, eng.kw_words)
        attempt = 0
        while True:
            try:
                with tr.span("serve.dispatch", route=route):
                    out_dev = self._dispatch(eng, buf, reqs)
                with tr.span("serve.compute"):
                    out = np.asarray(out_dev)   # blocks on device work
                break
            except DeviceLossError:
                raise        # not batch-local: _serve_cohort requeues
            except Exception as e:
                if attempt < self.retry.max_retries:
                    attempt += 1
                    self._m_retries.inc()
                    self._sleep(self.retry.backoff(attempt))
                    continue
                if len(reqs) == 1:
                    with tr.span("serve.complete"):
                        self._finish(reqs[0], "error", self._clock(),
                                     error=e)
                        self._m_depth.set(len(self._queue))
                    return list(reqs)
                self._m_bisections.inc()
                mid = len(reqs) // 2
                return (self._dispatch_cohort(reqs[:mid], eng) +
                        self._dispatch_cohort(reqs[mid:], eng))
        with tr.span("serve.complete"):
            now = self._clock()
            for i, r in enumerate(reqs):
                self._h_wait.observe(max(0.0, t0 - r.submitted_at))
                self._finish(r, "ok", now, result=out[i])
            self.flushes.append(FlushRecord(
                batch=len(reqs), bucket=bucket, route=route,
                at=now, wall_s=now - t0, retries=attempt))
            del self.flushes[:-self._completed_cap]
            self._m_flushes.inc()
            self._m_routes[route].inc()
            self._m_padded.inc(bucket - len(reqs))
            self._m_depth.set(len(self._queue))
            self._h_flush.observe(now - t0)
        return list(reqs)

    def _flush_window(self, limit: int) -> list[ServeRequest]:
        """One flush: pop a FIFO window, triage expired requests to
        ``timeout``, then serve the live cohort (`_serve_cohort` does
        pad → dispatch-with-retry → complete, bisecting on failure).

        The serving lifecycle is traced per phase when the server's
        tracer is enabled (span taxonomy in ``docs/observability.md``):
        a ``serve.flush`` parent wrapping ``serve.bucket_pad`` →
        ``serve.pack`` → ``serve.dispatch`` (the jitted call returns) →
        ``serve.compute`` (host transfer blocks on device work) →
        ``serve.complete``, plus one explicit-time ``serve.queue_wait``
        span per request (submit → flush start).  Metrics (queue-wait /
        latency / flush-wall histograms, route + padded-row + lifecycle
        counters) update unconditionally — they are a few dict ops per
        flush.
        """
        tr = self.telemetry.tracer
        flush_t0 = tr.now_ns() if tr.enabled else 0
        with tr.span("serve.bucket_pad"):
            reqs = [self._queue.popleft()
                    for _ in range(min(limit, len(self._queue)))]
            if not reqs:
                return []
            eng = self._active_engine()
            now = self._clock()
        if tr.enabled:
            for r in reqs:
                if r.trace_submit_ns is not None:
                    tr.add_complete("serve.queue_wait", r.trace_submit_ns,
                                    flush_t0, rid=r.rid)
        done: list[ServeRequest] = []
        live: list[ServeRequest] = []
        for r in reqs:
            if self._timed_out(r, now):
                self._finish(r, "timeout", now)
                done.append(r)
            else:
                live.append(r)
        flush_args: dict = {"batch": len(reqs)}
        if not live:
            self._m_depth.set(len(self._queue))
        else:
            bucket = self._bucket_for(eng, len(live))
            flush_args["bucket"] = bucket
            flush_args["route"] = kops.dispatch_batch(bucket, eng.kw_words)
            done += self._serve_cohort(live, eng)
        if tr.enabled:
            tr.add_complete("serve.flush", flush_t0, tr.now_ns(),
                            **flush_args)
        return done


def latency_percentile(sorted_vals, q: float):
    """Nearest-rank percentile over a pre-sorted latency list — the one
    definition the serving CLI (``launch/serve.py``) and the serving
    benchmark (``benchmarks/serve_latency.py``) both report, so the two
    cannot drift.

    Raises ``ValueError`` on an empty sequence (``sorted_vals[-1]`` would
    silently report the caller's last GC'd value as a latency) and on a
    ``q`` outside [0, 1] (``q > 1`` used to clamp to the max — a p200
    typo would masquerade as p100).  A single sample returns that sample
    for every ``q``.
    """
    if not 0.0 <= q <= 1.0:
        raise ValueError(f"percentile q must be in [0, 1], got {q!r}")
    n = len(sorted_vals)
    if n == 0:
        raise ValueError("latency_percentile of an empty sequence")
    return sorted_vals[min(n - 1, int(n * q))]


class SimClock:
    """Deterministic monotonic clock for tests and benches: inject as
    ``PackedInferenceServer(clock=...)`` and drive time by hand."""

    def __init__(self, t: float = 0.0):
        self.t = t

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> float:
        self.t += dt
        return self.t


def _ceil_mult(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


# ---------------------------------------------------------------------------
# LM decode serving (scaffold models): step factories + slot-ring driver
# ---------------------------------------------------------------------------

def make_prefill_step(cfg: ArchConfig, max_len: int):
    def prefill_step(params, batch):
        return M.prefill(params, cfg, batch, max_len)
    return prefill_step


def make_decode_step(cfg: ArchConfig):
    def decode_step(params, cache, tokens, idx):
        return M.decode_step(params, cfg, tokens, cache, idx)
    return decode_step


@dataclasses.dataclass
class Request:
    rid: int
    prompt: jax.Array          # (S,) int32
    max_new: int
    out: list = dataclasses.field(default_factory=list)
    truncated: bool = False    # hit the cache length before max_new tokens


class BatchedServer:
    """Minimal continuous-batching server over the jitted decode step.

    All sequences share one ring of decode slots; finished requests free
    their slot for the next queued prompt.  Single-host demo driver for
    examples/serve_binary_lm.py — the distributed serving path is the
    jitted step itself.
    """

    def __init__(self, cfg: ArchConfig, params, batch_slots: int,
                 max_len: int):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.slots = batch_slots
        self.cache = M.init_cache(params, cfg, batch_slots, max_len)
        self.decode = jax.jit(make_decode_step(cfg))
        self.active: dict[int, Request] = {}
        self.idx = 0

    def _reset_slot(self, s: int) -> None:
        """Zero the freed slot's cache rows (K/V and recurrent state).

        A reused slot would otherwise inherit the previous request's rows
        at positions < self.idx — the new occupant's attention reads them.
        Cache leaves are (L, B, ...) with the slot axis at 1.

        Zeroing removes the cross-request information leak (zero V rows
        contribute a zero vector), but the decode mask is global
        (j <= idx), so the zeroed positions still take softmax weight and
        dilute the new occupant's attention vs decoding it alone.  Exact
        isolation needs a per-slot start-position mask in the attention
        step — out of scope for this Python-level driver.
        """
        self.cache = jax.tree.map(
            lambda a: a.at[:, s].set(jnp.zeros_like(a[:, s]))
            if hasattr(a, "ndim") and a.ndim >= 2 and a.shape[1] == self.slots
            else a,
            self.cache)

    def submit_and_run(self, requests: list[Request]) -> list[Request]:
        """Greedy decode all requests (prompts are consumed token-by-token
        — teacher-forcing the prompt through the decode path keeps this
        driver cache-layout agnostic).

        Every submitted request appears in the return value: either
        completed (``max_new`` tokens) or flagged ``truncated=True`` when
        the shared cache ran out of positions before it finished (requests
        still queued at that point come back truncated with empty output).
        """
        queue = list(requests)
        # Resubmitting a truncated request is the natural retry: restart
        # it cleanly (its prompt is re-decoded, so stale tokens from the
        # aborted window must not count toward max_new).
        for r in queue:
            r.out = []
            r.truncated = False
        done: list[Request] = []
        slot_req: dict[int, Request] = {}
        tok = jnp.zeros((self.slots, 1), jnp.int32)
        pos = [0] * self.slots
        # Every slot was freed AND reset when the previous call returned,
        # so each call starts a fresh cache window — without this, one
        # exhausting call would leave idx == max_len forever and every
        # later call would return instantly, all-truncated.
        self.idx = 0
        while (queue or slot_req) and self.idx < self.max_len:
            for s in range(self.slots):
                if s not in slot_req and queue:
                    slot_req[s] = queue.pop(0)
                    pos[s] = 0
            step_tok = []
            for s in range(self.slots):
                r = slot_req.get(s)
                if r is None:
                    step_tok.append(0)
                elif pos[s] < len(r.prompt):
                    step_tok.append(int(r.prompt[pos[s]]))
                else:
                    step_tok.append(r.out[-1] if r.out else 0)
            tok = jnp.asarray(step_tok, jnp.int32)[:, None]
            logits, self.cache = self.decode(self.params, self.cache, tok,
                                             jnp.int32(self.idx))
            nxt = jnp.argmax(logits[:, 0], axis=-1)
            for s in list(slot_req):
                r = slot_req[s]
                pos[s] += 1
                if pos[s] >= len(r.prompt):
                    r.out.append(int(nxt[s]))
                    if len(r.out) >= r.max_new:
                        done.append(r)
                        del slot_req[s]
                        self._reset_slot(s)
            self.idx += 1
        # Cache exhausted: account for every in-flight and queued request,
        # and scrub the abandoned slots so the next call starts clean.
        for s, r in list(slot_req.items()):
            r.truncated = True
            done.append(r)
            self._reset_slot(s)
        for r in queue:
            r.truncated = True
            done.append(r)
        return done
