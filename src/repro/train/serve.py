"""Serving: prefill / decode step factories + a batched request loop.

``make_decode_step`` is the function the decode_32k / long_500k dry-run
cells lower: one new token for the whole batch against a seq_len KV
cache.  The server loop demonstrates continuous batching at the Python
level (slot reuse on completion) — the per-step compute is the jitted
decode step.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import model as M


def make_prefill_step(cfg: ArchConfig, max_len: int):
    def prefill_step(params, batch):
        return M.prefill(params, cfg, batch, max_len)
    return prefill_step


def make_decode_step(cfg: ArchConfig):
    def decode_step(params, cache, tokens, idx):
        return M.decode_step(params, cfg, tokens, cache, idx)
    return decode_step


def make_forward(cfg: ArchConfig):
    def fwd(params, batch):
        return M.loss_fn(params, cfg, batch)
    return fwd


@dataclasses.dataclass
class Request:
    rid: int
    prompt: jax.Array          # (S,) int32
    max_new: int
    out: list = dataclasses.field(default_factory=list)
    truncated: bool = False    # hit the cache length before max_new tokens


class BatchedServer:
    """Minimal continuous-batching server over the jitted decode step.

    All sequences share one ring of decode slots; finished requests free
    their slot for the next queued prompt.  Single-host demo driver for
    examples/serve_binary_lm.py — the distributed serving path is the
    jitted step itself (launch/serve.py).
    """

    def __init__(self, cfg: ArchConfig, params, batch_slots: int,
                 max_len: int):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.slots = batch_slots
        self.cache = M.init_cache(params, cfg, batch_slots, max_len)
        self.decode = jax.jit(make_decode_step(cfg))
        self.active: dict[int, Request] = {}
        self.idx = 0

    def _reset_slot(self, s: int) -> None:
        """Zero the freed slot's cache rows (K/V and recurrent state).

        A reused slot would otherwise inherit the previous request's rows
        at positions < self.idx — the new occupant's attention reads them.
        Cache leaves are (L, B, ...) with the slot axis at 1.

        Zeroing removes the cross-request information leak (zero V rows
        contribute a zero vector), but the decode mask is global
        (j <= idx), so the zeroed positions still take softmax weight and
        dilute the new occupant's attention vs decoding it alone.  Exact
        isolation needs a per-slot start-position mask in the attention
        step — out of scope for this Python-level driver.
        """
        self.cache = jax.tree.map(
            lambda a: a.at[:, s].set(jnp.zeros_like(a[:, s]))
            if hasattr(a, "ndim") and a.ndim >= 2 and a.shape[1] == self.slots
            else a,
            self.cache)

    def submit_and_run(self, requests: list[Request]) -> list[Request]:
        """Greedy decode all requests (prompts are consumed token-by-token
        — teacher-forcing the prompt through the decode path keeps this
        driver cache-layout agnostic).

        Every submitted request appears in the return value: either
        completed (``max_new`` tokens) or flagged ``truncated=True`` when
        the shared cache ran out of positions before it finished (requests
        still queued at that point come back truncated with empty output).
        """
        queue = list(requests)
        # Resubmitting a truncated request is the natural retry: restart
        # it cleanly (its prompt is re-decoded, so stale tokens from the
        # aborted window must not count toward max_new).
        for r in queue:
            r.out = []
            r.truncated = False
        done: list[Request] = []
        slot_req: dict[int, Request] = {}
        tok = jnp.zeros((self.slots, 1), jnp.int32)
        pos = [0] * self.slots
        # Every slot was freed AND reset when the previous call returned,
        # so each call starts a fresh cache window — without this, one
        # exhausting call would leave idx == max_len forever and every
        # later call would return instantly, all-truncated.
        self.idx = 0
        while (queue or slot_req) and self.idx < self.max_len:
            for s in range(self.slots):
                if s not in slot_req and queue:
                    slot_req[s] = queue.pop(0)
                    pos[s] = 0
            step_tok = []
            for s in range(self.slots):
                r = slot_req.get(s)
                if r is None:
                    step_tok.append(0)
                elif pos[s] < len(r.prompt):
                    step_tok.append(int(r.prompt[pos[s]]))
                else:
                    step_tok.append(r.out[-1] if r.out else 0)
            tok = jnp.asarray(step_tok, jnp.int32)[:, None]
            logits, self.cache = self.decode(self.params, self.cache, tok,
                                             jnp.int32(self.idx))
            nxt = jnp.argmax(logits[:, 0], axis=-1)
            for s in list(slot_req):
                r = slot_req[s]
                pos[s] += 1
                if pos[s] >= len(r.prompt):
                    r.out.append(int(nxt[s]))
                    if len(r.out) >= r.max_new:
                        done.append(r)
                        del slot_req[s]
                        self._reset_slot(s)
            self.idx += 1
        # Cache exhausted: account for every in-flight and queued request,
        # and scrub the abandoned slots so the next call starts clean.
        for s, r in list(slot_req.items()):
            r.truncated = True
            done.append(r)
            self._reset_slot(s)
        for r in queue:
            r.truncated = True
            done.append(r)
        return done
