"""Serving: prefill / decode step factories + a batched request loop.

``make_decode_step`` is the function the decode_32k / long_500k dry-run
cells lower: one new token for the whole batch against a seq_len KV
cache.  The server loop demonstrates continuous batching at the Python
level (slot reuse on completion) — the per-step compute is the jitted
decode step.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import model as M


def make_prefill_step(cfg: ArchConfig, max_len: int):
    def prefill_step(params, batch):
        return M.prefill(params, cfg, batch, max_len)
    return prefill_step


def make_decode_step(cfg: ArchConfig):
    def decode_step(params, cache, tokens, idx):
        return M.decode_step(params, cfg, tokens, cache, idx)
    return decode_step


def make_forward(cfg: ArchConfig):
    def fwd(params, batch):
        return M.loss_fn(params, cfg, batch)
    return fwd


@dataclasses.dataclass
class Request:
    rid: int
    prompt: jax.Array          # (S,) int32
    max_new: int
    out: list = dataclasses.field(default_factory=list)


class BatchedServer:
    """Minimal continuous-batching server over the jitted decode step.

    All sequences share one ring of decode slots; finished requests free
    their slot for the next queued prompt.  Single-host demo driver for
    examples/serve_binary_lm.py — the distributed serving path is the
    jitted step itself (launch/serve.py).
    """

    def __init__(self, cfg: ArchConfig, params, batch_slots: int,
                 max_len: int):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.slots = batch_slots
        self.cache = M.init_cache(params, cfg, batch_slots, max_len)
        self.decode = jax.jit(make_decode_step(cfg))
        self.active: dict[int, Request] = {}
        self.idx = 0

    def submit_and_run(self, requests: list[Request]) -> list[Request]:
        """Greedy decode all requests (prompts are consumed token-by-token
        — teacher-forcing the prompt through the decode path keeps this
        driver cache-layout agnostic)."""
        queue = list(requests)
        done: list[Request] = []
        slot_req: dict[int, Request] = {}
        tok = jnp.zeros((self.slots, 1), jnp.int32)
        pos = [0] * self.slots
        while queue or slot_req:
            for s in range(self.slots):
                if s not in slot_req and queue:
                    slot_req[s] = queue.pop(0)
                    pos[s] = 0
            step_tok = []
            for s in range(self.slots):
                r = slot_req.get(s)
                if r is None:
                    step_tok.append(0)
                elif pos[s] < len(r.prompt):
                    step_tok.append(int(r.prompt[pos[s]]))
                else:
                    step_tok.append(r.out[-1] if r.out else 0)
            tok = jnp.asarray(step_tok, jnp.int32)[:, None]
            logits, self.cache = self.decode(self.params, self.cache, tok,
                                             jnp.int32(self.idx))
            nxt = jnp.argmax(logits[:, 0], axis=-1)
            for s in list(slot_req):
                r = slot_req[s]
                pos[s] += 1
                if pos[s] >= len(r.prompt):
                    r.out.append(int(nxt[s]))
                    if len(r.out) >= r.max_new:
                        done.append(r)
                        del slot_req[s]
            self.idx += 1
            if self.idx >= self.max_len:
                break
        return done
