"""VMEM preflight pass: static per-launch VMEM estimation from block
shapes, grids, and dtypes — BEFORE any tracing or compilation.

A Pallas launch that oversubscribes the ~16 MB/core VMEM fails deep
inside Mosaic (or silently thrashes in interpret mode); the only
guard the repo had was ``dense_stack_fits_vmem``'s hand-rolled budget
arithmetic for ONE kernel family.  This pass generalizes it:

* **Closed-form estimators** (``gemm_estimate``, ``conv_estimate``,
  ``attention_estimate``, ``dense_stack_estimate``, ...) mirror each
  wrapper's own block-resolution math, so ``kernels/ops.py`` can
  :func:`preflight` a launch from shapes + knobs alone — at Python
  call time, before ``jax.jit`` ever traces.  An over-budget launch
  raises :class:`VmemBudgetError` with the per-term breakdown.  These
  estimators are also the static cost model the ROADMAP autotuner
  consumes (score = estimate.total, feasibility = estimate.fits()).
* **Traced estimator** (:func:`estimate_eqn` / :func:`estimate_forward`)
  reads a traced ``pallas_call``'s ``grid_mapping`` (block shapes,
  array dtypes, scratch avals) — the ground-truth view the merged
  analysis report records per launch and CI drift-gates.

Accounting model (matches the old ``dense_stack_vmem_bytes``): a
BlockSpec whose block covers its whole array is DMA'd once and held
resident (1 buffer); a genuinely tiled block is double-buffered by the
pipeline emitter (2 buffers).  Scratch is a single allocation.  The
closed-form estimators additionally charge the kernel's compute
transient (the (bm, bn, ws) popcount broadcast + the pre-pack int32
tile), which the traced view cannot see.

Budget: 16 MiB/core by default; override with the environment knob
``REPRO_VMEM_BUDGET_BYTES`` (e.g. to model a smaller core or leave
explicit headroom).  The single-launch dense stack keeps its own
tighter 8 MiB gate (``kernels.binary_matmul.STACK_VMEM_BUDGET``) —
residency there is a routing *choice* with a jnp fallback, not an
error.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Any, Sequence

from repro.analysis import graph

# TPU tile granularity + packing word width (kept in sync with
# core.binarize.WORD_BITS and the kernels' own module constants; pure
# ints here so this module never imports jax at module level for the
# closed-form path).
SUBLANE = 8
LANE = 128
WORD_BITS = 32

# GEMV routing bound (kernels.binary_matmul._GEMV_MAX_KW).
GEMV_MAX_KW = 4096

DEFAULT_VMEM_BUDGET = 16 * 2**20


def vmem_budget() -> int:
    """The per-core VMEM budget preflight enforces (env-overridable)."""
    env = os.environ.get("REPRO_VMEM_BUDGET_BYTES")
    return int(env) if env else DEFAULT_VMEM_BUDGET


def _ceil_mult(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _prod(xs: Sequence[int]) -> int:
    out = 1
    for x in xs:
        out *= int(x)
    return out


# ---------------------------------------------------------------------------
# Estimate model
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class VmemTerm:
    """One VMEM resident: a staged operand block, scratch, or transient.

    ``bytes`` is per buffer; ``buffers`` is 2 for pipeline-streamed
    blocks (double-buffered), 1 for pinned/resident blocks, scratch,
    and compute transients.
    """
    name: str
    bytes: int
    buffers: int = 1

    @property
    def total(self) -> int:
        return self.bytes * self.buffers


@dataclasses.dataclass(frozen=True)
class LaunchEstimate:
    """Static VMEM estimate for one pallas launch."""
    kernel: str
    grid: tuple[int, ...]
    terms: tuple[VmemTerm, ...]

    @property
    def total(self) -> int:
        return sum(t.total for t in self.terms)

    def fits(self, budget: int | None = None) -> bool:
        return self.total <= (vmem_budget() if budget is None else budget)

    def breakdown(self) -> str:
        lines = [f"{self.kernel} grid={self.grid}: "
                 f"{self.total} B estimated VMEM"]
        for t in sorted(self.terms, key=lambda t: -t.total):
            tag = f" x{t.buffers}" if t.buffers != 1 else ""
            lines.append(f"  {t.name}: {t.bytes} B{tag} = {t.total} B")
        return "\n".join(lines)

    def to_json(self) -> dict[str, Any]:
        return {
            "kernel": self.kernel,
            "grid": list(self.grid),
            "bytes": self.total,
            "fits": self.fits(),
            "terms": {t.name: t.total for t in self.terms},
        }


class VmemBudgetError(ValueError):
    """A launch's static VMEM estimate exceeds the per-core budget."""

    def __init__(self, estimate: LaunchEstimate, budget: int):
        self.estimate = estimate
        self.budget = budget
        super().__init__(
            f"launch would need ~{estimate.total} B VMEM, over the "
            f"{budget} B budget (REPRO_VMEM_BUDGET_BYTES to override).\n"
            f"{estimate.breakdown()}\n"
            "Shrink the block knobs (block_m/block_n/block_kw/...) or "
            "raise the budget.")


def preflight(estimate: LaunchEstimate,
              budget: int | None = None) -> LaunchEstimate:
    """Raise :class:`VmemBudgetError` if ``estimate`` oversubscribes
    VMEM; return it unchanged otherwise (so call sites can chain)."""
    budget = vmem_budget() if budget is None else budget
    if estimate.total > budget:
        raise VmemBudgetError(estimate, budget)
    return estimate


# ---------------------------------------------------------------------------
# Closed-form estimators (pre-trace; mirror each wrapper's block math)
# ---------------------------------------------------------------------------

def gemm_estimate(m: int, n: int, kw: int, *, block_m: int = 128,
                  block_n: int = 128, block_kw: int = 128,
                  words_per_step: int = 8,
                  fused: bool = False) -> LaunchEstimate:
    """Estimate the packed GEMM / GEMV launch of
    ``kernels.binary_matmul`` for (M, Kw) x (N, Kw) packed operands.

    Reproduces ``_resolve_blocks``'s trimming and the GEMV-vs-GEMM
    routing, so the estimate tracks the grid the wrapper actually
    emits.  ``fused=True`` adds the BN tau/flip rows and the packed
    output (the ``*_bn_sign_packed`` variants).
    """
    if m <= SUBLANE:
        block_m = SUBLANE
    block_m = min(block_m, _ceil_mult(m, SUBLANE))
    block_n = min(block_n, _ceil_mult(n, LANE))
    block_kw = min(block_kw, _ceil_mult(kw, LANE))
    mp = _ceil_mult(m, block_m)
    np_ = _ceil_mult(n, block_n)
    kwp = _ceil_mult(kw, block_kw)

    gemv = m <= SUBLANE and kwp <= GEMV_MAX_KW
    bm = mp if gemv else block_m
    bkw = kwp if gemv else block_kw
    ws = min(words_per_step, bkw)
    out_w = block_n // WORD_BITS if fused else block_n

    terms = [
        VmemTerm("a_block", bm * bkw * 4, 1 if gemv else 2),
        VmemTerm("b_block", block_n * bkw * 4, 2),
        VmemTerm("out_block", bm * out_w * 4, 2),
        VmemTerm("mismatch_broadcast", bm * block_n * ws * 4),
        VmemTerm("y_tile", bm * block_n * 4),
    ]
    if fused:
        terms += [VmemTerm("tau_block", block_n * 4, 2),
                  VmemTerm("flip_block", block_n * 4, 2)]
    if not gemv:
        terms.append(VmemTerm("acc_scratch", block_m * block_n * 4))
    if gemv:
        grid: tuple[int, ...] = (np_ // block_n,)
    else:
        grid = (mp // block_m, np_ // block_n, kwp // block_kw)
    return LaunchEstimate(kernel="gemv" if gemv else "gemm",
                          grid=grid, terms=tuple(terms))


def dense_stack_estimate(weight_shapes: Sequence[tuple[int, int]], *,
                         block_m: int = SUBLANE,
                         words_per_step: int = 8) -> LaunchEstimate:
    """Estimate the single-launch hidden stack
    (``kernels.binary_matmul.binary_dense_stack_packed``).

    ``weight_shapes``: per-stage packed weight shapes (N_s, Kw_s).
    This IS the arithmetic ``dense_stack_vmem_bytes`` historically
    hand-rolled (that function now delegates here; the crossover is
    regression-pinned in tests): the x tile + every stage's lane-padded
    resident weights and folded tau/flip rows, plus the single largest
    stage transient — the (bm, n_pad, ws) popcount broadcast, the int32
    pre-threshold tile, and the repacked words.
    """
    prev_words = int(weight_shapes[0][1])
    terms = [VmemTerm("x_tile", block_m * prev_words * 4)]
    peak = 0
    for s, (n_s, _) in enumerate(weight_shapes):
        n_pad = _ceil_mult(int(n_s), LANE)
        terms.append(VmemTerm(f"stage{s}_weights", n_pad * prev_words * 4))
        terms.append(VmemTerm(f"stage{s}_bn", 2 * n_pad * 4))
        ws = min(words_per_step, prev_words)
        stage = (block_m * n_pad * (ws + 1) * 4
                 + block_m * (n_pad // WORD_BITS) * 4)
        peak = max(peak, stage)
        prev_words = n_pad // WORD_BITS
    terms.append(VmemTerm("stage_transient_peak", peak))
    return LaunchEstimate(kernel="dense_stack", grid=(1,),
                          terms=tuple(terms))


def conv_estimate(batch: int, padded_hw: tuple[int, int], cw: int,
                  kh: int, kw: int, c_out: int, out_hw: tuple[int, int], *,
                  block_n: int, block_oh: int, fused: bool = False,
                  nbits: int = 1) -> LaunchEstimate:
    """Estimate the fused conv launches of ``kernels.binary_conv``.

    ``padded_hw`` is the spatially padded image size the wrapper stages
    (``_prep_operands``), ``cw`` the packed channel words.  ``nbits > 1``
    models the bit-plane first-layer kernel (the plane stack rides in
    one VMEM block).  ``fused`` adds the BN rows and shrinks the output
    to packed words; the plain conv instead stages the correction tile.
    """
    hp, wp = padded_hw
    oh, ow = out_hw
    block_m = block_oh * ow
    m_tiles = -(-oh // block_oh)
    c_out_p = _ceil_mult(c_out, block_n)
    out_w = block_n // WORD_BITS if fused else block_n
    terms = [
        # Image BlockSpec depends only on the batch index: resident
        # across (m, j) steps, double-buffered across batch elements.
        VmemTerm("image_block", nbits * hp * wp * cw * 4, 2),
        VmemTerm("weight_block", block_n * kh * kw * cw * 4, 2),
        VmemTerm("out_block", block_m * out_w * 4, 2),
        VmemTerm("acc_tile", block_m * block_n * 4),
    ]
    if fused:
        terms += [VmemTerm("tau_block", block_n * 4, 2),
                  VmemTerm("flip_block", block_n * 4, 2)]
    elif nbits > 1:
        terms.append(VmemTerm("rowsum_block", block_n * 4, 2))
    else:
        terms.append(VmemTerm("correction_block", block_m * block_n * 4, 2))
    return LaunchEstimate(
        kernel="bitplane_conv" if nbits > 1 else
        ("conv_bn_sign" if fused else "conv"),
        grid=(batch, m_tiles, c_out_p // block_n),
        terms=tuple(terms))


def attention_estimate(b: int, hq: int, sq: int, skv: int, dw: int,
                       dv: int, *, block_q: int = 128,
                       block_kv: int = 128) -> LaunchEstimate:
    """Estimate the packed flash-attention launch
    (``kernels.binary_attention.binary_attention_packed``)."""
    sq_p = _ceil_mult(sq, block_q)
    skv_p = _ceil_mult(skv, block_kv)
    dw_p = _ceil_mult(dw, LANE)
    dv_p = _ceil_mult(dv, LANE)
    terms = (
        VmemTerm("q_block", block_q * dw_p * 4, 2),
        VmemTerm("k_block", block_kv * dw_p * 4, 2),
        VmemTerm("v_block", block_kv * dv_p * 4, 2),
        VmemTerm("out_block", block_q * dv_p * 4, 2),
        VmemTerm("m_scratch", block_q * LANE * 4),
        VmemTerm("l_scratch", block_q * LANE * 4),
        VmemTerm("acc_scratch", block_q * dv_p * 4),
        VmemTerm("scores_tile", block_q * block_kv * 4),
    )
    return LaunchEstimate(kernel="binary_attention",
                          grid=(b * hq, sq_p // block_q, skv_p // block_kv),
                          terms=terms)


def bitpack_estimate(m: int, k: int, *, block_m: int = 256,
                     block_kw: int = 128) -> LaunchEstimate:
    """Estimate the sign-binarize + bitpack launch (``kernels.bitpack``)."""
    kw = -(-k // WORD_BITS)
    block_m = min(block_m, _ceil_mult(m, SUBLANE))
    block_kw = min(block_kw, _ceil_mult(kw, LANE))
    block_k = block_kw * WORD_BITS
    mp = _ceil_mult(m, block_m)
    kp = _ceil_mult(k, block_k)
    terms = (
        VmemTerm("x_block", block_m * block_k * 4, 2),
        VmemTerm("out_block", block_m * block_kw * 4, 2),
        VmemTerm("bits_tile", block_m * block_k * 4),
    )
    return LaunchEstimate(kernel="bitpack",
                          grid=(mp // block_m, kp // block_k), terms=terms)


def bn_sign_pack_estimate(m: int, c: int, *, block_m: int = 256,
                          block_cw: int = LANE) -> LaunchEstimate:
    """Estimate the standalone BN-sign-repack epilogue launch
    (``kernels.fused_epilogue.bn_sign_pack``)."""
    cw = -(-c // WORD_BITS)
    block_m = min(block_m, _ceil_mult(m, SUBLANE))
    block_cw = min(block_cw, _ceil_mult(cw, LANE))
    block_c = block_cw * WORD_BITS
    mp = _ceil_mult(m, block_m)
    cp = _ceil_mult(c, block_c)
    terms = (
        VmemTerm("x_block", block_m * block_c * 4, 2),
        VmemTerm("tau_block", block_c * 4, 2),
        VmemTerm("flip_block", block_c * 4, 2),
        VmemTerm("out_block", block_m * block_cw * 4, 2),
        VmemTerm("bits_tile", block_m * block_c * 4),
    )
    return LaunchEstimate(kernel="bn_sign_pack",
                          grid=(mp // block_m, cp // block_c), terms=terms)


# ---------------------------------------------------------------------------
# Traced estimator (per-launch ground truth for the analysis report)
# ---------------------------------------------------------------------------

def _block_dims(block_shape: Sequence[Any]) -> list[int]:
    """Block dims as ints (squeezed / mapped dims count as 1)."""
    return [int(d) if isinstance(d, int) else 1 for d in block_shape]


def estimate_eqn(eqn: Any) -> LaunchEstimate:
    """VMEM estimate of one traced ``pallas_call`` eqn, from its
    ``grid_mapping`` block shapes + dtypes and its scratch avals.

    A block that covers its whole operand array is pinned (1 buffer);
    a tiled block is double-buffered (2).  Kernel-internal compute
    transients are invisible at this level — the closed-form
    estimators account for those.
    """
    gm = eqn.params["grid_mapping"]
    terms: list[VmemTerm] = []
    n_in = gm.num_inputs
    for i, bm in enumerate(gm.block_mappings):
        asd = bm.array_shape_dtype
        dims = _block_dims(bm.block_shape)
        nbytes = _prod(dims) * asd.dtype.itemsize
        pinned = dims == [int(d) for d in asd.shape]
        role = "in" if i < n_in else "out"
        terms.append(VmemTerm(f"{role}{i if i < n_in else i - n_in}_block",
                              nbytes, 1 if pinned else 2))
    ns = getattr(gm, "num_scratch_operands", 0)
    if ns:
        kjaxpr = eqn.params["jaxpr"]
        for j, var in enumerate(kjaxpr.invars[-ns:]):
            aval = var.aval
            inner = getattr(aval, "inner_aval", aval)
            if hasattr(inner, "size") and hasattr(inner, "dtype"):
                terms.append(VmemTerm(
                    f"scratch{j}",
                    int(inner.size) * inner.dtype.itemsize))
    return LaunchEstimate(kernel=graph.kernel_name(eqn),
                          grid=tuple(int(g) for g in gm.grid),
                          terms=tuple(terms))


def estimate_forward(fn: Any, *args: Any) -> list[LaunchEstimate]:
    """Traced VMEM estimate of every launch in ``fn``, in trace order."""
    return [estimate_eqn(eqn) for eqn in graph.pallas_eqns(fn, *args)]
