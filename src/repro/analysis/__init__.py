"""Static-analysis subsystem: machine-checked packed-BCNN invariants.

Four passes over traced programs and source (see ``docs/analysis.md``):

* :mod:`repro.analysis.packedness` — dataflow proof that activations
  stay bit-packed across every HBM crossing of a forward;
* :mod:`repro.analysis.vmem` — static per-launch VMEM estimation
  (closed-form preflight for ``kernels/ops.py`` + the autotuner cost
  model, and a traced per-launch view for the report);
* :mod:`repro.analysis.collectives` — compiled-HLO collective rules
  for sharded forwards (collective-free data paths, all-gather-only
  model meshes);
* :mod:`repro.analysis.lint` — AST-enforced repo conventions
  (``python -m repro.analysis.lint src/``).

:mod:`repro.analysis.graph` is the shared jaxpr traversal under the
traced passes (``utils/jaxpr.py`` re-exports it), and
:mod:`repro.analysis.report` merges every pass into the CI-gated
baseline (``python -m repro.analysis --check``).
"""
from repro.analysis.graph import (CALL_PRIMITIVES, PallasLaunch,
                                  call_subjaxpr, count_pallas_calls,
                                  iter_eqns, kernel_name,
                                  max_intermediate_bytes, pallas_eqns,
                                  pallas_grids, pallas_launches, subjaxprs)
from repro.analysis.vmem import (LaunchEstimate, VmemBudgetError, VmemTerm,
                                 attention_estimate, bitpack_estimate,
                                 bn_sign_pack_estimate, conv_estimate,
                                 dense_stack_estimate, estimate_eqn,
                                 estimate_forward, gemm_estimate, preflight,
                                 vmem_budget)

__all__ = [
    "CALL_PRIMITIVES", "PallasLaunch", "call_subjaxpr",
    "count_pallas_calls", "iter_eqns", "kernel_name",
    "max_intermediate_bytes", "pallas_eqns", "pallas_grids",
    "pallas_launches", "subjaxprs",
    "LaunchEstimate", "VmemBudgetError", "VmemTerm",
    "attention_estimate", "bitpack_estimate", "bn_sign_pack_estimate",
    "conv_estimate", "dense_stack_estimate", "estimate_eqn",
    "estimate_forward", "gemm_estimate", "preflight", "vmem_budget",
]
