"""CLI for the merged static-analysis report::

    PYTHONPATH=src python -m repro.analysis [--write|--check|--json]
                                            [--no-sharded]

``--check`` (the CI analysis job) fails on hard invariant violations
(packedness escapes, over-budget launches, lint/sharding violations)
AND on any drift against ``experiments/ANALYSIS_baseline.json``;
``--write`` regenerates the baseline after an intentional change.

The sharding cells need 8 devices: like ``telemetry/probes.py``, the
CLI re-execs itself with ``REPRO_ANALYSIS_FORCE_DEVICES`` set so the
XLA host-device override below lands before jax's first import.
"""
from __future__ import annotations

import os
import sys

# ``python -m repro.analysis`` imports the package __init__ (and so
# jax) BEFORE this module runs — but jax only reads XLA_FLAGS at lazy
# backend initialization, which nothing in the import chain triggers,
# so setting the flag here still lands in the fresh child process.
if os.environ.get("REPRO_ANALYSIS_FORCE_DEVICES"):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") +
        " --xla_force_host_platform_device_count=" +
        os.environ["REPRO_ANALYSIS_FORCE_DEVICES"])

import argparse
import json
import subprocess

from repro.analysis import report as R


def _respawn_with_devices(argv: list[str]) -> int:
    env = dict(os.environ)
    env["REPRO_ANALYSIS_FORCE_DEVICES"] = str(R.SHARDED_DEVICES)
    env.pop("XLA_FLAGS", None)          # the child derives its own
    env["PYTHONPATH"] = (os.path.join(R.repo_root(), "src") + os.pathsep +
                         env.get("PYTHONPATH", ""))
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", *argv],
        env=env, cwd=R.repo_root())
    return proc.returncode


def main(argv: list[str] | None = None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="merged static-analysis report (see docs/analysis.md)")
    ap.add_argument("--write", action="store_true",
                    help="regenerate the committed baseline")
    ap.add_argument("--check", action="store_true",
                    help="verify invariants + diff against the baseline; "
                         "exit 1 on any violation or drift")
    ap.add_argument("--json", action="store_true",
                    help="print the full report as JSON")
    ap.add_argument("--no-sharded", action="store_true",
                    help="skip the sharding cells (no 8-device need)")
    ap.add_argument("--baseline",
                    default=os.path.join(R.repo_root(), R.BASELINE_PATH))
    args = ap.parse_args(argv)

    sharded = not args.no_sharded
    if sharded:
        import jax
        if len(jax.devices()) < R.SHARDED_DEVICES and \
                not os.environ.get("REPRO_ANALYSIS_FORCE_DEVICES"):
            return _respawn_with_devices(argv)

    report = R.merged_report(sharded=sharded)
    if args.json:
        print(json.dumps(report, indent=1))
    if args.write:
        os.makedirs(os.path.dirname(args.baseline), exist_ok=True)
        with open(args.baseline, "w") as f:
            json.dump(report, f, indent=1, sort_keys=True)
        print(f"wrote {len(report['cells'])} analysis cells -> "
              f"{args.baseline}")
    if args.check:
        bad = R.report_ok(report)
        if bad:
            print(f"ANALYSIS VIOLATIONS ({len(bad)}):")
            for line in bad:
                print(f"  {line}")
            return 1
        with open(args.baseline) as f:
            baseline = json.load(f)
        if args.no_sharded:                 # compare only what we ran
            baseline = {"schema": baseline["schema"],
                        "cells": {k: v
                                  for k, v in baseline["cells"].items()
                                  if k in report["cells"]}}
        drift = R.diff_reports(baseline, report)
        if drift:
            print(f"ANALYSIS DRIFT vs {args.baseline} "
                  f"({len(drift)} differences):")
            for line in drift:
                print(f"  {line}")
            print("If intentional, regenerate: "
                  "PYTHONPATH=src python -m repro.analysis --write")
            return 1
        print(f"analysis clean, matches baseline "
              f"({len(report['cells'])} cells)")
    if not (args.json or args.write or args.check):
        for name, cell in report["cells"].items():
            if name.startswith("packedness/"):
                print(f"{name}: {cell['launch_count']} launches, "
                      f"max_live_unpacked={cell['max_live_unpacked_bytes']}B"
                      f" escapes={len(cell['escapes'])}")
            elif name.startswith("vmem/"):
                worst = max(cell, key=lambda c: c["bytes"], default=None)
                if worst:
                    print(f"{name}: {len(cell)} launches, worst "
                          f"{worst['kernel']} {worst['bytes']}B "
                          f"fits={worst['fits']}")
            elif name == "lint":
                print(f"lint: {len(cell['violations'])} violation(s)")
            else:
                print(f"{name}: kinds={cell['kinds']} "
                      f"violations={len(cell['violations'])}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
