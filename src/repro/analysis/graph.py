"""Traced-program graph core: the ONE jaxpr traversal every static
pass and every legacy helper is built on.

The kernel subsystem's evidence ("the bit-plane conv is ONE launch",
"the patch matrix never hits HBM") is op-count-level: it comes from
walking a traced jaxpr, recursing into nested (pjit) bodies.  ONE
recursive traversal (:func:`iter_eqns`) backs every consumer — the
:func:`pallas_launches` launch inventory (kernel name + grid per
launch), the :func:`pallas_grids` / :func:`count_pallas_calls` views
over it, :func:`max_intermediate_bytes` (the largest HBM intermediate,
the fused-epilogue evidence), and the dataflow passes in
``analysis.packedness`` / ``analysis.vmem`` — so the recursion rule
cannot drift between them.  ``pallas_call`` bodies are never descended
into: everything inside one is a single launch's VMEM-resident work,
not an HBM intermediate or a separate launch.

``utils/jaxpr.py`` re-exports this module's names for older call
sites; new code should import from ``repro.analysis``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Iterator

import jax

try:                                   # jax >= 0.6 moved these aliases
    from jax.extend.core import ClosedJaxpr, Jaxpr
except ImportError:                    # jax <= 0.5
    from jax.core import ClosedJaxpr, Jaxpr

# Higher-order call primitives whose operands map POSITIONALLY onto the
# inner jaxpr's invars — the only ones the dataflow passes flow values
# through.  Anything else with a nested jaxpr (scan, cond,
# reduce_window, custom_* with consts) is treated as an opaque eqn by
# the dataflow walk; the syntactic walk still descends so launch counts
# never under-report.
CALL_PRIMITIVES = frozenset({"pjit", "closed_call", "core_call"})


def subjaxprs(param: Any) -> Iterator[Jaxpr]:
    """Yield every jaxpr nested inside one eqn param (lists included)."""
    if isinstance(param, ClosedJaxpr):
        yield param.jaxpr
    elif isinstance(param, Jaxpr):
        yield param
    elif isinstance(param, (list, tuple)):
        for e in param:
            yield from subjaxprs(e)


def iter_eqns(jaxpr: Jaxpr) -> Iterator[Any]:
    """Yield every eqn in ``jaxpr``, recursing into nested jaxprs (jit /
    scan / cond bodies) but NOT into ``pallas_call`` kernel bodies — a
    kernel's internal eqns are one launch's VMEM work, not separate
    launches or HBM intermediates."""
    for eqn in jaxpr.eqns:
        yield eqn
        if eqn.primitive.name == "pallas_call":
            continue
        for p in eqn.params.values():
            for sub in subjaxprs(p):
                yield from iter_eqns(sub)


@dataclasses.dataclass(frozen=True)
class PallasLaunch:
    """One traced ``pallas_call``: the kernel's name and launch grid."""
    kernel: str
    grid: tuple[int, ...]


def kernel_name(eqn: Any) -> str:
    """The kernel function name a ``pallas_call`` eqn was traced from."""
    info = eqn.params.get("name_and_src_info")
    if info is not None and getattr(info, "name", None):
        return str(info.name)
    name = eqn.params.get("name")           # older jax spelling
    return str(name) if name else "pallas_call"


def call_subjaxpr(eqn: Any) -> ClosedJaxpr | None:
    """The positionally-mapped inner jaxpr of a call primitive, or None.

    Only :data:`CALL_PRIMITIVES` qualify: their ``eqn.invars`` line up
    one-to-one with the inner jaxpr's invars, which is what lets the
    dataflow passes thread value identity through the call boundary.
    """
    if eqn.primitive.name not in CALL_PRIMITIVES:
        return None
    inner = eqn.params.get("jaxpr") or eqn.params.get("call_jaxpr")
    if isinstance(inner, Jaxpr):
        inner = ClosedJaxpr(inner, ())
    if isinstance(inner, ClosedJaxpr) and \
            len(inner.jaxpr.invars) == len(eqn.invars):
        return inner
    return None


def pallas_eqns(fn: Any, *args: Any) -> list[Any]:
    """Every traced ``pallas_call`` eqn of ``fn``, in trace order — the
    raw material for the launch inventory and the VMEM pass."""
    closed = jax.make_jaxpr(fn)(*args)
    return [eqn for eqn in iter_eqns(closed.jaxpr)
            if eqn.primitive.name == "pallas_call"]


def pallas_launches(fn: Any, *args: Any) -> list[PallasLaunch]:
    """Every pallas_call in ``fn``'s jaxpr, in trace order, with its
    kernel name and launch grid — the unit the telemetry cost probes
    (``telemetry/probes.py``) record and regression-gate."""
    return [PallasLaunch(kernel=kernel_name(eqn),
                         grid=tuple(eqn.params["grid_mapping"].grid))
            for eqn in pallas_eqns(fn, *args)]


def pallas_grids(fn: Any, *args: Any) -> list[tuple[int, ...]]:
    """Launch grid of every pallas_call in ``fn``'s jaxpr, in trace order.

    The serving subsystem's GEMV-vs-GEMM evidence is launch-*shape*
    level: a batch ≤ 8 dense flush must lower to the N-major 1-D GEMV
    grid and a large flush to the 3-D (M, N, K) blocked GEMM grid
    (``kernels.ops.dispatch_batch``).
    """
    return [launch.grid for launch in pallas_launches(fn, *args)]


def count_pallas_calls(fn: Any, *args: Any) -> int:
    """Number of pallas_call primitives in ``fn``'s jaxpr — the
    kernel-launch count of the traced fn, recursing into jit bodies."""
    return len(pallas_launches(fn, *args))


def max_intermediate_bytes(fn: Any, *args: Any) -> tuple[int, tuple[int, ...]]:
    """(bytes, shape) of the largest intermediate any eqn produces —
    the HBM high-water evidence for the fused epilogues (an eqn output
    is an HBM-visible array at jaxpr level; pallas_call bodies are
    excluded, their internals live in VMEM)."""
    closed = jax.make_jaxpr(fn)(*args)
    best_bytes, best_shape = 0, ()
    for eqn in iter_eqns(closed.jaxpr):
        for v in eqn.outvars:
            aval = v.aval
            if hasattr(aval, "shape") and hasattr(aval, "dtype"):
                nbytes = int(aval.size) * aval.dtype.itemsize
                if nbytes > best_bytes:
                    best_bytes, best_shape = nbytes, tuple(aval.shape)
    return best_bytes, best_shape
