"""Sharding pass: collective-traffic analysis of compiled (partitioned)
HLO, lifted out of ``distributed/verify_sharded.py``'s inline asserts.

The sharded packed forward has exactly two legal collective shapes
(DESIGN.md / ``distributed/sharding.py``):

* **data-parallel mesh** (model degree 1): ZERO collectives anywhere in
  the forward — batch shards never communicate;
* **model-parallel mesh**: packed-word **all-gathers only** — an
  all-reduce would mean a contraction crossed chips with a partial
  int32 sum, and a reduce-scatter / all-to-all would mean the shard
  plan resharded an activation mid-stack.

``utils/hlo.py`` stays the low-level text parser (regex + wire-byte
model); this module turns its output into reusable verdicts with a
violation list, so the verifier, the telemetry probes, and the merged
analysis report all apply the SAME rule instead of three hand-rolled
copies of ``set(kinds) <= {...}``.
"""
from __future__ import annotations

import dataclasses

from repro.utils.hlo import collective_bytes, collective_kinds

# The one collective a model-parallel packed forward may emit: the
# packed-word all-gather at stage output seams (``cnn._gather_packed``).
MODEL_PARALLEL_ALLOWED = frozenset({"all-gather"})


@dataclasses.dataclass(frozen=True)
class CollectiveReport:
    """Collective inventory of one compiled module + rule verdicts."""
    kinds: dict[str, int]            # kind -> occurrence count
    bytes_by_kind: dict[str, float]  # kind -> modeled wire bytes
    total_bytes: float
    violations: tuple[str, ...]

    @property
    def ok(self) -> bool:
        return not self.violations

    def to_json(self) -> dict:
        return {
            "kinds": dict(sorted(self.kinds.items())),
            "total_bytes": self.total_bytes,
            "violations": list(self.violations),
        }


def analyze_hlo(hlo_text: str) -> tuple[dict[str, int], dict[str, float]]:
    """Raw collective inventory (kinds + modeled bytes) of one module."""
    by_kind = collective_bytes(hlo_text)
    return collective_kinds(hlo_text), by_kind


def check_data_parallel(hlo_text: str) -> CollectiveReport:
    """Data-parallel rule: the partitioned module must contain ZERO
    collectives — any at all means batch shards are communicating."""
    kinds, by_kind = analyze_hlo(hlo_text)
    total = float(by_kind.get("total", 0.0))
    violations = tuple(
        f"data-parallel path emits {n}x {kind} "
        f"({by_kind.get(kind, 0.0):.0f} B) — must be collective-free"
        for kind, n in sorted(kinds.items()))
    if not kinds and total:
        violations = (f"data-parallel path moves {total:.0f} collective "
                      "bytes — must be collective-free",)
    return CollectiveReport(kinds=kinds, bytes_by_kind=by_kind,
                            total_bytes=total, violations=violations)


def check_model_parallel(hlo_text: str, *,
                         allowed: frozenset[str] = MODEL_PARALLEL_ALLOWED
                         ) -> CollectiveReport:
    """Model-parallel rule: only ``allowed`` collective kinds (default:
    the packed-word all-gather).  An all-reduce is the canonical
    violation — a partial int32 sum crossed chips unpacked."""
    kinds, by_kind = analyze_hlo(hlo_text)
    violations = tuple(
        f"off-plan collective: {n}x {kind} "
        f"({by_kind.get(kind, 0.0):.0f} B) — model mesh allows only "
        f"{sorted(allowed)}"
        for kind, n in sorted(kinds.items()) if kind not in allowed)
    return CollectiveReport(kinds=kinds, bytes_by_kind=by_kind,
                            total_bytes=float(by_kind.get("total", 0.0)),
                            violations=violations)


def check_mesh(hlo_text: str, mesh_shape: tuple[int, int]
               ) -> CollectiveReport:
    """Apply the rule matching a (data, model) mesh shape: model degree
    1 is the data-parallel rule, anything else the model-parallel one."""
    if mesh_shape[1] == 1:
        return check_data_parallel(hlo_text)
    return check_model_parallel(hlo_text)
