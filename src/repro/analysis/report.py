"""Merged static-analysis report: every pass over the canonical demo
models, keyed like ``PROBES_baseline.json`` and drift-gated in CI.

Cells (stable keys — they ARE the baseline diff surface):

* ``packedness/{bmlp,bcnn,transformer}`` — the packedness dataflow
  verdict for each packed forward at the serving batch (8): HBM
  crossing classification, max live unpacked bytes, escapes (must stay
  empty);
* ``vmem/{kind}_b8`` — the traced per-launch VMEM estimates (kernel,
  grid, bytes, fits) for the same forwards;
* ``lint`` — the repo lint pass over ``src/`` (violations must stay
  empty);
* ``sharding/{bmlp,bcnn}_4x2`` — the collective-rule verdict for the
  model-parallel mesh the probes exercise (all-gather-only).

CI runs ``PYTHONPATH=src python -m repro.analysis --check`` and fails
on ANY drift against ``experiments/ANALYSIS_baseline.json``; after an
intentional kernel/model change, regenerate with ``--write`` and
commit the diff (see ``docs/analysis.md``).  The sharding cells need 8
devices — the CLI re-execs itself with forced host devices, same
pattern as ``telemetry/probes.py``.

``diff_reports`` lives here (moved from ``telemetry/probes.py``, which
now re-exports it): one structural differ serves both baselines.
"""
from __future__ import annotations

import os
from typing import Any

BASELINE_PATH = os.path.join("experiments", "ANALYSIS_baseline.json")
SHARDED_MESH = (4, 2)
SHARDED_DEVICES = SHARDED_MESH[0] * SHARDED_MESH[1]
REPORT_BATCH = 8


def repo_root() -> str:
    return os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))))


def demo_packed(kind: str) -> Any:
    """The shared demo configs every standing gate probes: the two
    smoke-sized demo networks and the reduced gemma2 binary LM (the
    same builders ``telemetry/probes.py`` records baselines for)."""
    from repro.models import cnn

    if kind == "transformer":
        import jax

        from repro.configs import get_config
        from repro.models import transformer as TF

        cfg = get_config("gemma2-9b", reduced=True)
        params = TF.init_binary_lm(jax.random.PRNGKey(0), cfg)
        return TF.pack_transformer(params, cfg, max_len=8)
    params, spec, kind = cnn.demo_model(kind, smoke=True)
    pack = cnn.pack_bcnn if kind == "bcnn" else cnn.pack_bmlp
    return pack(params, spec)


def _forward_and_input(packed: Any, batch: int):
    import numpy as np

    from repro.models import cnn

    fwd = cnn.make_packed_forward(packed, backend="pallas")
    x = np.zeros((batch, *cnn.packed_input_shape(packed)), np.uint8)
    return (lambda a: fwd(a)), x


def packedness_cell(kind: str, *, batch: int = REPORT_BATCH) -> dict:
    """Packedness verdict for one demo forward (pallas backend)."""
    from repro.analysis.packedness import analyze_packedness, model_policy

    fn, x = _forward_and_input(demo_packed(kind), batch)
    return analyze_packedness(fn, x, policy=model_policy(kind)).to_json()


def vmem_cell(kind: str, *, batch: int = REPORT_BATCH) -> list[dict]:
    """Traced per-launch VMEM estimates for one demo forward."""
    from repro.analysis.vmem import estimate_forward

    fn, x = _forward_and_input(demo_packed(kind), batch)
    return [est.to_json() for est in estimate_forward(fn, x)]


def lint_cell(root: str | None = None) -> dict:
    """The repo lint pass over ``src/`` as a report cell."""
    from repro.analysis.lint import lint_paths

    root = os.path.join(repo_root(), "src") if root is None else root
    return {"violations": [str(v).replace(repo_root() + os.sep, "")
                           for v in lint_paths([root])]}


def sharding_cell(kind: str, *,
                  mesh_shape: tuple[int, int] = SHARDED_MESH) -> dict:
    """Collective-rule verdict for one demo forward on a (data, model)
    mesh — the compiled-HLO path ``probe_sharded`` records bytes for,
    run through ``analysis.collectives.check_mesh``.  Requires
    ``prod(mesh_shape)`` devices."""
    import numpy as np

    from repro.analysis.collectives import check_mesh
    from repro.distributed import sharding as SH
    from repro.launch.mesh import make_mesh
    from repro.models import cnn

    packed = demo_packed(kind)
    mesh = make_mesh(mesh_shape, ("data", "model"))
    fwd = SH.make_sharded_forward(packed, mesh, backend="jnp")
    x = np.zeros((REPORT_BATCH, *cnn.packed_input_shape(packed)), np.uint8)
    hlo = fwd.lower(x).compile().as_text()
    return check_mesh(hlo, mesh_shape).to_json()


def merged_report(*, sharded: bool = True) -> dict:
    """All four passes over the canonical cells (see module docstring)."""
    cells: dict[str, Any] = {}
    for kind in ("bmlp", "bcnn", "transformer"):
        cells[f"packedness/{kind}"] = packedness_cell(kind)
        cells[f"vmem/{kind}_b{REPORT_BATCH}"] = vmem_cell(kind)
    cells["lint"] = lint_cell()
    if sharded:
        for kind in ("bmlp", "bcnn"):
            cells[f"sharding/{kind}_"
                  f"{SHARDED_MESH[0]}x{SHARDED_MESH[1]}"] = \
                sharding_cell(kind)
    return {"schema": 1, "cells": cells}


def report_ok(report: dict) -> list[str]:
    """Hard invariant failures in a merged report (independent of any
    baseline): packedness escapes, incomplete dataflow coverage,
    over-budget launches, lint or sharding violations."""
    bad: list[str] = []
    for key, cell in report["cells"].items():
        if key.startswith("packedness/"):
            bad += [f"{key}: {e}" for e in cell["escapes"]]
            if not cell["complete"]:
                bad.append(f"{key}: dataflow did not cover every launch")
        elif key.startswith("vmem/"):
            bad += [f"{key}: {c['kernel']} grid={c['grid']} "
                    f"needs {c['bytes']} B VMEM (over budget)"
                    for c in cell if not c["fits"]]
        elif key == "lint":
            bad += [f"lint: {v}" for v in cell["violations"]]
        elif key.startswith("sharding/"):
            bad += [f"{key}: {v}" for v in cell["violations"]]
    return bad


def diff_reports(baseline: Any, current: Any, path: str = "") -> list[str]:
    """Recursive structural diff, one human-readable line per drift.

    Shared by this module's ``--check`` gate and the telemetry probes'
    (``telemetry/probes.py`` re-exports it).
    """
    out: list[str] = []
    if isinstance(baseline, dict) and isinstance(current, dict):
        for k in sorted(set(baseline) | set(current)):
            p = f"{path}/{k}" if path else str(k)
            if k not in baseline:
                out.append(f"{p}: NEW (not in baseline)")
            elif k not in current:
                out.append(f"{p}: MISSING (in baseline only)")
            else:
                out += diff_reports(baseline[k], current[k], p)
        return out
    if isinstance(baseline, list) and isinstance(current, list):
        if len(baseline) != len(current):
            out.append(f"{path}: length {len(baseline)} -> {len(current)}")
        for i, (b, c) in enumerate(zip(baseline, current)):
            out += diff_reports(b, c, f"{path}[{i}]")
        return out
    if baseline != current:
        out.append(f"{path}: {baseline!r} -> {current!r}")
    return out
