"""Repo lint pass: AST-enforced kernel-subsystem conventions.

These are the rules the kernel reviews kept re-checking by hand; each
encodes a invariant whose violation has bitten a binary-net codebase
before (BMXNet's integration bugs are the cautionary tale):

* **R001 backend-resolve** — in ``kernels/``, every function exposing a
  ``backend`` parameter must route it through ``_resolve`` (the single
  place unknown backends raise) or forward it onward; a dispatcher
  that string-matches backends locally silently accepts typos and
  falls back to the wrong path.
* **R002 knob-validation** — in ``kernels/`` (the wrappers that build
  BlockSpecs), every exposed block knob (``block_*``,
  ``words_per_step``) must be validated via a ``check_*``/``resolve_*``
  helper or forwarded to one — an unvalidated knob reaches Mosaic as a
  lane/sublane seam error (or silent mis-tiling in interpret mode).
* **R003 no-hardcoded-interpret** — no ``interpret=True`` literal
  anywhere in ``src/``: interpret mode is a per-call decision owned by
  ``ops._on_tpu()``; a hardcoded literal would pin a kernel to the
  slow path on real TPUs (tests may do it; src must not).
* **R004 backend-probe-locality** — ``jax.default_backend()`` calls
  and ``backend == "..."`` string comparisons are only legal in
  ``kernels/ops.py``: backend resolution has exactly one home, so a
  silent jnp fallback can't hide in a model file.

Run as a CLI (the CI analysis job does)::

    PYTHONPATH=src python -m repro.analysis.lint src/

exits 1 and prints ``path:line: RULE message`` per violation.  The
merged analysis report embeds the same result as its ``lint`` cell.
"""
from __future__ import annotations

import ast
import dataclasses
import os
import sys
from typing import Iterable, Iterator

KNOB_PREFIXES = ("block_",)
KNOB_NAMES = ("words_per_step",)

# Files exempt per rule (paths matched by basename within kernels/).
R001_EXEMPT = ("ref.py",)
R002_EXEMPT = ("ref.py",)
R004_HOME = os.path.join("kernels", "ops.py")


@dataclasses.dataclass(frozen=True)
class Violation:
    rule: str
    path: str
    line: int
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} {self.message}"


def _is_kernels_file(path: str) -> bool:
    parts = os.path.normpath(path).split(os.sep)
    return "kernels" in parts


def _func_params(fn: ast.FunctionDef | ast.AsyncFunctionDef) -> list[str]:
    a = fn.args
    return [p.arg for p in
            (*a.posonlyargs, *a.args, *a.kwonlyargs)]


def _calls(fn: ast.AST) -> Iterator[ast.Call]:
    for node in ast.walk(fn):
        if isinstance(node, ast.Call):
            yield node


def _call_name(call: ast.Call) -> str:
    f = call.func
    if isinstance(f, ast.Name):
        return f.id
    if isinstance(f, ast.Attribute):
        return f.attr
    return ""


def _forwards_name(call: ast.Call, name: str) -> bool:
    """Does ``call`` pass the bare variable ``name`` (positionally or as
    any keyword)?"""
    for arg in call.args:
        if isinstance(arg, ast.Name) and arg.id == name:
            return True
    for kw in call.keywords:
        if isinstance(kw.value, ast.Name) and kw.value.id == name:
            return True
    return False


def _check_backend_rule(fn: ast.FunctionDef | ast.AsyncFunctionDef,
                        path: str) -> Iterator[Violation]:
    """R001: a kernels/ function with a ``backend`` param must resolve
    or forward it."""
    if "backend" not in _func_params(fn) or fn.name == "_resolve":
        return
    for call in _calls(fn):
        if _call_name(call).endswith("_resolve"):
            return
        if _forwards_name(call, "backend"):
            return
        if any(kw.arg == "backend" for kw in call.keywords):
            return
    yield Violation("R001", path, fn.lineno,
                    f"function '{fn.name}' takes a backend parameter but "
                    "neither routes it through _resolve nor forwards it")


def _check_knob_rule(fn: ast.FunctionDef | ast.AsyncFunctionDef,
                     path: str) -> Iterator[Violation]:
    """R002: every block knob param must be validated (check_*/resolve_*)
    or forwarded into some call that will.

    Applies to public functions only: validation is the exposed
    wrapper's contract; private kernels/helpers receive knobs their
    wrapper already validated.
    """
    if fn.name.startswith("_"):
        return
    knobs = [p for p in _func_params(fn)
             if p.startswith(KNOB_PREFIXES) or p in KNOB_NAMES]
    for knob in knobs:
        ok = False
        for call in _calls(fn):
            name = _call_name(call)
            validated = name.startswith(("check_", "resolve_"))
            if validated or _forwards_name(call, knob):
                if validated and not _forwards_name(call, knob):
                    # check_block_lanes("block_n", block_n) names the knob
                    # as a string; accept that spelling too.
                    if not any(isinstance(a, ast.Constant) and
                               a.value == knob for a in call.args):
                        continue
                ok = True
                break
        if not ok:
            yield Violation(
                "R002", path, fn.lineno,
                f"block knob '{knob}' of '{fn.name}' is neither validated "
                "(check_*/resolve_*) nor forwarded to a validator")


def _check_interpret_rule(tree: ast.AST, path: str) -> Iterator[Violation]:
    """R003: no literal ``interpret=True`` keyword in src/."""
    for call in _calls(tree):
        for kw in call.keywords:
            if kw.arg == "interpret" and \
                    isinstance(kw.value, ast.Constant) and \
                    kw.value.value is True:
                yield Violation(
                    "R003", path, kw.value.lineno,
                    "hardcoded interpret=True — interpret mode is decided "
                    "per call by kernels.ops (_on_tpu)")


def _check_backend_locality(tree: ast.AST, path: str) -> Iterator[Violation]:
    """R004: backend probing/string-matching only in kernels/ops.py."""
    if os.path.normpath(path).endswith(R004_HOME):
        return
    for node in ast.walk(tree):
        if isinstance(node, ast.Attribute) and \
                node.attr == "default_backend":
            yield Violation(
                "R004", path, node.lineno,
                "jax.default_backend() outside kernels/ops.py — backend "
                "resolution has one home")
        if isinstance(node, ast.Compare) and \
                isinstance(node.left, ast.Name) and \
                node.left.id == "backend" and \
                any(isinstance(c, ast.Constant) and isinstance(c.value, str)
                    for c in node.comparators):
            yield Violation(
                "R004", path, node.lineno,
                "string-matching 'backend' outside kernels/ops.py — route "
                "through ops._resolve instead")


def lint_source(source: str, path: str) -> list[Violation]:
    """Lint one file's source text; ``path`` scopes the per-dir rules."""
    tree = ast.parse(source, filename=path)
    out: list[Violation] = []
    base = os.path.basename(path)
    if _is_kernels_file(path):
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                if base not in R001_EXEMPT:
                    out.extend(_check_backend_rule(node, path))
                if base not in R002_EXEMPT:
                    out.extend(_check_knob_rule(node, path))
    out.extend(_check_interpret_rule(tree, path))
    out.extend(_check_backend_locality(tree, path))
    return sorted(out, key=lambda v: (v.path, v.line, v.rule))


def lint_paths(paths: Iterable[str]) -> list[Violation]:
    """Lint every ``.py`` file under the given files/directories."""
    files: list[str] = []
    for p in paths:
        if os.path.isdir(p):
            for root, _, names in os.walk(p):
                files += [os.path.join(root, n) for n in names
                          if n.endswith(".py")]
        elif p.endswith(".py"):
            files.append(p)
    out: list[Violation] = []
    for f in sorted(files):
        with open(f, encoding="utf-8") as fh:
            out.extend(lint_source(fh.read(), f))
    return out


def main(argv: list[str] | None = None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    paths = argv or ["src"]
    violations = lint_paths(paths)
    for v in violations:
        print(v)
    if violations:
        print(f"{len(violations)} lint violation(s)")
        return 1
    print("lint clean")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
