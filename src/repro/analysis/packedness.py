"""Packedness dataflow pass: verify that activations stay bit-packed
across every HBM crossing of a traced packed forward.

Espresso's value proposition — the speedups and the 9.4 MB → 256 KB
intermediate shrink — evaporates silently if one stage leaks an
unpacked int32/float32 activation back to HBM between kernels.  The
per-PR evidence so far was bench-level (``max_intermediate_bytes``
rows); this pass turns it into a machine-checked dataflow invariant.

The pass abstract-interprets a forward's jaxpr (one pass, in trace
order, threading value identity through ``pjit`` call boundaries) and
classifies every value that crosses a ``pallas_call`` boundary — i.e.
is HBM-resident by construction — into:

* ``packed``  — uint32 words (bit-packed activations / weights);
* ``float``   — floating values (folded BN thresholds, attention V,
  the float residual stream of the binary LM, output logits);
* ``unpacked`` — integer non-uint32 values *derived from a kernel
  output* (int32 accumulator activations);
* ``staging`` — integer values derived only from the jaxpr's inputs
  (bit-plane extraction, raw uint8 images) — input staging, not an
  intermediate.

Escape rule (the invariant): a value **produced by a kernel in
unpacked form** must only ever re-enter the kernel domain through a
fused *epilogue* kernel (:data:`EPILOGUE_KERNELS` — the standalone
BN-sign-repack used after accumulating stages, whose whole point is
consuming the int32 bridge).  Reaching any other kernel — e.g. being
host-side re-binarized and fed to the generic ``_bitpack_kernel`` —
is an HBM escape and is reported with producer and consumer names.

Two policies, matching the two workload families:

* ``strict`` (``bcnn`` / ``bmlp``): fully binary networks — every
  kernel output other than packed words is tracked, and the taint
  survives float laundering (an int32 GEMM output that is sign()-ed to
  float and then re-packed is exactly the leak this pass exists for).
* ``float-residual`` (``transformer``): the residual stream is float
  by design (paper's LM serving path), so float kernel outputs are a
  legal class and an int → float conversion ends the taint (the V / Q
  / K projections are *meant* to step through float before
  re-binarizing).

The headline per-model number is ``max_live_unpacked_bytes``: a
liveness sweep over the unpacked class — the peak HBM footprint of
un-packed activations at any point of the forward.  ``analysis
--check`` pins it (and the full classification) against
``experiments/ANALYSIS_baseline.json``.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.analysis import graph

# Kernels whose JOB is to consume an unpacked HBM bridge: the standalone
# fused BN-sign-repack epilogue (used after stages that accumulate in
# int32 outside a single launch — the bit-plane first layers).
EPILOGUE_KERNELS = frozenset({"_bn_sign_pack_kernel"})

POLICIES = ("strict", "float-residual")


@dataclasses.dataclass
class ValueRecord:
    """One traced value (jaxpr-level array) seen by the dataflow walk."""
    shape: tuple[int, ...]
    dtype: str
    nbytes: int
    producer: str                 # 'input' | 'const' | prim or kernel name
    step: int                     # production step (flattened eqn order)
    last_use: int                 # last consuming step (-1: never used)
    kernel_output: bool           # produced directly by a pallas_call
    pallas_ancestry: bool         # transitively derived from a launch
    cls: str = "staging"          # packed | float | unpacked | staging
    escapes: tuple[str, ...] = () # non-epilogue kernels this leaked into


@dataclasses.dataclass(frozen=True)
class Escape:
    """One packedness violation: an unpacked kernel output that crossed
    HBM into a non-epilogue kernel."""
    producer: str
    consumer: str
    shape: tuple[int, ...]
    dtype: str
    nbytes: int

    def describe(self) -> str:
        return (f"{self.producer} -> {self.consumer}: unpacked "
                f"{self.dtype}{list(self.shape)} ({self.nbytes} B) "
                f"crossed HBM outside the epilogue contract")


@dataclasses.dataclass(frozen=True)
class PackednessReport:
    """Result of :func:`analyze_packedness` for one traced forward."""
    policy: str
    launch_count: int
    complete: bool                # dataflow saw every syntactic launch
    hbm_values: dict[str, int]    # class -> count of boundary crossings
    hbm_bytes: dict[str, int]     # class -> max single-value bytes
    max_live_unpacked_bytes: int
    max_unpacked_shape: tuple[int, ...]
    escapes: tuple[Escape, ...]

    @property
    def ok(self) -> bool:
        return not self.escapes and self.complete

    def to_json(self) -> dict[str, Any]:
        """Stable dict form — the ``packedness/*`` baseline cells."""
        return {
            "policy": self.policy,
            "launch_count": self.launch_count,
            "complete": self.complete,
            "hbm_values": dict(sorted(self.hbm_values.items())),
            "hbm_bytes": dict(sorted(self.hbm_bytes.items())),
            "max_live_unpacked_bytes": self.max_live_unpacked_bytes,
            "max_unpacked_shape": list(self.max_unpacked_shape),
            "escapes": [e.describe() for e in self.escapes],
        }


def _is_float(dtype: Any) -> bool:
    return jnp.issubdtype(dtype, jnp.floating)


def _is_packed(dtype: Any) -> bool:
    return jnp.dtype(dtype) == jnp.dtype(jnp.uint32)


def _is_int(dtype: Any) -> bool:
    return jnp.issubdtype(dtype, jnp.integer) or \
        jnp.dtype(dtype) == jnp.dtype(bool)


class _Walker:
    """Abstract interpreter over a closed jaxpr (see module docstring)."""

    def __init__(self, policy: str):
        if policy not in POLICIES:
            raise ValueError(f"policy must be one of {POLICIES}, "
                             f"got {policy!r}")
        self.policy = policy
        self.step = 0
        self.values: list[ValueRecord] = []
        self.carries: dict[int, frozenset[int]] = {}   # value idx -> roots
        self.boundary: set[int] = set()                # crossed a launch
        self.launch_count = 0

    # -- value bookkeeping --------------------------------------------------

    def _new(self, aval: Any, producer: str, *,
             kernel_output: bool = False,
             pallas_ancestry: bool = False) -> int:
        shape = tuple(getattr(aval, "shape", ()))
        dtype = getattr(aval, "dtype", None)
        nbytes = (int(aval.size) * dtype.itemsize
                  if dtype is not None and hasattr(aval, "size") else 0)
        rec = ValueRecord(
            shape=shape, dtype=str(dtype), nbytes=nbytes,
            producer=producer, step=self.step, last_use=-1,
            kernel_output=kernel_output, pallas_ancestry=pallas_ancestry)
        if dtype is None:
            rec.cls = "staging"
        elif _is_packed(dtype):
            rec.cls = "packed"
        elif _is_float(dtype):
            rec.cls = "float"
        elif _is_int(dtype) and pallas_ancestry:
            rec.cls = "unpacked"
        else:
            rec.cls = "staging"
        self.values.append(rec)
        return len(self.values) - 1

    def _tracked_root(self, idx: int) -> bool:
        """Is this value a taint root — a kernel output that left the
        launch in unpacked form?  Under the float-residual policy a
        float kernel output is a legal class, not a root."""
        rec = self.values[idx]
        if not rec.kernel_output or rec.cls == "packed":
            return False
        if rec.cls == "float" and self.policy == "float-residual":
            return False
        return True

    def _propagate(self, out_idx: int, in_idxs: list[int]) -> None:
        rec = self.values[out_idx]
        roots: set[int] = set()
        for i in in_idxs:
            roots |= self.carries.get(i, frozenset())
            if self._tracked_root(i):
                roots.add(i)
        if roots and self.policy == "float-residual" and rec.cls == "float":
            roots = set()          # int -> float conversion launders
        if roots:
            self.carries[out_idx] = frozenset(roots)

    # -- traversal ----------------------------------------------------------

    def run(self, closed: Any) -> None:
        env: dict[Any, int] = {}
        jaxpr = closed.jaxpr
        for var in jaxpr.invars:
            env[var] = self._new(var.aval, "input")
        for var, const in zip(jaxpr.constvars, closed.consts):
            env[var] = self._new(var.aval, "const")
        self._walk(jaxpr, env)
        for var in jaxpr.outvars:
            idx = None if hasattr(var, "val") else env.get(var)
            if idx is not None:
                self.values[idx].last_use = self.step + 1
                self.boundary.add(idx)      # model outputs are HBM-visible

    def _in_idxs(self, eqn: Any, env: dict[Any, int]) -> list[int]:
        idxs = []
        for v in eqn.invars:
            # Literals (unhashable, carry .val) are constants, not values.
            if not hasattr(v, "val") and v in env:
                idxs.append(env[v])
        return idxs

    def _walk(self, jaxpr: Any, env: dict[Any, int]) -> None:
        for eqn in jaxpr.eqns:
            self.step += 1
            in_idxs = self._in_idxs(eqn, env)
            for i in in_idxs:
                self.values[i].last_use = self.step
            name = eqn.primitive.name
            if name == "pallas_call":
                self._visit_pallas(eqn, env, in_idxs)
                continue
            inner = graph.call_subjaxpr(eqn)
            if inner is not None:
                sub_env: dict[Any, int] = {}
                for var, const in zip(inner.jaxpr.constvars, inner.consts):
                    sub_env[var] = self._new(var.aval, "const")
                for var, idx in zip(inner.jaxpr.invars, in_idxs):
                    sub_env[var] = idx
                self._walk(inner.jaxpr, sub_env)
                for outer, var in zip(eqn.outvars, inner.jaxpr.outvars):
                    if not hasattr(var, "val") and var in sub_env:
                        env[outer] = sub_env[var]
                        self.values[sub_env[var]].last_use = self.step
                    else:                    # literal-returning body
                        env[outer] = self._new(outer.aval, name)
                continue
            ancestry = any(self.values[i].pallas_ancestry or
                           self.values[i].kernel_output for i in in_idxs)
            for outer in eqn.outvars:
                idx = self._new(outer.aval, name, pallas_ancestry=ancestry)
                self._propagate(idx, in_idxs)
                env[outer] = idx

    def _visit_pallas(self, eqn: Any, env: dict[Any, int],
                      in_idxs: list[int]) -> None:
        self.launch_count += 1
        kname = graph.kernel_name(eqn)
        for i in in_idxs:
            self.boundary.add(i)
            roots = set(self.carries.get(i, frozenset()))
            if self._tracked_root(i):
                roots.add(i)
            if kname not in EPILOGUE_KERNELS:
                for r in roots:
                    rec = self.values[r]
                    if kname not in rec.escapes:
                        rec.escapes = (*rec.escapes, kname)
        for outer in eqn.outvars:
            idx = self._new(outer.aval, kname, kernel_output=True,
                            pallas_ancestry=True)
            self.boundary.add(idx)
            env[outer] = idx


def _max_live(values: list[ValueRecord]) -> tuple[int, tuple[int, ...]]:
    """Peak concurrent bytes of the unpacked class (linear liveness
    sweep over production/last-use steps) and the single largest
    unpacked value's shape."""
    events: list[tuple[int, int, int]] = []
    best_shape: tuple[int, ...] = ()
    best_bytes = 0
    for rec in values:
        if rec.cls != "unpacked" or rec.last_use < rec.step:
            continue
        events.append((rec.step, 0, rec.nbytes))
        events.append((rec.last_use, 1, -rec.nbytes))
        if rec.nbytes > best_bytes:
            best_bytes, best_shape = rec.nbytes, rec.shape
    live = peak = 0
    # births sort before deaths at the same step: a value is live at the
    # step that both produces it and last-uses its predecessor.
    for _, _, delta in sorted(events):
        live += delta
        peak = max(peak, live)
    return peak, best_shape


def analyze_packedness(fn: Any, *args: Any,
                       policy: str = "strict") -> PackednessReport:
    """Run the packedness dataflow pass over ``fn`` traced at ``args``.

    Pure tracing (``jax.make_jaxpr``) — no kernel executes, so the
    pallas backend is cheap to analyze off-TPU.  ``policy``:
    ``'strict'`` (fully binary networks) or ``'float-residual'``
    (binary LMs with a float residual stream); see module docstring.
    """
    closed = jax.make_jaxpr(fn)(*args)
    walker = _Walker(policy)
    walker.run(closed)

    syntactic = sum(1 for eqn in graph.iter_eqns(closed.jaxpr)
                    if eqn.primitive.name == "pallas_call")
    hbm_values: dict[str, int] = {}
    hbm_bytes: dict[str, int] = {}
    escapes: list[Escape] = []
    for idx, rec in enumerate(walker.values):
        if idx in walker.boundary:
            hbm_values[rec.cls] = hbm_values.get(rec.cls, 0) + 1
            hbm_bytes[rec.cls] = max(hbm_bytes.get(rec.cls, 0), rec.nbytes)
        for kname in rec.escapes:
            escapes.append(Escape(producer=rec.producer, consumer=kname,
                                  shape=rec.shape, dtype=rec.dtype,
                                  nbytes=rec.nbytes))
    peak, shape = _max_live(walker.values)
    return PackednessReport(
        policy=policy,
        launch_count=walker.launch_count,
        complete=walker.launch_count == syntactic,
        hbm_values=hbm_values,
        hbm_bytes=hbm_bytes,
        max_live_unpacked_bytes=peak,
        max_unpacked_shape=shape,
        escapes=tuple(sorted(escapes,
                             key=lambda e: (e.producer, e.consumer))),
    )


def model_policy(kind: str) -> str:
    """The packedness policy each workload family is verified under."""
    return "float-residual" if kind == "transformer" else "strict"
