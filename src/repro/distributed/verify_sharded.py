"""Sharded packed-forward verifier: 8 forced CPU devices, own process.

Proves, without hardware, that ``make_sharded_forward`` is

* **bit-identical** to the single-device packed forward for every mesh
  shape (data, model) in {(8,1), (4,2), (2,4)}, for both evaluation
  networks (BCNN and BMLP), including a non-word-divisible stage that
  exercises the per-stage replication fallback, and (one cell) the
  Pallas backend in interpret mode under shard_map;
* **collective-free on the data-parallel path**: the compiled HLO of the
  (8, 1) mesh contains zero collectives;
* **packed-words-only on the model path**: sharded meshes emit only
  all-gathers (no all-reduce — the conv stack never crosses devices with
  a partial sum or an un-packed int32 activation).

Both rules come from ``analysis.collectives`` (``check_mesh``), the
shared analyzers the telemetry probes and the merged analysis report
also consume.

Usage (the CI sharding job and tests/test_sharded_forward.py run this):

    PYTHONPATH=src python -m repro.distributed.verify_sharded [--json]

NOTE: the XLA_FLAGS line below must execute before ANY other import
touches jax — keep it immediately after the docstring (same pattern as
launch/dryrun.py).
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=8")

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.collectives import check_mesh, check_model_parallel
from repro.distributed import sharding as SH
from repro.launch.mesh import make_mesh
from repro.models import cnn

MESH_SHAPES = ((8, 1), (4, 2), (2, 4))
BATCH = 8

# Small nets that still hit every seam: stages word-divisible at every
# model degree here (128 % (32·4) == 0 shards the bit-plane first stage
# 4-ways; 64 % (32·2) == 0 shards only 2-ways), a stage (48, and 96 in
# the MLP) that is NOT word-divisible for model > 1 (-> replication
# fallback), a pooled sharded stage (bit-domain pool masks sharded), and
# the grouped conv->dense flatten.
BCNN_SPEC = cnn.BCNNSpec(
    input_hw=(8, 8), c_in=3,
    stages=(cnn.ConvStage(128), cnn.ConvStage(48, pool=True),
            cnn.ConvStage(64, pool=True)),
    dense=(128, 10))
BMLP_SIZES = (784, 128, 96, 10)


def _build(kind: str):
    key = jax.random.PRNGKey(0)
    if kind == "bcnn":
        params = cnn.init_bcnn(key, BCNN_SPEC)
        packed = cnn.pack_bcnn(params, BCNN_SPEC)
        x = jax.random.randint(jax.random.fold_in(key, 1),
                               (BATCH, *BCNN_SPEC.input_hw, BCNN_SPEC.c_in),
                               0, 256).astype(jnp.uint8)
        want = cnn.bcnn_forward_packed(packed, x, backend="jnp")
    else:
        spec = cnn.BMLPSpec(sizes=BMLP_SIZES)
        params = cnn.init_bmlp(key, spec)
        packed = cnn.pack_bmlp(params, spec)
        x = jax.random.randint(jax.random.fold_in(key, 1),
                               (BATCH, BMLP_SIZES[0]), 0,
                               256).astype(jnp.uint8)
        want = cnn.bmlp_forward_packed(packed, x, backend="jnp")
    return packed, x, np.asarray(want)


def run_cells(backends=("jnp",), pallas_cell: bool = True) -> list[dict]:
    assert len(jax.devices()) == 8, jax.devices()
    built = {kind: _build(kind) for kind in ("bcnn", "bmlp")}
    cells = []
    for kind in ("bcnn", "bmlp"):
        for shape in MESH_SHAPES:
            for backend in backends:
                cells.append((kind, shape, backend, *built[kind]))
    if pallas_cell:
        # Interpret-mode Pallas cells: the kernels themselves run
        # per-shard under shard_map with local C_out/batch shapes —
        # (4, 2) shards the conv stack (incl. the bit-plane stage 0 and
        # a pooled stage) AND the hidden dense stage (the fused dense
        # GEMM epilogue on word-aligned local rows), (2, 4) shards
        # stage 0 four ways.  The BMLP cell runs the single-launch
        # VMEM-resident hidden stack per shard (its 96-wide hidden
        # layer replicates at the pack seam) under a sharded first
        # layer.
        cells.append(("bcnn", (4, 2), "pallas", *built["bcnn"]))
        cells.append(("bcnn", (2, 4), "pallas", *built["bcnn"]))
        cells.append(("bmlp", (4, 2), "pallas", *built["bmlp"]))

    results = []
    for kind, shape, backend, packed, x, want in cells:
        mesh = make_mesh(shape, ("data", "model"))
        fwd = SH.make_sharded_forward(packed, mesh, backend=backend)
        t0 = time.monotonic()
        got = np.asarray(jax.block_until_ready(fwd(x)))
        t_first = time.monotonic() - t0
        t0 = time.monotonic()
        np.asarray(jax.block_until_ready(fwd(x)))
        t_steady = time.monotonic() - t0
        bitexact = bool((got == want).all())
        # Data-parallel meshes must be collective-free; model meshes may
        # emit packed-word all-gathers only (a partial sum crossing
        # chips would surface as an all-reduce).  The rules live in
        # analysis.collectives so the probes/report apply the same ones.
        coll = check_mesh(fwd.lower(x).compile().as_text(), shape)
        rec = {
            "kind": kind, "mesh": list(shape), "backend": backend,
            "bitexact": bitexact,
            "shard_plan": {k: list(v) for k, v in fwd.shard_plan.items()},
            "collective_bytes": coll.total_bytes,
            "collective_kinds": coll.kinds,
            "collective_violations": list(coll.violations),
            "fwd_first_us": t_first * 1e6, "fwd_us": t_steady * 1e6,
            "ok": bitexact and coll.ok,
        }
        results.append(rec)
    results.append(serve_cell(built))
    results.append(degrade_cell(built))
    return results


def serve_cell(built: dict) -> dict:
    """Serving-over-mesh cell: a ``PackedInferenceServer`` with the
    (4, 2) mesh behind its queue (``train/serve.py``).

    Ragged submits + a deadline flush + a second full-window flush must
    return rows bit-identical to the single-device forward, the flush
    buckets must honor the mesh's ``batch_multiple`` (= 4 here), and
    the engine's compiled HLO obeys the same all-gather-only rule as
    the bare sharded forward.
    """
    from repro.train import serve as SV

    packed, x, want = built["bcnn"]
    mesh = make_mesh((4, 2), ("data", "model"))
    clock = SV.SimClock()
    srv = SV.PackedInferenceServer(max_batch=BATCH,
                                   default_deadline=0.005, clock=clock)
    srv.register("bcnn-serve", packed=packed, backend="jnp", mesh=mesh)
    eng = srv.engine()
    assert all(b % eng.batch_multiple == 0 for b in eng.buckets), eng.buckets
    # Ragged arrivals: 5 requests ride the deadline flush (padded up to
    # the 8 bucket), the remaining 3 arrive later and flush on their own
    # deadline (bucket 4) — no head-of-line blocking either way.
    rids = [srv.submit(np.asarray(x[i])) for i in range(5)]
    assert srv.step() == []                 # deadline still in the future
    clock.advance(1.0)
    done = srv.step()
    rids += [srv.submit(np.asarray(x[i])) for i in range(5, BATCH)]
    clock.advance(1.0)
    done += srv.step()
    by = {r.rid: r.result for r in done}
    got = np.stack([by[rid] for rid in rids])
    bitexact = bool((got == np.asarray(want)).all())
    t0 = time.monotonic()
    srv.serve([np.asarray(x[i]) for i in range(BATCH)])
    t_steady = time.monotonic() - t0
    hlo = eng.fwd.lower(np.zeros((eng.buckets[-1], *eng.example_shape),
                                 np.uint8)).compile().as_text()
    coll = check_model_parallel(hlo)
    return {
        "kind": "bcnn", "mesh": [4, 2], "backend": "serve",
        "bitexact": bitexact,
        "shard_plan": {k: list(v) for k, v in eng.fwd.shard_plan.items()},
        "collective_bytes": coll.total_bytes,
        "collective_kinds": coll.kinds,
        "collective_violations": list(coll.violations),
        "fwd_first_us": t_steady * 1e6, "fwd_us": t_steady * 1e6,
        "ok": (bitexact and coll.ok
               and [f.bucket for f in srv.flushes[:2]] == [8, 4]
               and [f.route for f in srv.flushes[:2]] == ["gemv", "gemv"]),
    }


def degrade_cell(built: dict) -> dict:
    """Elastic-degradation cell: a supervised server on the (4, 2) mesh
    loses 4 of its 8 devices mid-flight (``runtime.faults`` injection).

    The ``ServingSupervisor`` must remesh onto the 4 survivors
    (``remesh_plan`` -> (2, 2)), re-place the packed weights, rebuild
    the engine UNDER the queue, and serve the requeued window — rows
    bit-identical to the single-device forward, with the shrunken
    engine's compiled HLO still obeying the all-gather-only rule
    (a degrade must not smuggle in an all-reduce).
    """
    from repro.runtime.faults import FaultInjector, FaultPlan, FaultSpec
    from repro.runtime.supervisor import ServingSupervisor
    from repro.train import serve as SV

    packed, x, want = built["bcnn"]
    clock = SV.SimClock()
    srv = SV.PackedInferenceServer(max_batch=BATCH,
                                   default_deadline=0.005, clock=clock)
    srv.register("bcnn-degrade", packed=packed, backend="jnp",
                 mesh=make_mesh((4, 2), ("data", "model")))
    sup = ServingSupervisor(srv, "bcnn-degrade", backend="jnp")
    FaultInjector(FaultPlan.of(
        FaultSpec("device_loss", survivors=4))).attach(srv)
    rids = [srv.submit(np.asarray(x[i])) for i in range(BATCH)]
    t0 = time.monotonic()
    done = sup.step()           # loss -> degrade -> requeued window served
    t_first = time.monotonic() - t0
    by = {r.rid: r for r in done}
    bitexact = (all(by[rid].status == "ok" for rid in rids) and
                bool((np.stack([by[rid].result for rid in rids])
                      == np.asarray(want)).all()))
    eng = srv.engine("bcnn-degrade")
    t0 = time.monotonic()
    srv.serve([np.asarray(x[i]) for i in range(BATCH)])
    t_steady = time.monotonic() - t0
    hlo = eng.fwd.lower(np.zeros((eng.buckets[-1], *eng.example_shape),
                                 np.uint8)).compile().as_text()
    coll = check_model_parallel(hlo)
    m = srv.telemetry.metrics
    return {
        "kind": "bcnn", "mesh": [2, 2], "backend": "degrade",
        "bitexact": bitexact,
        "shard_plan": {k: list(v) for k, v in eng.fwd.shard_plan.items()},
        "collective_bytes": coll.total_bytes,
        "collective_kinds": coll.kinds,
        "collective_violations": list(coll.violations),
        "fwd_first_us": t_first * 1e6, "fwd_us": t_steady * 1e6,
        "ok": (bitexact and coll.ok
               and sup.events[0].mesh_shape == (2, 2)
               and tuple(eng.fwd.mesh.shape.values()) == (2, 2)
               and len(eng.fwd.mesh.devices.flatten()) == 4
               and m.value("serve.degraded") == 1
               and m.value("serve.degraded_state") == 0),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", action="store_true",
                    help="machine-readable output only")
    args = ap.parse_args()
    results = run_cells()
    if args.json:
        print(json.dumps(results))
    else:
        for r in results:
            print(f"{r['kind']} mesh={tuple(r['mesh'])} {r['backend']:6s} "
                  f"bitexact={r['bitexact']} "
                  f"coll={r['collective_kinds'] or 'none'} "
                  f"shards={r['shard_plan']} "
                  f"{'OK' if r['ok'] else 'FAIL'}")
    bad = [r for r in results if not r["ok"]]
    if bad:
        raise SystemExit(f"{len(bad)} sharded-forward cells failed")


if __name__ == "__main__":
    main()
