"""Launch `repro.distributed.verify_sharded` in its own process.

The verifier must own its process because the forced device count is
fixed at jax init (and importing the module sets XLA_FLAGS).  The test
suite, the Table-3 benchmark, and the CI sharding job all go through
this one helper so the invocation recipe cannot drift.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys


def repo_root() -> str:
    return os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))))


def run_verifier(timeout: int = 540) -> list[dict]:
    """Run the 8-device sharded-forward sweep; return its result cells.

    Raises RuntimeError (with the subprocess stderr tail) on a non-zero
    exit — callers decide whether that is fatal.
    """
    root = repo_root()
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(root, "src") + os.pathsep +
                         env.get("PYTHONPATH", ""))
    env.pop("XLA_FLAGS", None)      # the verifier sets its own
    proc = subprocess.run(
        [sys.executable, "-m", "repro.distributed.verify_sharded",
         "--json"],
        capture_output=True, text=True, env=env, cwd=root, timeout=timeout)
    if proc.returncode != 0:
        raise RuntimeError(proc.stderr[-4000:])
    return json.loads(proc.stdout)
