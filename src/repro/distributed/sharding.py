"""Sharding rules + divisibility-aware resolver (DESIGN.md §5).

Logical mesh axes:
* ``pod``   — outer data-parallel axis (multi-pod runs)
* ``data``  — inner data-parallel / FSDP axis
* ``model`` — tensor/expert-parallel axis

Parameter rules are matched on the *path* of each leaf in the param tree
(column-parallel projections shard d_out over 'model', row-parallel shard
d_in, experts shard E, embeddings shard vocab, FSDP shards one remaining
large dim over 'data').  The resolver drops any axis assignment whose
mesh size does not divide the dimension — small models (whisper-base)
degrade gracefully to replication instead of failing to lower.

SSM/RG-LRU internals: Mamba-2's fused in-projection interleaves five
semantic blocks on one axis; sharding it over 'model' misaligns shard and
split boundaries and GSPMD inserts reshuffles.  We shard Mamba-2 params
over 'data' (FSDP) only and keep 'model' for the (elementwise-shardable)
RG-LRU width — see EXPERIMENTS.md §Roofline notes.
"""
from __future__ import annotations

import re
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

DATA_AXES = ("pod", "data")      # batch shards over both when present


def _axis_size(mesh: Mesh, name) -> int:
    if name is None:
        return 1
    if isinstance(name, (tuple, list)):
        s = 1
        for n in name:
            s *= _axis_size(mesh, n)
        return s
    return mesh.shape[name] if name in mesh.shape else 0


def _fit(mesh: Mesh, spec: tuple, shape: tuple[int, ...]) -> P:
    """Drop axis assignments that don't divide the dim (or don't exist)."""
    out = []
    for dim, ax in zip(shape, spec):
        if ax is None:
            out.append(None)
            continue
        size = _axis_size(mesh, ax)
        if size and size > 1 and dim % size == 0:
            out.append(ax)
        elif size == 1:
            out.append(None)
        else:
            # try partial tuples: ('pod','data') -> 'data'
            if isinstance(ax, tuple) and len(ax) > 1:
                for sub in (ax[1:], ax[:1]):
                    ssize = _axis_size(mesh, sub)
                    if ssize and dim % ssize == 0:
                        out.append(sub if len(sub) > 1 else sub[0])
                        break
                else:
                    out.append(None)
            else:
                out.append(None)
    return P(*out)


# --------------------------------------------------------------------------
# parameter rules
# --------------------------------------------------------------------------

# (regex on '/'-joined path, spec builder given leaf ndim)
# dims are written for the UNSTACKED leaf; a leading scan axis (stacked
# layers) gets None prepended automatically.
_PARAM_RULES: list[tuple[str, tuple]] = [
    # attention projections (column-parallel qkv, row-parallel o)
    (r"attn/wq/w$",      ("data", "model")),
    (r"attn/wk/w$",      ("data", "model")),
    (r"attn/wv/w$",      ("data", "model")),
    (r"attn/wo/w$",      ("model", "data")),
    (r"xattn/wq/w$",     ("data", "model")),
    (r"xattn/wk/w$",     ("data", "model")),
    (r"xattn/wv/w$",     ("data", "model")),
    (r"xattn/wo/w$",     ("model", "data")),
    # dense FFN
    (r"mlp/w_up/w$",     ("data", "model")),
    (r"mlp/w_gate/w$",   ("data", "model")),
    (r"mlp/w_down/w$",   ("model", "data")),
    (r"shared/w_up/w$",  ("data", "model")),
    (r"shared/w_gate/w$", ("data", "model")),
    (r"shared/w_down/w$", ("model", "data")),
    # MoE experts: E over model (expert parallelism), FSDP over data
    (r"mlp/router/w$",   (None, None)),
    (r"mlp/we_up/we$",   ("model", "data", None)),      # (E, D, F)
    (r"mlp/we_gate/we$", ("model", "data", None)),
    (r"mlp/we_down/we$", ("model", None, "data")),
    # RG-LRU (width shards over model; elementwise recurrence)
    (r"rec/w_gelu/w$",   ("data", "model")),
    (r"rec/w_rec_in/w$", ("data", "model")),
    (r"rec/wa/w$",       ("data", "model")),
    (r"rec/wx/w$",       ("data", "model")),
    (r"rec/conv_w$",     (None, "model")),
    (r"rec/conv_b$",     ("model",)),
    (r"rec/ba$",         ("model",)),
    (r"rec/bx$",         ("model",)),
    (r"rec/lambda_p$",   ("model",)),
    (r"rec/w_out/w$",    ("model", "data")),
    # Mamba-2, fused form: FSDP only (see module docstring)
    (r"ssm/in_proj/w$",  ("data", None)),
    (r"ssm/out_proj/w$", (None, "data")),
    # Mamba-2, split form (§Perf): d_inner/heads shard over 'model';
    # B/C/dt projections replicate (small)
    (r"ssm/[zx]_proj/w$",   ("data", "model")),
    (r"ssm/(b|c|dt)_proj/w$", ("data", None)),
    (r"ssm/conv_w_x$",   (None, "model")),
    (r"ssm/conv_b_x$",   ("model",)),
    (r"ssm/norm_tp/scale$", ("model",)),
    (r"ssm/out_proj_tp/w$", ("model", "data")),
    (r"ssm/.*",          (None,)),
    # embeddings / head: vocab over model
    (r"embed/table$",    ("model", "data")),
    (r"head/w$",         ("data", "model")),
    (r"dec_pos$",        (None, None)),
    # packed (1-bit) inference weights: (d_out, kw) — column-parallel
    # shard d_out; row-parallel shard the packed-word (d_in) axis.
    (r"attn/w[qkv]/w_packed$", ("model", "data")),
    (r"attn/wo/w_packed$",     ("data", "model")),
    (r"xattn/w[qkv]/w_packed$", ("model", "data")),
    (r"xattn/wo/w_packed$",    ("data", "model")),
    (r"mlp/w_(up|gate)/w_packed$", ("model", "data")),
    (r"mlp/w_down/w_packed$",  ("data", "model")),
    (r"head/w_packed$",        ("model", "data")),
    (r"attn/w[qkv]/alpha$",    ("model",)),
    (r"attn/wo/alpha$",        (None,)),
    (r"mlp/w_(up|gate)/alpha$", ("model",)),
    (r"mlp/w_down/alpha$",     (None,)),
    (r"head/alpha$",           ("model",)),
    (r"w_packed$",             (None, None)),   # fallback: replicate
    (r"alpha$",                (None,)),
]


def drop_fsdp(spec: tuple) -> tuple:
    """ZeRO-degree-0 variant: replicate over 'data' (weights + opt state
    fit per-chip); keeps TP over 'model'.  Collective cost becomes one
    grad all-reduce instead of per-layer weight all-gathers — the §Perf
    train-cell optimization."""
    return tuple(None if ax == "data" else ax for ax in spec)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_specs(params: Any, mesh: Mesh, *, fsdp: bool = True,
                replicate_embed: bool = False) -> Any:
    """PartitionSpec tree for a model/optimizer param tree.

    ``fsdp=False`` replicates parameters over the 'data' axis (ZeRO-0):
    right when optimizer state fits per-chip; see ``should_fsdp``.
    ``replicate_embed=True`` replicates the embedding table: a
    vocab-sharded table turns every lookup into masked-gather +
    all-reduce of the full (B, S, D) activation — replication trades
    ~1 GB of HBM for removing that collective (§Perf cell B v2)."""

    def spec_for(path, leaf):
        if not hasattr(leaf, "ndim") or leaf.ndim == 0:
            return P()
        pstr = _path_str(path)
        if replicate_embed and re.search(r"embed/table$", pstr):
            return P()
        # find the matching rule whose spec rank matches the trailing dims
        chosen = None
        for pat, spec in _PARAM_RULES:
            if re.search(pat, pstr) and len(spec) <= leaf.ndim:
                # prefer exact-trailing-rank match (moe 3d vs dense 2d)
                if chosen is None or len(spec) > len(chosen):
                    chosen = spec
        if chosen is None:
            return P()
        if not fsdp:
            chosen = drop_fsdp(chosen)
        # prepend None for any leading (scan-stack) axes
        full = (None,) * (leaf.ndim - len(chosen)) + tuple(chosen)
        return _fit(mesh, full, leaf.shape)

    return jax.tree_util.tree_map_with_path(spec_for, params)


def should_fsdp(cfg, mesh: Mesh, *, hbm_bytes: float = 16e9,
                budget: float = 0.6) -> bool:
    """ZeRO-degree policy: keep FSDP only if replicated-over-data
    optimizer state would overflow ``budget`` of HBM.

    Per-chip bytes without FSDP = total_params/TP x (4 master + 8 adam
    + 2 bf16 + 4 grad) = 18 B/param."""
    tp = _axis_size(mesh, "model") or 1
    total = cfg.param_counts()["total"]
    per_chip = total / tp * 18.0
    return per_chip > budget * hbm_bytes


def param_shardings(params: Any, mesh: Mesh) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(params, mesh),
                        is_leaf=lambda x: isinstance(x, P))


# --------------------------------------------------------------------------
# activations / batches / caches
# --------------------------------------------------------------------------

def batch_specs(batch_like: Any, mesh: Mesh, *,
                shard_seq: bool = False) -> Any:
    """Input batch: batch dim over (pod, data); optionally the sequence
    dim instead (long-context, batch==1)."""

    def spec_for(leaf):
        if not hasattr(leaf, "ndim"):
            return P()
        if leaf.ndim == 0:
            return P()
        if shard_seq and leaf.ndim >= 2:
            return _fit(mesh, (None, DATA_AXES) + (None,) * (leaf.ndim - 2),
                        leaf.shape)
        return _fit(mesh, (DATA_AXES,) + (None,) * (leaf.ndim - 1),
                    leaf.shape)

    return jax.tree.map(spec_for, batch_like)


def cache_specs(cache: Any, mesh: Mesh, *, shard_seq: bool = False,
                kv_layout: str = "batch_heads") -> Any:
    """KV/state caches.  Layout (L, B, S, H, D) for attention K/V (leading
    scan axis), (L, B, ...) for recurrent states.

    kv_layout:
      'batch_heads' (baseline): batch over (pod, data), heads over model.
      'seq_model' (§Perf decode optimization): batch over (pod, data),
        the S axis over 'model'.  GQA head counts rarely divide the TP
        degree (kv=2..8 vs 16) so 'batch_heads' replicates attention
        across the model axis; sharding S instead always divides (32k),
        cuts the per-chip cache 16x, and GSPMD turns the softmax
        reductions into small (B, H) all-reduces — the flash-decoding
        combine, synthesized by the partitioner.
    ``shard_seq``: shard S over (pod, data) too (batch==1 long-context).
    """

    def spec_for(path, leaf):
        if not hasattr(leaf, "ndim") or leaf.ndim == 0:
            return P()
        pstr = _path_str(path)
        if re.search(r"(^|/)(k|v)$", pstr) and leaf.ndim >= 4:
            # (..., B, S, H, D) with possible leading stack axes
            lead = (None,) * (leaf.ndim - 4)
            if shard_seq:
                spec = lead + (None, DATA_AXES, "model", None)
            elif kv_layout == "seq_model":
                spec = lead + (DATA_AXES, "model", None, None)
            else:
                spec = lead + (DATA_AXES, None, "model", None)
            return _fit(mesh, spec, leaf.shape)
        if re.search(r"(k|v)_scale$", pstr) and leaf.ndim >= 3:
            # int8-KV scales: (..., B, S, H) — same layout minus head_dim
            lead = (None,) * (leaf.ndim - 3)
            if shard_seq:
                spec = lead + (None, DATA_AXES, "model")
            elif kv_layout == "seq_model":
                spec = lead + (DATA_AXES, "model", None)
            else:
                spec = lead + (DATA_AXES, None, "model")
            return _fit(mesh, spec, leaf.shape)
        # recurrent states: (..., B, ...): batch after stack axes is dim -? —
        # use: first dim that matches the batch size heuristically; simpler:
        # states replicate over model, batch over data at axis = ndim-2? Keep
        # conservative: shard nothing but the leading batch-like dim found.
        lead = (None,) * (leaf.ndim - 1)
        if leaf.ndim >= 2:
            spec = (None,) * (leaf.ndim - 2) + (DATA_AXES, None)
            # the batch dim of stacked states (L, B, ...) is axis 1
            if leaf.ndim >= 3:
                spec = (None, DATA_AXES) + (None,) * (leaf.ndim - 2)
            return _fit(mesh, spec, leaf.shape)
        return P()

    return jax.tree_util.tree_map_with_path(spec_for, cache)


def logical_activation_spec(mesh: Mesh, ndim: int, *,
                            shard_seq: bool = False) -> P:
    if shard_seq:
        return _fit(mesh, (None, DATA_AXES) + (None,) * (ndim - 2),
                    (1 << 30,) * ndim)
    return _fit(mesh, (DATA_AXES,) + (None,) * (ndim - 1), (1 << 30,) * ndim)


# --------------------------------------------------------------------------
# Packed BCNN / BMLP forward (Espresso): C_out-parallel over 'model',
# batch-parallel over 'data'
# --------------------------------------------------------------------------
#
# Each output-channel shard of a packed stage owns its own packed weight
# rows, folded BN thresholds (tau/flip), pad-correction columns, and
# pool-mask words, so the conv + BN-sign + repack (+ bit-domain pool)
# epilogue is embarrassingly parallel along C_out (XNOR-Net's
# decomposition).  The one real seam is the C_out -> packed-word boundary
# at the re-bitpack epilogue — standalone bn_sign_pack AND the fused
# dense GEMM epilogue (ops.binary_matmul_bn_sign_packed) alike: a shard
# can only emit its own 32-bit word span if its channel range is
# word-aligned, i.e. c_out % (32 * |model|) == 0.  Sharded hidden dense
# stages therefore run the per-layer fused kernel on their local rows
# (models/cnn._dense_hidden_stack); the single-launch resident stack is
# reserved for unsharded stacks, where it composes with pure data
# parallelism (every 'data' shard runs the one-launch stack locally).
# Stages that fail the test degrade to replication over 'model' (the
# same divisibility-aware fallback philosophy as `_fit`), never to a
# wrong answer.  Packed activations are batch-sharded over 'data' and
# replicated over 'model'; the only cross-device traffic is the
# word-aligned all-gather of PACKED words at sharded stage boundaries —
# on the pure data-parallel path there are no collectives at all
# (asserted on compiled HLO by distributed/verify_sharded.py).

def packed_stage_shards(c_out: int, mesh: Mesh) -> int:
    """C_out-parallel shard count for one packed stage.

    The 'model' axis size when every shard owns whole 32-bit packed
    words (``c_out % (32·|model|) == 0``), else 1 — the stage replicates
    instead of splitting a word across devices.
    """
    from repro.core.binarize import WORD_BITS
    nm = _axis_size(mesh, "model")
    if nm > 1 and c_out % (WORD_BITS * nm) == 0:
        return nm
    return 1


def bcnn_shard_plan(packed: Any, mesh: Mesh) -> dict:
    """Per-stage shard counts for a ``pack_bcnn`` tree on ``mesh``.

    The last dense layer always replicates: its int32 output feeds the
    fp output batch-norm, not a word-packing epilogue.
    """
    conv = tuple(packed_stage_shards(p["c_out"], mesh)
                 for p in packed["convs"])
    douts = [p["w_packed"].shape[0] for p in packed["denses"]]
    dense = tuple(packed_stage_shards(d, mesh) for d in douts[:-1]) + (1,)
    return {"conv": conv, "dense": dense}


def bmlp_shard_plan(packed: Any, mesh: Mesh) -> dict:
    douts = [p["w_packed"].shape[0] for p in packed["layers"]]
    layer = tuple(packed_stage_shards(d, mesh) for d in douts[:-1]) + (1,)
    return {"layer": layer}


def _is_array(leaf) -> bool:
    import numpy as np
    return isinstance(leaf, (jax.Array, np.ndarray))


def _bcnn_spec_rule(shard_plan: dict):
    """path-str + leaf -> PartitionSpec (or None for non-array statics)."""
    conv, dense = shard_plan["conv"], shard_plan["dense"]

    def rule(pstr: str, leaf) -> P | None:
        if not _is_array(leaf):
            return None
        m = re.match(r"convs/(\d+)/(w_packed|correction|rowsum)$", pstr)
        if m and conv[int(m.group(1))] > 1:
            if m.group(2) == "correction":      # (OH, OW, C_out)
                return P(None, None, "model")
            return P("model") if leaf.ndim == 1 else P("model", None)
        m = re.match(r"(folded_conv)/(\d+)/(tau|flip)$", pstr)
        if m and conv[int(m.group(2))] > 1:
            return P("model")
        m = re.match(r"pool_masks/(\d+)$", pstr)
        if m and conv[int(m.group(1))] > 1:
            return P("model")                   # (Cw,) packed-word spans
        m = re.match(r"denses/(\d+)/w_packed$", pstr)
        if m and dense[int(m.group(1))] > 1:
            return P("model", None)
        m = re.match(r"folded_dense/(\d+)/(tau|flip)$", pstr)
        if m and dense[int(m.group(1))] > 1:
            return P("model")
        return P()                              # replicate (bn_out, fallback)

    return rule


def _bmlp_spec_rule(shard_plan: dict):
    layer = shard_plan["layer"]

    def rule(pstr: str, leaf) -> P | None:
        if not _is_array(leaf):
            return None
        m = re.match(r"layers/(\d+)/(w_packed|w_rowsum)$", pstr)
        if m and layer[int(m.group(1))] > 1:
            return P("model") if leaf.ndim == 1 else P("model", None)
        m = re.match(r"folded/(\d+)/(tau|flip)$", pstr)
        if m and layer[int(m.group(1))] > 1:
            return P("model")
        return P()

    return rule


def _packed_kind(packed: Any) -> str:
    from repro.models.cnn import packed_kind
    return packed_kind(packed)


def _packed_rule(packed: Any, mesh: Mesh):
    if _packed_kind(packed) == "bcnn":
        return _bcnn_spec_rule(bcnn_shard_plan(packed, mesh))
    return _bmlp_spec_rule(bmlp_shard_plan(packed, mesh))


def _fitted_spec(mesh: Mesh, s: P, leaf) -> P:
    """`_fit`-checked, trailing-None-normalized spec for one array leaf.

    Placement (`shard_packed`), the shard_map in_specs, and the
    advertised `packed_param_specs` map ALL go through this one
    function, so a rule whose axis cannot divide the dim degrades to
    replication everywhere consistently instead of failing to lower.
    """
    fitted = tuple(_fit(mesh, tuple(s) + (None,) * (leaf.ndim - len(s)),
                        leaf.shape))
    while fitted and fitted[-1] is None:            # P(None,..) == P()
        fitted = fitted[:-1]
    return P(*fitted)


def packed_param_specs(packed: Any, mesh: Mesh) -> dict[str, P]:
    """{'/'-joined path: PartitionSpec} for every array leaf of a packed
    BCNN/BMLP tree — exactly the specs placement and shard_map use."""
    rule = _packed_rule(packed, mesh)
    out: dict[str, P] = {}

    def visit(path, leaf):
        s = rule(_path_str(path), leaf)
        if s is not None:
            out[_path_str(path)] = _fitted_spec(mesh, s, leaf)
        return leaf

    jax.tree_util.tree_map_with_path(visit, packed)
    return out


def shard_packed(packed: Any, mesh: Mesh) -> Any:
    """device_put every array leaf of a packed tree with its
    NamedSharding (one-time placement, paper C2 spirit: pack once, place
    once).  Statics (plan geometry ints, the spec dataclass) pass
    through untouched."""
    rule = _packed_rule(packed, mesh)

    def put(path, leaf):
        s = rule(_path_str(path), leaf)
        if s is None:
            return leaf
        return jax.device_put(leaf,
                              NamedSharding(mesh, _fitted_spec(mesh, s,
                                                               leaf)))

    return jax.tree_util.tree_map_with_path(put, packed)


def reshard_packed(packed: Any, mesh: Mesh | None) -> Any:
    """Move a packed tree to a DIFFERENT mesh (elastic degradation).

    Array leaves are pulled to host first — after a (simulated) device
    loss the old placements may reference devices that no longer exist,
    so re-placement must not read through them lazily inside a jit.
    ``mesh=None`` returns the host-resident tree (the checkpoint-shaped
    view); otherwise the tree is placed via :func:`shard_packed` under
    the new mesh's own divisibility plan.  Cheap by construction: the
    paper's 32x weight compression means the bytes crossing host here
    are the packed words, not fp32 weights.
    """
    import numpy as np
    host = jax.tree.map(lambda l: np.asarray(l) if _is_array(l) else l,
                        packed)
    if mesh is None:
        return host
    return shard_packed(host, mesh)


# `shard_bcnn` / `shard_bmlp`: explicit entry points (same placement,
# kind-checked).
def shard_bcnn(packed: Any, mesh: Mesh) -> Any:
    assert _packed_kind(packed) == "bcnn"
    return shard_packed(packed, mesh)


def shard_bmlp(packed: Any, mesh: Mesh) -> Any:
    assert _packed_kind(packed) == "bmlp"
    return shard_packed(packed, mesh)


def _partition_arrays(tree: Any):
    """Split a mixed pytree into (array leaves, their paths, rebuild fn).

    ``shard_map`` can only take arrays as operands; plan statics (ints,
    pad tuples, the spec dataclass) are baked back in by ``rebuild``
    inside the traced body.  One flatten produces both the operand list
    and the path strings its specs are looked up by, so the two can
    never disagree on leaf order.
    """
    leaves_p, treedef = jax.tree_util.tree_flatten_with_path(tree)
    is_arr = [_is_array(l) for _, l in leaves_p]
    arrays = [l for (_, l), a in zip(leaves_p, is_arr) if a]
    paths = [_path_str(p) for (p, _), a in zip(leaves_p, is_arr) if a]

    def rebuild(arrs):
        it = iter(arrs)
        merged = [next(it) if a else l
                  for (_, l), a in zip(leaves_p, is_arr)]
        return jax.tree_util.tree_unflatten(treedef, merged)

    return arrays, paths, rebuild


class ShardedForward:
    """Callable wrapper around the jitted shard_map'd packed forward.

    Holds the device_put params so calls are ``fwd(x)``; exposes
    ``.lower(x)`` for HLO inspection, ``.shard_plan`` for tests, and
    the serving-facing seams ``.kind`` / ``.batch_multiple`` — the
    request queue (``train.serve.PackedInferenceServer``) sizes its
    flush buckets to multiples of ``batch_multiple`` so every flush
    satisfies the shard_map batch divisibility rule.
    """

    def __init__(self, jitted, arrays, shard_plan: dict, mesh: Mesh,
                 kind: str, telemetry=None):
        from repro import telemetry as _telemetry
        self._jitted = jitted
        self._arrays = arrays
        self.shard_plan = shard_plan
        self.mesh = mesh
        self.kind = kind
        self.telemetry = (telemetry if telemetry is not None
                          else _telemetry.default())

    @property
    def batch_multiple(self) -> int:
        """Every submitted batch must be a multiple of this (the product
        of the mesh's data-parallel axis sizes)."""
        mult = 1
        for ax in DATA_AXES:
            mult *= max(1, _axis_size(self.mesh, ax))
        return mult

    def __call__(self, x):
        tr = self.telemetry.tracer
        if not tr.enabled:
            return self._jitted(self._arrays, x)
        # Traced path only: splitting dispatch from block costs a
        # block_until_ready the async-dispatch steady state must not
        # pay, so the untraced fast path above stays one call.
        with tr.span("sharded.dispatch", mesh=list(self.mesh.shape.values()),
                     kind=self.kind):
            out = self._jitted(self._arrays, x)
        with tr.span("sharded.block"):
            jax.block_until_ready(out)
        return out

    def lower(self, x):
        return self._jitted.lower(self._arrays, x)


def make_sharded_forward(packed: Any, mesh: Mesh, *,
                         backend: str = "auto",
                         dense_stack: str = "auto",
                         telemetry=None) -> ShardedForward:
    """Shard-mapped packed BCNN/BMLP forward on a ('data', 'model') mesh.

    Batch shards over 'data'; every word-divisible stage C_out-shards
    over 'model' (see :func:`packed_stage_shards`), with per-stage
    degradation to replication otherwise.  Inside the conv stack the
    only collectives are tiled all-gathers of PACKED words at sharded
    stage seams — zero collectives on the pure data-parallel path.  The
    batch must divide the 'data' axis size.  Bit-identical to the
    single-device forward (distributed/verify_sharded.py sweeps mesh
    shapes on a forced-8-device CPU platform).

    ``dense_stack`` forwards to the model: hidden dense stages that are
    NOT model-sharded run the single-launch VMEM-resident stack (the
    residency decision is pure shape math, so every shard agrees);
    model-sharded stages always run per-layer fused kernels on their
    local word-aligned rows.
    """
    from jax.experimental.shard_map import shard_map

    from repro.models import cnn as _cnn

    kind = _packed_kind(packed)
    rule = _packed_rule(packed, mesh)
    plan = (bcnn_shard_plan(packed, mesh) if kind == "bcnn"
            else bmlp_shard_plan(packed, mesh))
    placed = shard_packed(packed, mesh)
    arrays, arr_paths, rebuild = _partition_arrays(placed)
    arr_specs = [_fitted_spec(mesh, rule(p, l), l)
                 for p, l in zip(arr_paths, arrays)]

    x_ndim = 4 if kind == "bcnn" else 2
    x_spec = logical_activation_spec(mesh, x_ndim)
    out_spec = logical_activation_spec(mesh, 2)
    model_axis = "model" if _axis_size(mesh, "model") > 1 else None

    def fwd(arrs, x):
        p = rebuild(arrs)
        if kind == "bcnn":
            return _cnn.bcnn_forward_packed(
                p, x, backend=backend, model_axis=model_axis,
                conv_shards=plan["conv"], dense_shards=plan["dense"],
                dense_stack=dense_stack)
        return _cnn.bmlp_forward_packed(
            p, x, backend=backend, model_axis=model_axis,
            layer_shards=plan["layer"], dense_stack=dense_stack)

    sm = shard_map(fwd, mesh=mesh, in_specs=(arr_specs, x_spec),
                   out_specs=out_spec, check_rep=False)
    return ShardedForward(jax.jit(sm), arrays, plan, mesh, kind,
                          telemetry=telemetry)
