"""Sharding rules + divisibility-aware resolver (DESIGN.md §5).

Logical mesh axes:
* ``pod``   — outer data-parallel axis (multi-pod runs)
* ``data``  — inner data-parallel / FSDP axis
* ``model`` — tensor/expert-parallel axis

Parameter rules are matched on the *path* of each leaf in the param tree
(column-parallel projections shard d_out over 'model', row-parallel shard
d_in, experts shard E, embeddings shard vocab, FSDP shards one remaining
large dim over 'data').  The resolver drops any axis assignment whose
mesh size does not divide the dimension — small models (whisper-base)
degrade gracefully to replication instead of failing to lower.

SSM/RG-LRU internals: Mamba-2's fused in-projection interleaves five
semantic blocks on one axis; sharding it over 'model' misaligns shard and
split boundaries and GSPMD inserts reshuffles.  We shard Mamba-2 params
over 'data' (FSDP) only and keep 'model' for the (elementwise-shardable)
RG-LRU width — see EXPERIMENTS.md §Roofline notes.
"""
from __future__ import annotations

import re
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

DATA_AXES = ("pod", "data")      # batch shards over both when present


def _axis_size(mesh: Mesh, name) -> int:
    if name is None:
        return 1
    if isinstance(name, (tuple, list)):
        s = 1
        for n in name:
            s *= _axis_size(mesh, n)
        return s
    return mesh.shape[name] if name in mesh.shape else 0


def _fit(mesh: Mesh, spec: tuple, shape: tuple[int, ...]) -> P:
    """Drop axis assignments that don't divide the dim (or don't exist)."""
    out = []
    for dim, ax in zip(shape, spec):
        if ax is None:
            out.append(None)
            continue
        size = _axis_size(mesh, ax)
        if size and size > 1 and dim % size == 0:
            out.append(ax)
        elif size == 1:
            out.append(None)
        else:
            # try partial tuples: ('pod','data') -> 'data'
            if isinstance(ax, tuple) and len(ax) > 1:
                for sub in (ax[1:], ax[:1]):
                    ssize = _axis_size(mesh, sub)
                    if ssize and dim % ssize == 0:
                        out.append(sub if len(sub) > 1 else sub[0])
                        break
                else:
                    out.append(None)
            else:
                out.append(None)
    return P(*out)


# --------------------------------------------------------------------------
# parameter rules
# --------------------------------------------------------------------------

# (regex on '/'-joined path, spec builder given leaf ndim)
# dims are written for the UNSTACKED leaf; a leading scan axis (stacked
# layers) gets None prepended automatically.
_PARAM_RULES: list[tuple[str, tuple]] = [
    # attention projections (column-parallel qkv, row-parallel o)
    (r"attn/wq/w$",      ("data", "model")),
    (r"attn/wk/w$",      ("data", "model")),
    (r"attn/wv/w$",      ("data", "model")),
    (r"attn/wo/w$",      ("model", "data")),
    (r"xattn/wq/w$",     ("data", "model")),
    (r"xattn/wk/w$",     ("data", "model")),
    (r"xattn/wv/w$",     ("data", "model")),
    (r"xattn/wo/w$",     ("model", "data")),
    # dense FFN
    (r"mlp/w_up/w$",     ("data", "model")),
    (r"mlp/w_gate/w$",   ("data", "model")),
    (r"mlp/w_down/w$",   ("model", "data")),
    (r"shared/w_up/w$",  ("data", "model")),
    (r"shared/w_gate/w$", ("data", "model")),
    (r"shared/w_down/w$", ("model", "data")),
    # MoE experts: E over model (expert parallelism), FSDP over data
    (r"mlp/router/w$",   (None, None)),
    (r"mlp/we_up/we$",   ("model", "data", None)),      # (E, D, F)
    (r"mlp/we_gate/we$", ("model", "data", None)),
    (r"mlp/we_down/we$", ("model", None, "data")),
    # RG-LRU (width shards over model; elementwise recurrence)
    (r"rec/w_gelu/w$",   ("data", "model")),
    (r"rec/w_rec_in/w$", ("data", "model")),
    (r"rec/wa/w$",       ("data", "model")),
    (r"rec/wx/w$",       ("data", "model")),
    (r"rec/conv_w$",     (None, "model")),
    (r"rec/conv_b$",     ("model",)),
    (r"rec/ba$",         ("model",)),
    (r"rec/bx$",         ("model",)),
    (r"rec/lambda_p$",   ("model",)),
    (r"rec/w_out/w$",    ("model", "data")),
    # Mamba-2, fused form: FSDP only (see module docstring)
    (r"ssm/in_proj/w$",  ("data", None)),
    (r"ssm/out_proj/w$", (None, "data")),
    # Mamba-2, split form (§Perf): d_inner/heads shard over 'model';
    # B/C/dt projections replicate (small)
    (r"ssm/[zx]_proj/w$",   ("data", "model")),
    (r"ssm/(b|c|dt)_proj/w$", ("data", None)),
    (r"ssm/conv_w_x$",   (None, "model")),
    (r"ssm/conv_b_x$",   ("model",)),
    (r"ssm/norm_tp/scale$", ("model",)),
    (r"ssm/out_proj_tp/w$", ("model", "data")),
    (r"ssm/.*",          (None,)),
    # embeddings / head: vocab over model
    (r"embed/table$",    ("model", "data")),
    (r"head/w$",         ("data", "model")),
    (r"dec_pos$",        (None, None)),
    # packed (1-bit) inference weights: (d_out, kw) — column-parallel
    # shard d_out; row-parallel shard the packed-word (d_in) axis.
    (r"attn/w[qkv]/w_packed$", ("model", "data")),
    (r"attn/wo/w_packed$",     ("data", "model")),
    (r"xattn/w[qkv]/w_packed$", ("model", "data")),
    (r"xattn/wo/w_packed$",    ("data", "model")),
    (r"mlp/w_(up|gate)/w_packed$", ("model", "data")),
    (r"mlp/w_down/w_packed$",  ("data", "model")),
    (r"head/w_packed$",        ("model", "data")),
    (r"attn/w[qkv]/alpha$",    ("model",)),
    (r"attn/wo/alpha$",        (None,)),
    (r"mlp/w_(up|gate)/alpha$", ("model",)),
    (r"mlp/w_down/alpha$",     (None,)),
    (r"head/alpha$",           ("model",)),
    (r"w_packed$",             (None, None)),   # fallback: replicate
    (r"alpha$",                (None,)),
]


def drop_fsdp(spec: tuple) -> tuple:
    """ZeRO-degree-0 variant: replicate over 'data' (weights + opt state
    fit per-chip); keeps TP over 'model'.  Collective cost becomes one
    grad all-reduce instead of per-layer weight all-gathers — the §Perf
    train-cell optimization."""
    return tuple(None if ax == "data" else ax for ax in spec)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_specs(params: Any, mesh: Mesh, *, fsdp: bool = True,
                replicate_embed: bool = False) -> Any:
    """PartitionSpec tree for a model/optimizer param tree.

    ``fsdp=False`` replicates parameters over the 'data' axis (ZeRO-0):
    right when optimizer state fits per-chip; see ``should_fsdp``.
    ``replicate_embed=True`` replicates the embedding table: a
    vocab-sharded table turns every lookup into masked-gather +
    all-reduce of the full (B, S, D) activation — replication trades
    ~1 GB of HBM for removing that collective (§Perf cell B v2)."""

    def spec_for(path, leaf):
        if not hasattr(leaf, "ndim") or leaf.ndim == 0:
            return P()
        pstr = _path_str(path)
        if replicate_embed and re.search(r"embed/table$", pstr):
            return P()
        # find the matching rule whose spec rank matches the trailing dims
        chosen = None
        for pat, spec in _PARAM_RULES:
            if re.search(pat, pstr) and len(spec) <= leaf.ndim:
                # prefer exact-trailing-rank match (moe 3d vs dense 2d)
                if chosen is None or len(spec) > len(chosen):
                    chosen = spec
        if chosen is None:
            return P()
        if not fsdp:
            chosen = drop_fsdp(chosen)
        # prepend None for any leading (scan-stack) axes
        full = (None,) * (leaf.ndim - len(chosen)) + tuple(chosen)
        return _fit(mesh, full, leaf.shape)

    return jax.tree_util.tree_map_with_path(spec_for, params)


def should_fsdp(cfg, mesh: Mesh, *, hbm_bytes: float = 16e9,
                budget: float = 0.6) -> bool:
    """ZeRO-degree policy: keep FSDP only if replicated-over-data
    optimizer state would overflow ``budget`` of HBM.

    Per-chip bytes without FSDP = total_params/TP x (4 master + 8 adam
    + 2 bf16 + 4 grad) = 18 B/param."""
    tp = _axis_size(mesh, "model") or 1
    total = cfg.param_counts()["total"]
    per_chip = total / tp * 18.0
    return per_chip > budget * hbm_bytes


def param_shardings(params: Any, mesh: Mesh) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(params, mesh),
                        is_leaf=lambda x: isinstance(x, P))


# --------------------------------------------------------------------------
# activations / batches / caches
# --------------------------------------------------------------------------

def batch_specs(batch_like: Any, mesh: Mesh, *,
                shard_seq: bool = False) -> Any:
    """Input batch: batch dim over (pod, data); optionally the sequence
    dim instead (long-context, batch==1)."""

    def spec_for(leaf):
        if not hasattr(leaf, "ndim"):
            return P()
        if leaf.ndim == 0:
            return P()
        if shard_seq and leaf.ndim >= 2:
            return _fit(mesh, (None, DATA_AXES) + (None,) * (leaf.ndim - 2),
                        leaf.shape)
        return _fit(mesh, (DATA_AXES,) + (None,) * (leaf.ndim - 1),
                    leaf.shape)

    return jax.tree.map(spec_for, batch_like)


def cache_specs(cache: Any, mesh: Mesh, *, shard_seq: bool = False,
                kv_layout: str = "batch_heads") -> Any:
    """KV/state caches.  Layout (L, B, S, H, D) for attention K/V (leading
    scan axis), (L, B, ...) for recurrent states.

    kv_layout:
      'batch_heads' (baseline): batch over (pod, data), heads over model.
      'seq_model' (§Perf decode optimization): batch over (pod, data),
        the S axis over 'model'.  GQA head counts rarely divide the TP
        degree (kv=2..8 vs 16) so 'batch_heads' replicates attention
        across the model axis; sharding S instead always divides (32k),
        cuts the per-chip cache 16x, and GSPMD turns the softmax
        reductions into small (B, H) all-reduces — the flash-decoding
        combine, synthesized by the partitioner.
    ``shard_seq``: shard S over (pod, data) too (batch==1 long-context).
    """

    def spec_for(path, leaf):
        if not hasattr(leaf, "ndim") or leaf.ndim == 0:
            return P()
        pstr = _path_str(path)
        if re.search(r"(^|/)(k|v)$", pstr) and leaf.ndim >= 4:
            # (..., B, S, H, D) with possible leading stack axes
            lead = (None,) * (leaf.ndim - 4)
            if shard_seq:
                spec = lead + (None, DATA_AXES, "model", None)
            elif kv_layout == "seq_model":
                spec = lead + (DATA_AXES, "model", None, None)
            else:
                spec = lead + (DATA_AXES, None, "model", None)
            return _fit(mesh, spec, leaf.shape)
        if re.search(r"(k|v)_scale$", pstr) and leaf.ndim >= 3:
            # int8-KV scales: (..., B, S, H) — same layout minus head_dim
            lead = (None,) * (leaf.ndim - 3)
            if shard_seq:
                spec = lead + (None, DATA_AXES, "model")
            elif kv_layout == "seq_model":
                spec = lead + (DATA_AXES, "model", None)
            else:
                spec = lead + (DATA_AXES, None, "model")
            return _fit(mesh, spec, leaf.shape)
        # recurrent states: (..., B, ...): batch after stack axes is dim -? —
        # use: first dim that matches the batch size heuristically; simpler:
        # states replicate over model, batch over data at axis = ndim-2? Keep
        # conservative: shard nothing but the leading batch-like dim found.
        lead = (None,) * (leaf.ndim - 1)
        if leaf.ndim >= 2:
            spec = (None,) * (leaf.ndim - 2) + (DATA_AXES, None)
            # the batch dim of stacked states (L, B, ...) is axis 1
            if leaf.ndim >= 3:
                spec = (None, DATA_AXES) + (None,) * (leaf.ndim - 2)
            return _fit(mesh, spec, leaf.shape)
        return P()

    return jax.tree_util.tree_map_with_path(spec_for, cache)


def logical_activation_spec(mesh: Mesh, ndim: int, *,
                            shard_seq: bool = False) -> P:
    if shard_seq:
        return _fit(mesh, (None, DATA_AXES) + (None,) * (ndim - 2),
                    (1 << 30,) * ndim)
    return _fit(mesh, (DATA_AXES,) + (None,) * (ndim - 1), (1 << 30,) * ndim)
