"""Pallas TPU kernels: the bit-packed dense GEMM megakernel suite
(paper §4.2, §5.2, §6.2 — C1/C2/C7).

The dense analogue of the conv subsystem (``binary_conv.py``), built
around  out[m, n] = K − 2·popcount(XOR(a[m, :], b[n, :]))  over packed
uint32 operands:

* **Vectorized contraction** — each loop step contracts
  ``words_per_step`` packed words at once: one (bm, bn, ws)
  popcount-of-XOR broadcast and a word-axis reduce, instead of the old
  one-(bm, bn)-tile-per-word scheme (128 sequential steps per lane-wide
  K block -> 128/ws).  The knob is validated like ``block_oh``/``block_n``
  (divisors of the 128-lane group; invalid values raise) and the output
  is invariant to it.
* **Fused BN-sign-repack epilogue** (:func:`binary_matmul_bn_sign_packed`)
  — the kernel flush thresholds the int32 accumulator against the folded
  BN (``fold_bn_sign``) and re-bitpacks along N, so hidden dense layers
  emit packed uint32 directly and the (M, N) int32 activation never
  leaves VMEM.  ``block_n`` must land on 32-bit pack seams (the lane
  check subsumes it, asserted like the conv epilogue).
* **Single-launch hidden stack** (:func:`binary_dense_stack_packed`) —
  when every hidden layer's packed weights + folded thresholds fit a
  VMEM budget (:func:`dense_stack_fits_vmem`), the whole stack runs as
  ONE ``pallas_call``: grid over M tiles only, every weight BlockSpec
  pinned to block (0, 0) so the weights stay resident across tiles, and
  an in-kernel stage loop chains GEMM -> threshold -> repack entirely in
  VMEM.  The dense analogue of the conv subsystem's single-launch
  bit-plane kernel.
* **GEMV / serving specialization** (paper §6.2: matrix-vector swap at
  batch 1) — for M ≤ 8 the M tile collapses to the sublane minimum and
  the grid becomes N-major 1-D: the packed activation block is pinned
  resident in VMEM, weight row blocks stream past it, and the
  contraction completes per program (no cross-step accumulator).

HBM→VMEM staging via ``BlockSpec`` tiles is the TPU analogue of the
paper's shared-memory tiling (C7); 32-bit packing words match the TPU
VPU lane width (DESIGN.md §2).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.analysis import vmem
from repro.core import binarize as B
from repro.kernels.fused_epilogue import (bn_sign_bits_to_words,
                                          check_block_lanes,
                                          check_block_sublanes,
                                          check_words_per_step,
                                          pad_bn_params)

# Minimum int32 tile granularity on TPU: (8 sublanes, 128 lanes).
_SUBLANE = 8
_LANE = 128

# Packed words contracted per vectorized step (the (bm, bn, ws) popcount
# broadcast).  8 words = 256 logical K per step keeps the broadcast under
# ~512 KB at the default (128, 128) tile.
DEFAULT_WORDS_PER_STEP = 8

# GEMV path bound: both operands hold their whole packed-K extent in one
# block, so cap it (4096 words = 128K logical K; the streamed weight
# block is then block_n * 16 KB).
_GEMV_MAX_KW = 4096

# Single-launch stack defaults: serving-shaped M tiles (the resident
# stack is a decode/serve feature — weights dominate VMEM, activations
# ride in sublane-minimum tiles) and a budget that leaves headroom for
# Mosaic's double buffering under the ~16 MB/core VMEM.
STACK_BLOCK_M = _SUBLANE
STACK_VMEM_BUDGET = 8 * 2**20


# ---------------------------------------------------------------------------
# Shared contraction body
# ---------------------------------------------------------------------------

def _mismatch_counts(a: jax.Array, b: jax.Array, *,
                     words_per_step: int) -> jax.Array:
    """Vectorized XNOR-popcount contraction of two packed blocks.

    ``a``: (bm, kw) uint32, ``b``: (bn, kw) uint32.  Returns the (bm, bn)
    int32 total mismatch count.  Each loop step slices ``ws`` packed
    words from both operands and reduces one (bm, bn, ws)
    popcount-of-XOR broadcast over the word axis — ws lane-tiles of
    popcount work per step instead of the old single (bm, 1)×(1, bn)
    word op.  A static tail handles kw not divisible by ws (ragged stack
    stages); the result is invariant to ``words_per_step``.
    """
    bm, kw = a.shape
    bn = b.shape[0]
    ws = min(words_per_step, kw)
    steps, rem = divmod(kw, ws)

    def chunk(a_c, b_c):
        mism = jax.lax.population_count(a_c[:, None, :] ^ b_c[None, :, :])
        return mism.sum(axis=-1).astype(jnp.int32)

    def body(i, acc):
        a_c = jax.lax.dynamic_slice_in_dim(a, i * ws, ws, axis=1)
        b_c = jax.lax.dynamic_slice_in_dim(b, i * ws, ws, axis=1)
        return acc + chunk(a_c, b_c)

    acc = jax.lax.fori_loop(0, steps, body,
                            jnp.zeros((bm, bn), jnp.int32))
    if rem:
        acc = acc + chunk(jax.lax.slice_in_dim(a, steps * ws, kw, axis=1),
                          jax.lax.slice_in_dim(b, steps * ws, kw, axis=1))
    return acc


# ---------------------------------------------------------------------------
# Kernels
# ---------------------------------------------------------------------------

def _gemm_kernel(a_ref, b_ref, o_ref, acc_ref, *, k_true: int,
                 n_k_blocks: int, words_per_step: int):
    """One (bm, bn) output tile; grid dim 2 walks the packed-K blocks."""
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += _mismatch_counts(a_ref[...], b_ref[...],
                                     words_per_step=words_per_step)

    @pl.when(kb == n_k_blocks - 1)
    def _flush():
        o_ref[...] = jnp.int32(k_true) - 2 * acc_ref[...]


def _gemm_bn_sign_kernel(a_ref, b_ref, tau_ref, flip_ref, o_ref, acc_ref, *,
                         k_true: int, n_k_blocks: int, words_per_step: int):
    """Fused variant: the flush thresholds + re-bitpacks along N, so the
    int32 activation never leaves the accumulator scratch."""
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += _mismatch_counts(a_ref[...], b_ref[...],
                                     words_per_step=words_per_step)

    @pl.when(kb == n_k_blocks - 1)
    def _flush():
        y = jnp.int32(k_true) - 2 * acc_ref[...]
        o_ref[...] = bn_sign_bits_to_words(y, tau_ref[...], flip_ref[...])


def _gemv_kernel(a_ref, b_ref, o_ref, *, k_true: int, words_per_step: int):
    """N-major serving path: full-K contraction per program, A resident."""
    o_ref[...] = jnp.int32(k_true) - 2 * _mismatch_counts(
        a_ref[...], b_ref[...], words_per_step=words_per_step)


def _gemv_bn_sign_kernel(a_ref, b_ref, tau_ref, flip_ref, o_ref, *,
                         k_true: int, words_per_step: int):
    y = jnp.int32(k_true) - 2 * _mismatch_counts(
        a_ref[...], b_ref[...], words_per_step=words_per_step)
    o_ref[...] = bn_sign_bits_to_words(y, tau_ref[...], flip_ref[...])


def _dense_stack_kernel(*refs, k_trues: tuple[int, ...],
                        words_per_step: int):
    """In-kernel stage loop over the VMEM-resident hidden-layer weights.

    ``refs`` = (x, [w, tau, flip] per stage, out).  Each stage runs the
    full contraction for this M tile (the stack grid has no N or K
    blocking — residency is the point), thresholds against its folded
    BN, and re-bitpacks; the packed words feed the next stage without
    ever leaving VMEM.  Stage widths are lane-padded by the host wrapper
    so every repack lands on 32-bit word seams; padded channels carry
    tau=+inf / flip=+1 and pack as 0-bits, matching the zero-bit-tail
    convention of the next stage's zero-padded weight words.
    """
    x_ref, o_ref = refs[0], refs[-1]
    h = x_ref[...]
    for s in range(len(k_trues)):
        w_ref, tau_ref, flip_ref = refs[1 + 3 * s:4 + 3 * s]
        mism = _mismatch_counts(h, w_ref[...],
                                words_per_step=words_per_step)
        y = jnp.int32(k_trues[s]) - 2 * mism
        h = bn_sign_bits_to_words(y, tau_ref[...], flip_ref[...])
    o_ref[...] = h


# ---------------------------------------------------------------------------
# Host-side wrappers
# ---------------------------------------------------------------------------

def _resolve_blocks(m: int, n: int, kw: int, block_m: int, block_n: int,
                    block_kw: int, words_per_step: int):
    """Validate the GEMM knobs (raising, like the conv grid knobs) and
    trim over-padding.  M ≤ 8 collapses the M tile to the sublane
    minimum — the GEMV specialization's entry condition."""
    check_block_sublanes("block_m", block_m)
    check_block_lanes("block_n", block_n)
    check_block_lanes("block_kw", block_kw)
    check_words_per_step("words_per_step", words_per_step)
    if m <= _SUBLANE:
        block_m = _SUBLANE
    block_m = min(block_m, _ceil_mult(m, _SUBLANE))
    block_n = min(block_n, _ceil_mult(n, _LANE))
    block_kw = min(block_kw, _ceil_mult(kw, _LANE))
    return block_m, block_n, block_kw


def dispatch_batch(m: int, kw_words: int) -> str:
    """The GEMV-vs-GEMM routing rule — the one seam every dense caller
    (the GEMM wrappers here, ``ops.dispatch_batch``, the serving layer)
    shares, so the batching queue and the kernels can never disagree on
    which grid a flush lowers to.

    ``m`` is the batch (GEMM M) and ``kw_words`` the packed-K width in
    uint32 words.  Returns ``'gemv'`` when the M tile collapses to the
    8-sublane minimum AND the lane-padded packed K fits the resident
    activation block (``kw_words`` ≤ 4096 words = 128K logical K) —
    the N-major serving grid; ``'gemm'`` otherwise — the (M, N, K)
    blocked grid.  Idempotent under lane padding, so callers may pass
    either the logical or the padded word count.
    """
    kwp = _ceil_mult(kw_words, _LANE)
    return "gemv" if (m <= _SUBLANE and kwp <= _GEMV_MAX_KW) else "gemm"


@functools.partial(jax.jit, static_argnames=("k_true", "block_m", "block_n",
                                             "block_kw", "words_per_step",
                                             "interpret"))
def binary_matmul_packed(a_packed: jax.Array, b_packed: jax.Array, *,
                         k_true: int, block_m: int = 128, block_n: int = 128,
                         block_kw: int = 128,
                         words_per_step: int = DEFAULT_WORDS_PER_STEP,
                         interpret: bool = False) -> jax.Array:
    """Packed binary GEMM via Pallas.

    ``a_packed``: (M, Kw) uint32, ``b_packed``: (N, Kw) uint32 (pre-packed
    weights — packing happens once at load time, paper C2).  ``k_true`` is
    the *logical* K before packing/padding.  Returns (M, N) int32.

    Block knobs must honor TPU granularity (bm: multiples of 8, bn/bkw:
    multiples of 128; invalid values raise) and are trimmed down to the
    padded operand.  Zero-padded words XOR to zero and contribute no
    mismatches, so padding is exact (``core.binarize.pack_bits``).
    ``words_per_step`` packed words are contracted per loop step; the
    output is invariant to it.  M ≤ 8 with a VMEM-sized K takes the
    N-major GEMV grid (paper §6.2).
    """
    m, kw = a_packed.shape
    n, kw_b = b_packed.shape
    assert kw == kw_b, (a_packed.shape, b_packed.shape)
    block_m, block_n, block_kw = _resolve_blocks(
        m, n, kw, block_m, block_n, block_kw, words_per_step)

    a_p = B.pad_to_multiple(B.pad_to_multiple(a_packed, block_m, 0),
                            block_kw, 1)
    b_p = B.pad_to_multiple(B.pad_to_multiple(b_packed, block_n, 0),
                            block_kw, 1)
    mp, kwp = a_p.shape
    np_, _ = b_p.shape

    if dispatch_batch(m, kwp) == "gemv":
        kernel = functools.partial(_gemv_kernel, k_true=k_true,
                                   words_per_step=words_per_step)
        out = pl.pallas_call(
            kernel,
            grid=(np_ // block_n,),
            in_specs=[
                pl.BlockSpec((mp, kwp), lambda j: (0, 0)),
                pl.BlockSpec((block_n, kwp), lambda j: (j, 0)),
            ],
            out_specs=pl.BlockSpec((mp, block_n), lambda j: (0, j)),
            out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.int32),
            interpret=interpret,
        )(a_p, b_p)
        return out[:m, :n]

    grid = (mp // block_m, np_ // block_n, kwp // block_kw)
    kernel = functools.partial(_gemm_kernel, k_true=k_true,
                               n_k_blocks=grid[2],
                               words_per_step=words_per_step)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_kw), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_n, block_kw), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.int32),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.int32)],
        interpret=interpret,
    )(a_p, b_p)
    return out[:m, :n]


@functools.partial(jax.jit, static_argnames=("k_true", "block_m", "block_n",
                                             "block_kw", "words_per_step",
                                             "interpret"))
def binary_matmul_bn_sign_packed(a_packed: jax.Array, b_packed: jax.Array,
                                 tau: jax.Array, flip: jax.Array, *,
                                 k_true: int, block_m: int = 128,
                                 block_n: int = 128, block_kw: int = 128,
                                 words_per_step: int = DEFAULT_WORDS_PER_STEP,
                                 interpret: bool = False) -> jax.Array:
    """Fused packed GEMM + BN-sign-fold + re-bitpack; packed uint32 output.

    Same contraction (and the same GEMV specialization) as
    :func:`binary_matmul_packed`, but the kernel flush thresholds the
    int32 accumulator against the folded BN (``tau``/``flip`` per output
    channel) and packs the resulting ±1 bits along N — the hidden-layer
    activation leaves the kernel already packed for the next GEMM.
    Returns (M, ceil(N/32)) uint32, bit-identical to
    ``pack_bits(apply_bn_sign_folded(gemm_out))``.  ``block_n`` is a
    multiple of 128 (validated), which lands every output block on a
    32-bit pack seam — asserted like the conv epilogue.
    """
    m, kw = a_packed.shape
    n, kw_b = b_packed.shape
    assert kw == kw_b, (a_packed.shape, b_packed.shape)
    block_m, block_n, block_kw = _resolve_blocks(
        m, n, kw, block_m, block_n, block_kw, words_per_step)
    assert block_n % B.WORD_BITS == 0

    a_p = B.pad_to_multiple(B.pad_to_multiple(a_packed, block_m, 0),
                            block_kw, 1)
    b_p = B.pad_to_multiple(B.pad_to_multiple(b_packed, block_n, 0),
                            block_kw, 1)
    tau_p, flip_p = pad_bn_params(tau, flip, block_n)
    mp, kwp = a_p.shape
    np_, _ = b_p.shape
    bnw = block_n // B.WORD_BITS
    cw_out = B.packed_width(n)

    if dispatch_batch(m, kwp) == "gemv":
        kernel = functools.partial(_gemv_bn_sign_kernel, k_true=k_true,
                                   words_per_step=words_per_step)
        out = pl.pallas_call(
            kernel,
            grid=(np_ // block_n,),
            in_specs=[
                pl.BlockSpec((mp, kwp), lambda j: (0, 0)),
                pl.BlockSpec((block_n, kwp), lambda j: (j, 0)),
                pl.BlockSpec((1, block_n), lambda j: (0, j)),
                pl.BlockSpec((1, block_n), lambda j: (0, j)),
            ],
            out_specs=pl.BlockSpec((mp, bnw), lambda j: (0, j)),
            out_shape=jax.ShapeDtypeStruct((mp, np_ // B.WORD_BITS),
                                           jnp.uint32),
            interpret=interpret,
        )(a_p, b_p, tau_p, flip_p)
        return out[:m, :cw_out]

    grid = (mp // block_m, np_ // block_n, kwp // block_kw)
    kernel = functools.partial(_gemm_bn_sign_kernel, k_true=k_true,
                               n_k_blocks=grid[2],
                               words_per_step=words_per_step)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_kw), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_n, block_kw), lambda i, j, k: (j, k)),
            pl.BlockSpec((1, block_n), lambda i, j, k: (0, j)),
            pl.BlockSpec((1, block_n), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, bnw), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_ // B.WORD_BITS), jnp.uint32),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.int32)],
        interpret=interpret,
    )(a_p, b_p, tau_p, flip_p)
    return out[:m, :cw_out]


# ---------------------------------------------------------------------------
# Single-launch hidden stack
# ---------------------------------------------------------------------------

def dense_stack_vmem_bytes(weights: list, *,
                           block_m: int = STACK_BLOCK_M,
                           words_per_step: int = DEFAULT_WORDS_PER_STEP
                           ) -> int:
    """Upper-bound VMEM residency of :func:`binary_dense_stack_packed`.

    Resident terms: every stage's lane-padded weight block + folded
    tau/flip rows + the activation M tile.  Transient terms (the largest
    single stage): the (block_m, n_pad, ws) popcount broadcast, the
    int32 pre-threshold tile, and the repacked words.

    The arithmetic lives in the shared static VMEM estimator
    (``analysis.vmem.dense_stack_estimate`` — the same cost model the
    ops preflight and the autotuner consume); this wrapper keeps the
    historical array-based signature.  The GEMV-vs-stack crossover is
    regression-pinned in tests/test_analysis.py.
    """
    return vmem.dense_stack_estimate(
        [tuple(w.shape) for w in weights],
        block_m=block_m, words_per_step=words_per_step).total


def dense_stack_fits_vmem(weights: list, *, budget: int | None = None,
                          block_m: int = STACK_BLOCK_M,
                          words_per_step: int = DEFAULT_WORDS_PER_STEP
                          ) -> bool:
    """Residency decision for the single-launch stack (pure shape math —
    identical on every shard, so sharded callers never diverge)."""
    budget = STACK_VMEM_BUDGET if budget is None else budget
    return dense_stack_vmem_bytes(
        weights, block_m=block_m,
        words_per_step=words_per_step) <= budget


@functools.partial(jax.jit, static_argnames=("k_trues", "block_m",
                                             "words_per_step", "interpret"))
def binary_dense_stack_packed(x_packed: jax.Array, weights: list,
                              taus: list, flips: list, *,
                              k_trues: tuple[int, ...],
                              block_m: int = STACK_BLOCK_M,
                              words_per_step: int = DEFAULT_WORDS_PER_STEP,
                              interpret: bool = False) -> jax.Array:
    """The whole hidden dense stack in ONE ``pallas_call``.

    ``x_packed``: (M, Kw₀) packed input activation; stage ``s`` applies
    weights ``(N_s, Kw_s)`` then the folded BN threshold ``taus[s]`` /
    ``flips[s]`` and re-bitpacks.  Returns (M, ceil(N_last/32)) uint32 —
    bit-identical to chaining ``binary_matmul_bn_sign_packed`` per layer
    (and to GEMM -> ``bn_sign_pack``), property-tested.

    Grid: (M tiles,) only.  Every weight/tau/flip BlockSpec is pinned to
    block (0, 0), so Pallas holds ONE DMA of the full stack resident in
    VMEM across all M tiles while the x/out tiles stream — callers gate
    on :func:`dense_stack_fits_vmem` and fall back to per-layer fused
    launches when the stack doesn't fit.  Stage widths are lane-padded;
    a stage's padded channels pack as 0-bits (tau=+inf, flip=+1) and the
    next stage's weights are zero-word-padded to match, so padding is
    exact end-to-end.
    """
    m, kw0 = x_packed.shape
    n_stages = len(weights)
    assert n_stages == len(taus) == len(flips) == len(k_trues) >= 1
    assert weights[0].shape[1] == kw0, (weights[0].shape, x_packed.shape)
    check_block_sublanes("block_m", block_m)
    check_words_per_step("words_per_step", words_per_step)
    block_m = min(block_m, _ceil_mult(m, _SUBLANE))

    x_p = B.pad_to_multiple(x_packed, block_m, 0)
    mp = x_p.shape[0]
    operands = [x_p]
    in_specs = [pl.BlockSpec((block_m, kw0), lambda i: (i, 0))]
    prev_words = kw0
    for s in range(n_stages):
        w = weights[s]
        n_s, kw_s = w.shape
        assert kw_s <= prev_words, (s, w.shape, prev_words)
        w_p = B.pad_to_multiple(w, prev_words, 1)        # zero word tails
        n_pad = _ceil_mult(n_s, _LANE)
        w_p = B.pad_to_multiple(w_p, n_pad, 0)
        tau_p, flip_p = pad_bn_params(taus[s], flips[s], n_pad)
        operands += [w_p, tau_p, flip_p]
        in_specs += [
            pl.BlockSpec((n_pad, prev_words), lambda i: (0, 0)),
            pl.BlockSpec((1, n_pad), lambda i: (0, 0)),
            pl.BlockSpec((1, n_pad), lambda i: (0, 0)),
        ]
        prev_words = n_pad // B.WORD_BITS

    kernel = functools.partial(_dense_stack_kernel, k_trues=k_trues,
                               words_per_step=words_per_step)
    out = pl.pallas_call(
        kernel,
        grid=(mp // block_m,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_m, prev_words), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((mp, prev_words), jnp.uint32),
        interpret=interpret,
    )(*operands)
    return out[:m, :B.packed_width(weights[-1].shape[0])]


def _ceil_mult(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m
