"""Pallas TPU kernel: bit-packed binary GEMM (paper §4.2 + §5.2, C1/C7).

Computes  out[m, n] = K - 2 * popcount(XOR(a[m, :], b[n, :]))  over packed
uint32 operands — the XNOR-popcount dot-product of Espresso, adapted to TPU:

* 32-bit packing words (TPU VPU lanes are 32-bit; DESIGN.md §2),
* HBM→VMEM staging via ``BlockSpec`` tiles — the TPU analogue of the
  paper's shared-memory tiling (C7),
* grid (M/bm, N/bn, K/bk) with an int32 VMEM accumulator, initialized at
  k==0 and flushed at k==last (the paper's register-blocked accumulation
  maps onto Mosaic's vector-register allocation),
* a GEMV-shaped specialization for small M (paper §6.2: matrix-vector swap
  at batch 1) — the M tile collapses to the 8-sublane minimum.

The contraction loop runs per-word over the packed K dimension so each
step is one full (bm, bn) VPU op — mismatch counts accumulate in int32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import binarize as B

# Minimum int32 tile granularity on TPU: (8 sublanes, 128 lanes).
_SUBLANE = 8
_LANE = 128


def _binary_matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, k_true: int,
                          n_k_blocks: int, block_kw: int):
    """One (bm, bn) output tile; grid dim 2 walks the packed-K blocks."""
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...]          # (bm, block_kw) uint32
    b = b_ref[...]          # (bn, block_kw) uint32

    def body(i, acc):
        aw = jax.lax.dynamic_slice_in_dim(a, i, 1, axis=1)   # (bm, 1)
        bw = jax.lax.dynamic_slice_in_dim(b, i, 1, axis=1)   # (bn, 1)
        # (bm, bn) mismatch counts for packed word i — one full VPU tile op.
        mism = jax.lax.population_count(aw ^ bw.reshape(1, -1))
        return acc + mism.astype(jnp.int32)

    acc_ref[...] = jax.lax.fori_loop(0, block_kw, body, acc_ref[...])

    @pl.when(kb == n_k_blocks - 1)
    def _flush():
        o_ref[...] = jnp.int32(k_true) - 2 * acc_ref[...]


@functools.partial(jax.jit, static_argnames=("k_true", "block_m", "block_n",
                                             "block_kw", "interpret"))
def binary_matmul_packed(a_packed: jax.Array, b_packed: jax.Array, *,
                         k_true: int, block_m: int = 128, block_n: int = 128,
                         block_kw: int = 128,
                         interpret: bool = False) -> jax.Array:
    """Packed binary GEMM via Pallas.

    ``a_packed``: (M, Kw) uint32, ``b_packed``: (N, Kw) uint32 (pre-packed
    weights — packing happens once at load time, paper C2).  ``k_true`` is
    the *logical* K before packing/padding.  Returns (M, N) int32.

    Tile sizes are clamped/padded to TPU granularity: bm to 8 sublanes, bn
    to 128 lanes, block_kw to 128 lanes of the packed operand.  Zero-padded
    words XOR to zero and contribute no mismatches, so padding is exact
    (see ``core.binarize.pack_bits``).
    """
    m, kw = a_packed.shape
    n, kw_b = b_packed.shape
    assert kw == kw_b, (a_packed.shape, b_packed.shape)

    # GEMV specialization (paper §6.2): collapse the M tile for tiny batch.
    if m <= _SUBLANE:
        block_m = _SUBLANE
    block_m = max(_SUBLANE, min(block_m, _ceil_mult(m, _SUBLANE)))
    block_n = max(_LANE, min(block_n, _ceil_mult(n, _LANE)))
    block_kw = max(_LANE, min(block_kw, _ceil_mult(kw, _LANE)))

    a_p = B.pad_to_multiple(B.pad_to_multiple(a_packed, block_m, 0),
                            block_kw, 1)
    b_p = B.pad_to_multiple(B.pad_to_multiple(b_packed, block_n, 0),
                            block_kw, 1)
    mp, kwp = a_p.shape
    np_, _ = b_p.shape
    grid = (mp // block_m, np_ // block_n, kwp // block_kw)

    kernel = functools.partial(_binary_matmul_kernel, k_true=k_true,
                               n_k_blocks=grid[2], block_kw=block_kw)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_kw), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_n, block_kw), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.int32),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.int32)],
        interpret=interpret,
    )(a_p, b_p)
    return out[:m, :n]


def _ceil_mult(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m
