"""Pallas TPU kernel: fused bit-packed binary 2-D convolution (paper C5/C6).

The paper's headline claim is *dedicated convolutional layers for BCNNs*
that keep data bit-packed end-to-end.  The previous packed conv path did
im2col in plain jnp **outside** any kernel — materializing the full
(B·H'·W', KH·KW·Cw) patch matrix in HBM — then ran the packed GEMM over
it.  This kernel performs im2col **inside** the kernel:

* the channel-packed input image lives in VMEM ((Hp, Wp, Cw) uint32,
  channels packed 32/word, paper C3 "free lift" layout),
* each program slices its M tile's input slab from the VMEM-resident
  image with ``pl.ds`` (rows ``m·block_oh·stride`` onward), then for each
  of the KH·KW taps takes a strided in-VMEM slice of the slab (the
  im2col gather — never written back to HBM),
* XNOR-popcount accumulates word-by-word into an int32 accumulator
  (one full (block_m, bn) VPU op per packed word, same scheme as
  ``binary_matmul``),
* the epilogue folds the paper's pad-as-(−1) correction matrix (C5), and
  optionally the BN-sign threshold + re-bitpack (``fused_epilogue``), so
  the activation leaves the kernel already packed for the next layer.

Grid: ``(batch, M tiles of OH·OW, C_out blocks)``.  The M dimension is
tiled by output *rows* — an M tile is ``block_oh`` rows = ``block_oh·OW``
flattened output pixels — so each tile's input slab is a contiguous row
band of the image and the contraction is complete per program (no
cross-step scratch accumulator).  The image BlockSpec depends only on
the batch index, so Pallas holds one image DMA resident in VMEM across
all (m, j) steps of a batch element while the pipeline emitter
double-buffers the streaming blocks (weights, correction, output tiles)
— and prefetches the *next* batch element's image DMA under the current
batch's compute.

The first-layer fixed-precision conv (paper C4) is a third kernel,
:func:`bitplane_conv2d_packed`: the 8 bit-plane images ride along in one
VMEM block and an in-kernel plane loop reuses the resident image across
planes, folding the ``2^i`` plane weighting and the rowsum form of the
pad correction into the epilogue — one kernel launch where the model
previously issued 8 sequential plane convs.

Supported: arbitrary integer stride (paper evaluates 1 and 2), SAME and
VALID padding; spatial padding is staged as all-zero words (bit 0 == −1,
the paper's convention) and corrected exactly in the epilogue.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import binarize as B
from repro.kernels.fused_epilogue import (bn_sign_bits_to_words,
                                          check_block_lanes, pad_bn_params)

# Minimum tile granularity on TPU: (8 sublanes, 128 lanes).
_LANE = 128

# Default M-tile budget: ~this many output pixels per tile.  Small images
# fit in one tile (the pre-tiling behaviour); serving-sized spatial dims
# stream in row bands so the output/correction tiles stay VMEM-friendly.
_DEFAULT_TILE_M = 1024


# ---------------------------------------------------------------------------
# Conv plan: geometry + one-time weight packing (paper C2/C3/C5)
# ---------------------------------------------------------------------------

def conv_geometry(input_hw: tuple[int, int], kh: int, kw: int, stride: int,
                  padding: str) -> tuple[tuple[int, int], tuple]:
    """Output spatial size and ((top, bottom), (left, right)) pads.

    Matches XLA's SAME/VALID conventions (extra pad goes low-index-last,
    i.e. bottom/right), so the packed path lines up pixel-for-pixel with
    ``jax.lax.conv_general_dilated``.
    """
    h, w = input_hw
    if padding == "SAME":
        out_h = -(-h // stride)
        out_w = -(-w // stride)
        pad_h = max((out_h - 1) * stride + kh - h, 0)
        pad_w = max((out_w - 1) * stride + kw - w, 0)
        pads = ((pad_h // 2, pad_h - pad_h // 2),
                (pad_w // 2, pad_w - pad_w // 2))
    elif padding == "VALID":
        out_h = (h - kh) // stride + 1
        out_w = (w - kw) // stride + 1
        pads = ((0, 0), (0, 0))
    else:
        raise ValueError(f"padding must be SAME or VALID, got {padding!r}")
    if out_h <= 0 or out_w <= 0:
        raise ValueError(
            f"conv output would be empty: input {input_hw}, kernel "
            f"({kh}, {kw}), stride {stride}, {padding} padding")
    return (out_h, out_w), pads


def make_conv_plan(w: jax.Array, *, input_hw: tuple[int, int],
                   stride: int = 1, padding: str = "SAME") -> dict:
    """Pack conv weights per-tap along channels (C3) and precompute the

    zero-padding correction matrix (C5) for the layer's input size.

    ``w``: (C_out, KH, KW, C_in) latent fp weights.  The packed kernel
    treats padded pixels as −1, so the true zero-pad result is
    ``packed_result + conv(pad_indicator, Σ_c w)`` — computed once here.

    Returns the plan dict consumed by every conv backend (Pallas / jnp /
    ref): packed weights, geometry statics, and the correction.
    """
    c_out, kh, kw, c_in = w.shape
    wsign = B.sign_pm1(w)
    # Per-tap channel packing: (O, KH*KW, I) -> pack I -> (O, KH*KW*Iw).
    w_packed = B.pack_bits(wsign.reshape(c_out, kh * kw, c_in)
                           ).reshape(c_out, -1)

    (out_h, out_w), pads = conv_geometry(input_hw, kh, kw, stride, padding)
    h, wdt = input_hw

    # Correction (C5): pad_mask is 1 on the padded ring, 0 inside.  The
    # packed conv computes Σ w·(−1) at pad taps; truth is 0, so add
    # +Σ_{pad taps} w == valid-correlate(pad_mask, Σ_c w).
    pad_mask = jnp.pad(jnp.zeros((h, wdt), jnp.float32), pads,
                       constant_values=1.0)
    w_tap_sum = wsign.sum(axis=3)                     # (O, KH, KW)
    corr = jax.lax.conv_general_dilated(
        pad_mask[None, :, :, None],
        jnp.transpose(w_tap_sum, (1, 2, 0))[:, :, None, :],  # HWIO, I=1
        window_strides=(stride, stride), padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))[0]       # (H', W', O)

    return {
        "w_packed": w_packed, "k_true": kh * kw * c_in,
        "kh": kh, "kw": kw, "c_in": c_in, "c_out": c_out,
        "cw": B.packed_width(c_in),
        "stride": stride, "pads": pads,
        "in_hw": (h, wdt), "out_hw": (out_h, out_w),
        "correction": corr.astype(jnp.int32),
    }


def make_bitplane_conv_plan(w: jax.Array, *, input_hw: tuple[int, int],
                            stride: int = 1, padding: str = "SAME",
                            nbits: int = 8) -> dict:
    """Conv plan for the first-layer bit-plane conv (paper C4).

    Per-plane the plane identity  x·w = 1/2 Σ_i 2^i (p̂_i ⊛ w + Σ_taps w)
    holds, where the all-taps rowsum replaces BOTH the {0,1}->±1 shift and
    the pad correction: a zero-padded pixel has every plane bit 0
    (p̂ = −1), so its per-plane contribution (−Σw + Σw) vanishes exactly.
    The C5 correction matrix is therefore identically zero and the plan
    carries none (passing a bitplane plan to the ±1 conv ops fails
    loudly rather than silently dropping the rowsum).
    """
    plan = make_conv_plan(w, input_hw=input_hw, stride=stride,
                          padding=padding)
    wsign = B.sign_pm1(w)
    plan["rowsum"] = wsign.sum(axis=(1, 2, 3)).astype(jnp.int32)
    del plan["correction"]
    plan["nbits"] = nbits
    return plan


# ---------------------------------------------------------------------------
# Block-size resolution (the knobs `ops.py` exposes)
# ---------------------------------------------------------------------------

def resolve_block_n(block_n: int | None, c_out: int) -> int:
    """Validate/resolve the C_out block size.

    ``None`` -> one lane group (128).  Explicit values must be positive
    multiples of 128: silently *clamping up* a too-small user value used
    to hide mis-tuned configs, so it is now an error (clamping *down* to
    the padded C_out is still done — it only trims over-padding).
    """
    if block_n is None:
        block_n = _LANE
    check_block_lanes("block_n", block_n)
    return min(block_n, _ceil_mult(c_out, _LANE))


def resolve_block_oh(block_oh: int | None, oh: int, ow: int) -> int:
    """Validate/resolve the M-tile height (output rows per tile).

    ``None`` picks the largest row band whose flattened pixel count stays
    within ``_DEFAULT_TILE_M`` (whole image when it fits — the untiled
    pre-refactor grid).  Explicit values must be in [1, OH].
    """
    if block_oh is None:
        return max(1, min(oh, _DEFAULT_TILE_M // max(ow, 1) or 1))
    if not 1 <= block_oh:
        raise ValueError(f"block_oh must be >= 1, got {block_oh}")
    return min(block_oh, oh)


# ---------------------------------------------------------------------------
# The kernels
# ---------------------------------------------------------------------------

def _tile_slab(x_ref, prefix: tuple, *, block_oh: int, stride: int,
               kh: int) -> jax.Array:
    """Read this M tile's input row band out of the VMEM-resident image.

    ``x_ref``: ref whose trailing dims are (Hp, Wp, Cw); ``prefix``
    indexes the leading dims (batch slot / plane).  Tile ``m`` (grid dim
    1) covers output rows [m·block_oh, (m+1)·block_oh), which read input
    rows [m·block_oh·stride, m·block_oh·stride + (block_oh−1)·stride
    + kh).  The ``pl.ds`` ref read loads ONLY the slab — the rest of the
    image stays in VMEM untouched.  The host wrapper pads Hp so the last
    tile's slab stays in bounds.
    """
    row0 = pl.program_id(1) * (block_oh * stride)
    hblk = (block_oh - 1) * stride + kh
    return x_ref[(*prefix, pl.ds(row0, hblk))]


def _tap_mismatch(xs: jax.Array, w: jax.Array, *, kh, kw, stride, n_rows,
                  ow, cw) -> jax.Array:
    """In-VMEM im2col + XNOR-popcount mismatch accumulation.

    ``xs``: ((n_rows−1)·stride + kh, Wp, Cw) input slab, ``w``: (bn,
    KH·KW·Cw) tap-major packed weights.  Returns the (n_rows·ow, bn)
    int32 total mismatch count over all taps and packed words.
    """
    m = n_rows * ow
    bn = w.shape[0]
    acc = jnp.zeros((m, bn), jnp.int32)
    for di in range(kh):
        for dj in range(kw):
            # The im2col gather for tap (di, dj): a strided slice of the
            # VMEM-resident slab — never materialized as a patch matrix.
            tap = jax.lax.slice(
                xs, (di, dj, 0),
                (di + (n_rows - 1) * stride + 1,
                 dj + (ow - 1) * stride + 1, cw),
                (stride, stride, 1))                    # (n_rows, OW, Cw)
            a = tap.reshape(m, cw)
            base = (di * kw + dj) * cw
            for c in range(cw):
                aw = jax.lax.slice_in_dim(a, c, c + 1, axis=1)      # (m, 1)
                ww = jax.lax.slice_in_dim(w, base + c, base + c + 1,
                                          axis=1)                   # (bn, 1)
                # One full (m, bn) VPU op per packed word.
                mism = jax.lax.population_count(aw ^ ww.reshape(1, bn))
                acc = acc + mism.astype(jnp.int32)
    return acc


def _conv_kernel(x_ref, w_ref, corr_ref, o_ref, *, kh, kw, stride, block_oh,
                 ow, cw, k_true):
    """In-kernel im2col + XNOR-popcount, int32 output tile."""
    y = _conv_accumulate(x_ref, w_ref, corr_ref, kh=kh, kw=kw, stride=stride,
                         block_oh=block_oh, ow=ow, cw=cw, k_true=k_true)
    o_ref[0] = y


def _conv_bn_sign_kernel(x_ref, w_ref, corr_ref, tau_ref, flip_ref, o_ref, *,
                         kh, kw, stride, block_oh, ow, cw, k_true):
    """Fused variant: conv -> BN-sign threshold -> re-bitpack (uint32)."""
    y = _conv_accumulate(x_ref, w_ref, corr_ref, kh=kh, kw=kw, stride=stride,
                         block_oh=block_oh, ow=ow, cw=cw, k_true=k_true)
    o_ref[0] = bn_sign_bits_to_words(y, tau_ref[...], flip_ref[...])


def _conv_accumulate(x_ref, w_ref, corr_ref, *, kh, kw, stride, block_oh, ow,
                     cw, k_true):
    """Shared body: slab-slice this tile, popcount-accumulate, + correction.

    Returns the (block_oh·ow, bn) int32 pre-epilogue conv output tile.
    """
    xs = _tile_slab(x_ref, (0,), block_oh=block_oh, stride=stride, kh=kh)
    mism = _tap_mismatch(xs, w_ref[...], kh=kh, kw=kw, stride=stride,
                         n_rows=block_oh, ow=ow, cw=cw)
    return jnp.int32(k_true) - 2 * mism + corr_ref[...]


def _bitplane_conv_kernel(x_ref, w_ref, rowsum_ref, o_ref, *, kh, kw, stride,
                          block_oh, ow, cw, k_true, nbits):
    """Single-launch first-layer conv: in-kernel loop over bit planes.

    ``x_ref``: (nbits, 1, Hp, Wp, Cw) — all planes of one batch element
    resident in VMEM, so the plane loop re-reads the same block instead
    of re-DMAing the image per plane.  The epilogue folds the 2^i plane
    weighting and the rowsum pad/shift correction:

        out = ( (2^n − 1)·(K + rowsum)  −  2·Σ_p 2^p·mism_p ) >> 1

    which is  1/2 Σ_p 2^p (K − 2·mism_p + rowsum)  — the exact integer
    identity of ``core.binarize.bitplane_dot`` per output pixel.  The
    pre-shift value is always even, and >> on int32 is arithmetic, so
    the halving is exact for negative accumulators too.
    """
    w = w_ref[...]
    m = block_oh * ow
    bn = w.shape[0]
    wacc = jnp.zeros((m, bn), jnp.int32)
    for p in range(nbits):
        xs = _tile_slab(x_ref, (p, 0), block_oh=block_oh, stride=stride,
                        kh=kh)
        mism = _tap_mismatch(xs, w, kh=kh, kw=kw, stride=stride,
                             n_rows=block_oh, ow=ow, cw=cw)
        wacc = wacc + (mism << p)
    full = jnp.int32((1 << nbits) - 1)
    o_ref[0] = (full * (jnp.int32(k_true) + rowsum_ref[...])
                - 2 * wacc) >> 1


# ---------------------------------------------------------------------------
# Host-side wrappers
# ---------------------------------------------------------------------------

def _prep_operands(x_packed, w_packed, correction, *, pads, c_out, block_n,
                   block_oh, stride, kh, out_hw):
    """Stage every operand for the (batch, M tiles, C_out blocks) grid.

    * spatial zero-word padding (pad == all −1) on the image, plus extra
      zero rows so the last M tile's input slab stays in bounds,
    * C_out padding on weights/correction up to the block size,
    * OH padding on the correction up to a whole number of M tiles
      (padded output rows are computed then discarded by the caller).

    Works for both (B, H, W, Cw) images and (nbits, B, H, W, Cw) plane
    stacks — spatial axes are the last three.  ``correction=None`` (the
    bit-plane kernel, whose rowsum epilogue subsumes it) skips the
    correction staging and returns None in its slot.
    """
    lead = x_packed.ndim - 3
    xp = jnp.pad(x_packed,
                 ((0, 0),) * lead + (pads[0], pads[1], (0, 0)),
                 constant_values=0)
    oh, ow = out_hw
    m_tiles = -(-oh // block_oh)
    oh_p = m_tiles * block_oh
    need_h = (oh_p - 1) * stride + kh
    extra_h = max(0, need_h - xp.shape[lead])
    if extra_h:
        xp = jnp.pad(xp, ((0, 0),) * lead + ((0, extra_h), (0, 0), (0, 0)),
                     constant_values=0)
    c_out_p = _ceil_mult(c_out, block_n)
    w_p = B.pad_to_multiple(w_packed, block_n, 0)
    corr = None
    if correction is not None:
        corr = B.pad_to_multiple(correction.reshape(oh, ow, c_out),
                                 block_oh, 0)             # (OH_p, OW, C)
        corr = B.pad_to_multiple(corr.reshape(oh_p * ow, c_out), block_n, 1)
    return xp, w_p, corr, c_out_p, m_tiles, oh_p


@functools.partial(jax.jit, static_argnames=(
    "kh", "kw", "stride", "pads", "out_hw", "c_out", "k_true", "block_n",
    "block_oh", "interpret"))
def binary_conv2d_packed(x_packed: jax.Array, w_packed: jax.Array,
                         correction: jax.Array, *, kh: int, kw: int,
                         stride: int, pads, out_hw: tuple[int, int],
                         c_out: int, k_true: int, block_n: int | None = None,
                         block_oh: int | None = None,
                         interpret: bool = False) -> jax.Array:
    """Packed binary conv via Pallas; int32 output.

    ``x_packed``: (B, H, W, Cw) channel-packed uint32, ``w_packed``:
    (C_out, KH*KW*Cw) tap-major packed weights (from ``make_conv_plan``).
    Returns (B, OH, OW, C_out) int32 — the exact integer conv of the ±1
    tensors with true zero padding (pad-as-(−1) + correction, paper C5).

    ``block_oh``/``block_n`` tile the (OH·OW, C_out) output: the grid is
    (B, ⌈OH/block_oh⌉, ⌈C_out/block_n⌉) and the result is invariant to
    both knobs (property-tested in tests/test_conv_properties.py).
    """
    bsz = x_packed.shape[0]
    cw = x_packed.shape[-1]
    oh, ow = out_hw
    block_n = resolve_block_n(block_n, c_out)
    block_oh = resolve_block_oh(block_oh, oh, ow)
    xp, w_p, corr, c_out_p, m_tiles, oh_p = _prep_operands(
        x_packed, w_packed, correction, pads=pads, c_out=c_out,
        block_n=block_n, block_oh=block_oh, stride=stride, kh=kh,
        out_hw=out_hw)
    hp, wp = xp.shape[1:3]
    block_m = block_oh * ow
    grid = (bsz, m_tiles, c_out_p // block_n)

    kernel = functools.partial(_conv_kernel, kh=kh, kw=kw, stride=stride,
                               block_oh=block_oh, ow=ow, cw=cw,
                               k_true=k_true)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, hp, wp, cw), lambda b, m, j: (b, 0, 0, 0)),
            pl.BlockSpec((block_n, kh * kw * cw), lambda b, m, j: (j, 0)),
            pl.BlockSpec((block_m, block_n), lambda b, m, j: (m, j)),
        ],
        out_specs=pl.BlockSpec((1, block_m, block_n),
                               lambda b, m, j: (b, m, j)),
        out_shape=jax.ShapeDtypeStruct((bsz, oh_p * ow, c_out_p), jnp.int32),
        interpret=interpret,
    )(xp, w_p, corr)
    return out[:, :oh * ow, :c_out].reshape(bsz, oh, ow, c_out)


@functools.partial(jax.jit, static_argnames=(
    "kh", "kw", "stride", "pads", "out_hw", "c_out", "k_true", "block_n",
    "block_oh", "interpret"))
def binary_conv2d_bn_sign_packed(x_packed: jax.Array, w_packed: jax.Array,
                                 correction: jax.Array, tau: jax.Array,
                                 flip: jax.Array, *, kh: int, kw: int,
                                 stride: int, pads, out_hw: tuple[int, int],
                                 c_out: int, k_true: int,
                                 block_n: int | None = None,
                                 block_oh: int | None = None,
                                 interpret: bool = False) -> jax.Array:
    """Fused conv + BN-sign-fold + re-bitpack; packed uint32 output.

    Same contraction (and same M-tiled grid) as
    :func:`binary_conv2d_packed`, but the epilogue thresholds against the
    folded BN (``tau``/``flip``, per C_out channel) and packs the
    resulting ±1 bits along C_out — the activation never leaves packed
    form in HBM.  Returns (B, OH, OW, ceil(C_out/32)) uint32,
    bit-identical to ``pack_bits(apply_bn_sign_folded(conv_out))``.
    """
    bsz = x_packed.shape[0]
    cw = x_packed.shape[-1]
    oh, ow = out_hw
    block_n = resolve_block_n(block_n, c_out)
    block_oh = resolve_block_oh(block_oh, oh, ow)
    assert block_n % B.WORD_BITS == 0
    xp, w_p, corr, c_out_p, m_tiles, oh_p = _prep_operands(
        x_packed, w_packed, correction, pads=pads, c_out=c_out,
        block_n=block_n, block_oh=block_oh, stride=stride, kh=kh,
        out_hw=out_hw)
    tau_p, flip_p = pad_bn_params(tau, flip, block_n)
    hp, wp = xp.shape[1:3]
    block_m = block_oh * ow
    grid = (bsz, m_tiles, c_out_p // block_n)
    bnw = block_n // B.WORD_BITS

    kernel = functools.partial(_conv_bn_sign_kernel, kh=kh, kw=kw,
                               stride=stride, block_oh=block_oh, ow=ow,
                               cw=cw, k_true=k_true)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, hp, wp, cw), lambda b, m, j: (b, 0, 0, 0)),
            pl.BlockSpec((block_n, kh * kw * cw), lambda b, m, j: (j, 0)),
            pl.BlockSpec((block_m, block_n), lambda b, m, j: (m, j)),
            pl.BlockSpec((1, block_n), lambda b, m, j: (0, j)),
            pl.BlockSpec((1, block_n), lambda b, m, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, block_m, bnw), lambda b, m, j: (b, m, j)),
        out_shape=jax.ShapeDtypeStruct(
            (bsz, oh_p * ow, c_out_p // B.WORD_BITS), jnp.uint32),
        interpret=interpret,
    )(xp, w_p, corr, tau_p, flip_p)
    cw_out = B.packed_width(c_out)
    return out[:, :oh * ow, :cw_out].reshape(bsz, oh, ow, cw_out)


@functools.partial(jax.jit, static_argnames=(
    "kh", "kw", "stride", "pads", "out_hw", "c_out", "k_true", "nbits",
    "block_n", "block_oh", "interpret"))
def bitplane_conv2d_packed(x_planes: jax.Array, w_packed: jax.Array,
                           rowsum: jax.Array, *, kh: int, kw: int,
                           stride: int, pads, out_hw: tuple[int, int],
                           c_out: int, k_true: int, nbits: int,
                           block_n: int | None = None,
                           block_oh: int | None = None,
                           interpret: bool = False) -> jax.Array:
    """First-layer fixed-precision conv (paper C4) in ONE kernel launch.

    ``x_planes``: (nbits, B, H, W, Cw) packed bit-plane images (from
    ``core.binarize.pack_bitplanes_uint8`` — plane bit == packed bit, so
    plane value 0 encodes the ±1 value −1).  ``rowsum``: (C_out,) int32
    all-taps weight row sums (``make_bitplane_conv_plan``).  Returns
    (B, OH, OW, C_out) int32 == the exact integer conv of the raw
    fixed-precision input against sign(W) with true zero padding.

    Replaces the model's previous 8 sequential per-plane conv launches:
    all planes share one VMEM-resident image block and the plane loop,
    2^i weighting, and pad correction live in the kernel epilogue.
    """
    nb, bsz = x_planes.shape[:2]
    assert nb == nbits, (nb, nbits)
    cw = x_planes.shape[-1]
    oh, ow = out_hw
    block_n = resolve_block_n(block_n, c_out)
    block_oh = resolve_block_oh(block_oh, oh, ow)
    xp, w_p, _, c_out_p, m_tiles, oh_p = _prep_operands(
        x_planes, w_packed, None, pads=pads, c_out=c_out,
        block_n=block_n, block_oh=block_oh, stride=stride, kh=kh,
        out_hw=out_hw)
    rs = B.pad_to_multiple(rowsum.reshape(1, c_out).astype(jnp.int32),
                           block_n, 1)
    hp, wp = xp.shape[2:4]
    block_m = block_oh * ow
    grid = (bsz, m_tiles, c_out_p // block_n)

    kernel = functools.partial(_bitplane_conv_kernel, kh=kh, kw=kw,
                               stride=stride, block_oh=block_oh, ow=ow,
                               cw=cw, k_true=k_true, nbits=nbits)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((nbits, 1, hp, wp, cw),
                         lambda b, m, j: (0, b, 0, 0, 0)),
            pl.BlockSpec((block_n, kh * kw * cw), lambda b, m, j: (j, 0)),
            pl.BlockSpec((1, block_n), lambda b, m, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, block_m, block_n),
                               lambda b, m, j: (b, m, j)),
        out_shape=jax.ShapeDtypeStruct((bsz, oh_p * ow, c_out_p), jnp.int32),
        interpret=interpret,
    )(xp, w_p, rs)
    return out[:, :oh * ow, :c_out].reshape(bsz, oh, ow, c_out)


def _ceil_mult(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m
