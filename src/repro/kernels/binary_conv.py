"""Pallas TPU kernel: fused bit-packed binary 2-D convolution (paper C5/C6).

The paper's headline claim is *dedicated convolutional layers for BCNNs*
that keep data bit-packed end-to-end.  The previous packed conv path did
im2col in plain jnp **outside** any kernel — materializing the full
(B·H'·W', KH·KW·Cw) patch matrix in HBM — then ran the packed GEMM over
it.  This kernel performs im2col **inside** the kernel:

* the channel-packed input image tile lives in VMEM ((Hp, Wp, Cw) uint32,
  channels packed 32/word, paper C3 "free lift" layout),
* for each of the KH·KW taps the kernel takes a strided in-VMEM slice of
  the image (the im2col gather — never written back to HBM),
* XNOR-popcount accumulates word-by-word into an int32 accumulator
  (one full (OH·OW, bn) VPU op per packed word, same scheme as
  ``binary_matmul``),
* the epilogue folds the paper's pad-as-(−1) correction matrix (C5), and
  optionally the BN-sign threshold + re-bitpack (``fused_epilogue``), so
  the activation leaves the kernel already packed for the next layer.

Grid: (batch, C_out blocks).  Each program computes all output pixels of
one image for one block of output channels — the contraction is complete
per program, so no cross-step scratch accumulator is needed.

Supported: arbitrary integer stride (paper evaluates 1 and 2), SAME and
VALID padding; spatial padding is staged as all-zero words (bit 0 == −1,
the paper's convention) and corrected exactly in the epilogue.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import binarize as B
from repro.kernels.fused_epilogue import bn_sign_bits_to_words, pad_bn_params

# Minimum tile granularity on TPU: (8 sublanes, 128 lanes).
_LANE = 128


# ---------------------------------------------------------------------------
# Conv plan: geometry + one-time weight packing (paper C2/C3/C5)
# ---------------------------------------------------------------------------

def conv_geometry(input_hw: tuple[int, int], kh: int, kw: int, stride: int,
                  padding: str) -> tuple[tuple[int, int], tuple]:
    """Output spatial size and ((top, bottom), (left, right)) pads.

    Matches XLA's SAME/VALID conventions (extra pad goes low-index-last,
    i.e. bottom/right), so the packed path lines up pixel-for-pixel with
    ``jax.lax.conv_general_dilated``.
    """
    h, w = input_hw
    if padding == "SAME":
        out_h = -(-h // stride)
        out_w = -(-w // stride)
        pad_h = max((out_h - 1) * stride + kh - h, 0)
        pad_w = max((out_w - 1) * stride + kw - w, 0)
        pads = ((pad_h // 2, pad_h - pad_h // 2),
                (pad_w // 2, pad_w - pad_w // 2))
    elif padding == "VALID":
        out_h = (h - kh) // stride + 1
        out_w = (w - kw) // stride + 1
        pads = ((0, 0), (0, 0))
    else:
        raise ValueError(f"padding must be SAME or VALID, got {padding!r}")
    if out_h <= 0 or out_w <= 0:
        raise ValueError(
            f"conv output would be empty: input {input_hw}, kernel "
            f"({kh}, {kw}), stride {stride}, {padding} padding")
    return (out_h, out_w), pads


def make_conv_plan(w: jax.Array, *, input_hw: tuple[int, int],
                   stride: int = 1, padding: str = "SAME") -> dict:
    """Pack conv weights per-tap along channels (C3) and precompute the

    zero-padding correction matrix (C5) for the layer's input size.

    ``w``: (C_out, KH, KW, C_in) latent fp weights.  The packed kernel
    treats padded pixels as −1, so the true zero-pad result is
    ``packed_result + conv(pad_indicator, Σ_c w)`` — computed once here.

    Returns the plan dict consumed by every conv backend (Pallas / jnp /
    ref): packed weights, geometry statics, and the correction.
    """
    c_out, kh, kw, c_in = w.shape
    wsign = B.sign_pm1(w)
    # Per-tap channel packing: (O, KH*KW, I) -> pack I -> (O, KH*KW*Iw).
    w_packed = B.pack_bits(wsign.reshape(c_out, kh * kw, c_in)
                           ).reshape(c_out, -1)

    (out_h, out_w), pads = conv_geometry(input_hw, kh, kw, stride, padding)
    h, wdt = input_hw

    # Correction (C5): pad_mask is 1 on the padded ring, 0 inside.  The
    # packed conv computes Σ w·(−1) at pad taps; truth is 0, so add
    # +Σ_{pad taps} w == valid-correlate(pad_mask, Σ_c w).
    pad_mask = jnp.pad(jnp.zeros((h, wdt), jnp.float32), pads,
                       constant_values=1.0)
    w_tap_sum = wsign.sum(axis=3)                     # (O, KH, KW)
    corr = jax.lax.conv_general_dilated(
        pad_mask[None, :, :, None],
        jnp.transpose(w_tap_sum, (1, 2, 0))[:, :, None, :],  # HWIO, I=1
        window_strides=(stride, stride), padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))[0]       # (H', W', O)

    return {
        "w_packed": w_packed, "k_true": kh * kw * c_in,
        "kh": kh, "kw": kw, "c_in": c_in, "c_out": c_out,
        "cw": B.packed_width(c_in),
        "stride": stride, "pads": pads,
        "in_hw": (h, wdt), "out_hw": (out_h, out_w),
        "correction": corr.astype(jnp.int32),
    }


# ---------------------------------------------------------------------------
# The kernel
# ---------------------------------------------------------------------------

def _conv_kernel(x_ref, w_ref, corr_ref, o_ref, *, kh, kw, stride, oh, ow,
                 cw, k_true):
    """In-kernel im2col + XNOR-popcount, int32 output tile."""
    y = _conv_accumulate(x_ref, w_ref, corr_ref, kh=kh, kw=kw, stride=stride,
                         oh=oh, ow=ow, cw=cw, k_true=k_true)
    o_ref[0] = y


def _conv_bn_sign_kernel(x_ref, w_ref, corr_ref, tau_ref, flip_ref, o_ref, *,
                         kh, kw, stride, oh, ow, cw, k_true):
    """Fused variant: conv -> BN-sign threshold -> re-bitpack (uint32)."""
    y = _conv_accumulate(x_ref, w_ref, corr_ref, kh=kh, kw=kw, stride=stride,
                         oh=oh, ow=ow, cw=cw, k_true=k_true)
    o_ref[0] = bn_sign_bits_to_words(y, tau_ref[...], flip_ref[...])


def _conv_accumulate(x_ref, w_ref, corr_ref, *, kh, kw, stride, oh, ow, cw,
                     k_true):
    """Shared body: gather taps in VMEM, popcount-accumulate, + correction.

    Returns the (OH*OW, bn) int32 pre-epilogue conv output.
    """
    x = x_ref[0]                    # (Hp, Wp, Cw) uint32, one padded image
    w = w_ref[...]                  # (bn, KH*KW*Cw) uint32, tap-major
    m = oh * ow
    bn = w.shape[0]
    acc = jnp.zeros((m, bn), jnp.int32)
    for di in range(kh):
        for dj in range(kw):
            # The im2col gather for tap (di, dj): a strided slice of the
            # VMEM-resident image — never materialized as a patch matrix.
            tap = jax.lax.slice(
                x, (di, dj, 0),
                (di + (oh - 1) * stride + 1, dj + (ow - 1) * stride + 1, cw),
                (stride, stride, 1))                    # (OH, OW, Cw)
            a = tap.reshape(m, cw)
            base = (di * kw + dj) * cw
            for c in range(cw):
                aw = jax.lax.slice_in_dim(a, c, c + 1, axis=1)      # (m, 1)
                ww = jax.lax.slice_in_dim(w, base + c, base + c + 1,
                                          axis=1)                   # (bn, 1)
                # One full (m, bn) VPU op per packed word.
                mism = jax.lax.population_count(aw ^ ww.reshape(1, bn))
                acc = acc + mism.astype(jnp.int32)
    return jnp.int32(k_true) - 2 * acc + corr_ref[...]


# ---------------------------------------------------------------------------
# Host-side wrappers
# ---------------------------------------------------------------------------

def _prep_operands(x_packed, w_packed, correction, *, pads, c_out, block_n):
    """Spatial zero-word padding (pad == all −1) + C_out block padding."""
    xp = jnp.pad(x_packed, ((0, 0), pads[0], pads[1], (0, 0)),
                 constant_values=0)
    c_out_p = _ceil_mult(c_out, block_n)
    w_p = B.pad_to_multiple(w_packed, block_n, 0)
    oh, ow = correction.shape[:2]
    corr = B.pad_to_multiple(correction.reshape(oh * ow, c_out), block_n, 1)
    return xp, w_p, corr, c_out_p


@functools.partial(jax.jit, static_argnames=(
    "kh", "kw", "stride", "pads", "out_hw", "c_out", "k_true", "block_n",
    "interpret"))
def binary_conv2d_packed(x_packed: jax.Array, w_packed: jax.Array,
                         correction: jax.Array, *, kh: int, kw: int,
                         stride: int, pads, out_hw: tuple[int, int],
                         c_out: int, k_true: int, block_n: int = _LANE,
                         interpret: bool = False) -> jax.Array:
    """Packed binary conv via Pallas; int32 output.

    ``x_packed``: (B, H, W, Cw) channel-packed uint32, ``w_packed``:
    (C_out, KH*KW*Cw) tap-major packed weights (from ``make_conv_plan``).
    Returns (B, OH, OW, C_out) int32 — the exact integer conv of the ±1
    tensors with true zero padding (pad-as-(−1) + correction, paper C5).
    """
    bsz = x_packed.shape[0]
    cw = x_packed.shape[-1]
    oh, ow = out_hw
    block_n = max(_LANE, min(block_n, _ceil_mult(c_out, _LANE)))
    xp, w_p, corr, c_out_p = _prep_operands(
        x_packed, w_packed, correction, pads=pads, c_out=c_out,
        block_n=block_n)
    hp, wp = xp.shape[1:3]
    grid = (bsz, c_out_p // block_n)

    kernel = functools.partial(_conv_kernel, kh=kh, kw=kw, stride=stride,
                               oh=oh, ow=ow, cw=cw, k_true=k_true)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, hp, wp, cw), lambda b, j: (b, 0, 0, 0)),
            pl.BlockSpec((block_n, kh * kw * cw), lambda b, j: (j, 0)),
            pl.BlockSpec((oh * ow, block_n), lambda b, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, oh * ow, block_n),
                               lambda b, j: (b, 0, j)),
        out_shape=jax.ShapeDtypeStruct((bsz, oh * ow, c_out_p), jnp.int32),
        interpret=interpret,
    )(xp, w_p, corr)
    return out[..., :c_out].reshape(bsz, oh, ow, c_out)


@functools.partial(jax.jit, static_argnames=(
    "kh", "kw", "stride", "pads", "out_hw", "c_out", "k_true", "block_n",
    "interpret"))
def binary_conv2d_bn_sign_packed(x_packed: jax.Array, w_packed: jax.Array,
                                 correction: jax.Array, tau: jax.Array,
                                 flip: jax.Array, *, kh: int, kw: int,
                                 stride: int, pads, out_hw: tuple[int, int],
                                 c_out: int, k_true: int,
                                 block_n: int = _LANE,
                                 interpret: bool = False) -> jax.Array:
    """Fused conv + BN-sign-fold + re-bitpack; packed uint32 output.

    Same contraction as :func:`binary_conv2d_packed`, but the epilogue
    thresholds against the folded BN (``tau``/``flip``, per C_out channel)
    and packs the resulting ±1 bits along C_out — the activation never
    leaves packed form in HBM.  Returns (B, OH, OW, ceil(C_out/32)) uint32,
    bit-identical to ``pack_bits(apply_bn_sign_folded(conv_out))``.
    """
    bsz = x_packed.shape[0]
    cw = x_packed.shape[-1]
    oh, ow = out_hw
    block_n = max(_LANE, min(block_n, _ceil_mult(c_out, _LANE)))
    assert block_n % B.WORD_BITS == 0
    xp, w_p, corr, c_out_p = _prep_operands(
        x_packed, w_packed, correction, pads=pads, c_out=c_out,
        block_n=block_n)
    tau_p, flip_p = pad_bn_params(tau, flip, block_n)
    hp, wp = xp.shape[1:3]
    grid = (bsz, c_out_p // block_n)
    bnw = block_n // B.WORD_BITS

    kernel = functools.partial(_conv_bn_sign_kernel, kh=kh, kw=kw,
                               stride=stride, oh=oh, ow=ow, cw=cw,
                               k_true=k_true)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, hp, wp, cw), lambda b, j: (b, 0, 0, 0)),
            pl.BlockSpec((block_n, kh * kw * cw), lambda b, j: (j, 0)),
            pl.BlockSpec((oh * ow, block_n), lambda b, j: (0, j)),
            pl.BlockSpec((1, block_n), lambda b, j: (0, j)),
            pl.BlockSpec((1, block_n), lambda b, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, oh * ow, bnw), lambda b, j: (b, 0, j)),
        out_shape=jax.ShapeDtypeStruct(
            (bsz, oh * ow, c_out_p // B.WORD_BITS), jnp.uint32),
        interpret=interpret,
    )(xp, w_p, corr, tau_p, flip_p)
    cw_out = B.packed_width(c_out)
    return out[..., :cw_out].reshape(bsz, oh, ow, cw_out)


def _ceil_mult(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m
