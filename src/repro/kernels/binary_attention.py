"""Pallas TPU kernel: flash-style blocked binary attention.

The LM analogue of the dense megakernel suite (``binary_matmul.py``):
the QKᵀ inner product of every attention score is the XNOR-popcount
identity  s = (D − 2·popcount(XOR(q_packed, k_packed))) · D^(−1/2)
over sign-binarized Q/K packed 32-per-uint32-word along head_dim, and
the softmax runs in the FlashAttention online form — a per-q-row
(m, l, acc) carry in VMEM scratch walked over KV tiles by the last grid
dimension — so the (Sq, Skv) score matrix is never materialized in HBM.
V stays real-valued and accumulates in float32 (the paper binarizes the
*projections*; the attention average must keep magnitude information).

Layout and masking:

* ``q_packed``: (B, Sq, Hq, Dw) uint32, ``k_packed``: (B, Skv, Hkv, Dw)
  uint32 — packed along head_dim by the ``kernels.ops.bitpack``
  dispatcher (bit 1 ⇔ value ≥ 0, LSB-first, zero-bit tails when
  head_dim % 32 ≠ 0 — exact under the XOR-popcount identity because
  both operands pad identically). ``v``: (B, Skv, Hkv, Dv) real.
* GQA/MQA: ``Hq % Hkv == 0``; query head h reads KV head ``h // g``
  (g = Hq // Hkv) via BlockSpec index-map arithmetic — KV blocks are
  never replicated in HBM.
* Masks mirror ``models.attention.chunked_attention``: ``causal`` keeps
  qpos ≥ kpos (with ``q_offset`` aligning decode queries), ``window``
  keeps qpos − kpos < window (the sliding-window local-layer form), and
  masked lanes score ``NEG_INF`` *after* the optional logit softcap.

Grid: (B·Hq, Sq tiles, KV tiles) — KV innermost so the scratch carry is
sequential per q tile, exactly like the K-block walk of the GEMM
accumulator.  ``block_q`` is sublane-granular (multiple of 8),
``block_kv`` lane-granular (multiple of 128); both validate by RAISING,
like ``block_oh``/``block_n``/``words_per_step`` everywhere else.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import binarize as B
from repro.kernels.binary_matmul import (_LANE, _SUBLANE, _ceil_mult,
                                         _mismatch_counts,
                                         DEFAULT_WORDS_PER_STEP)
from repro.kernels.fused_epilogue import (check_block_lanes,
                                          check_block_sublanes,
                                          check_words_per_step)

# Additive mask value: finite (so NEG_INF − NEG_INF == 0 and fully-masked
# rows degrade to a uniform average instead of NaN), same constant as
# models.attention.
NEG_INF = -1e30

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_KV = 128


def _attention_kernel(qp_ref, kp_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                      d_true: int, skv_true: int, causal: bool,
                      window: int | None, softcap: float | None,
                      q_offset: int, n_kv_blocks: int, block_q: int,
                      block_kv: int, words_per_step: int):
    """One (block_q, Dv) output tile; grid dim 2 walks the KV tiles."""
    iq = pl.program_id(1)
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Scores: XNOR-popcount identity, then scale (and optional softcap)
    # in f32.  Packed-word tails are zero on both operands, so they XOR
    # to no mismatches and d_true keeps the identity exact.
    mism = _mismatch_counts(qp_ref[0], kp_ref[0],
                            words_per_step=words_per_step)
    s = (jnp.int32(d_true) - 2 * mism).astype(jnp.float32)
    s = s * jnp.float32(d_true) ** -0.5
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)

    qpos = q_offset + iq * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_kv), 0)
    kpos = kb * block_kv + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_kv), 1)
    mask = kpos < skv_true                      # KV padding rows
    if causal:
        mask = mask & (qpos >= kpos)
    if window is not None:
        mask = mask & (qpos - kpos < window)
    s = jnp.where(mask, s, NEG_INF)

    # Online-softmax carry (m, l, acc), FlashAttention recurrence.  The
    # scalars live lane-broadcast in (block_q, 128) scratch; column 0 is
    # the value.
    m_prev = m_ref[:, :1]
    l_prev = l_ref[:, :1]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jnp.dot(
        p, v_ref[0].astype(jnp.float32),
        preferred_element_type=jnp.float32)
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(kb == n_kv_blocks - 1)
    def _flush():
        o_ref[0] = acc_ref[...] / jnp.maximum(l_ref[:, :1], 1e-30)


@functools.partial(jax.jit, static_argnames=(
    "d_true", "causal", "window", "attn_softcap", "q_offset", "block_q",
    "block_kv", "words_per_step", "interpret"))
def binary_attention_packed(q_packed: jax.Array, k_packed: jax.Array,
                            v: jax.Array, *, d_true: int,
                            causal: bool = True, window: int | None = None,
                            attn_softcap: float | None = None,
                            q_offset: int = 0, block_q: int | None = None,
                            block_kv: int | None = None,
                            words_per_step: int = DEFAULT_WORDS_PER_STEP,
                            interpret: bool = False) -> jax.Array:
    """Blocked binary attention on pre-packed Q/K (see module docstring).

    ``q_packed``: (B, Sq, Hq, Dw) uint32, ``k_packed``: (B, Skv, Hkv, Dw)
    uint32, ``v``: (B, Skv, Hkv, Dv) real; ``d_true`` is the logical
    head_dim before packing.  Returns (B, Sq, Hq, Dv) float32.

    Block knobs validate by raising: ``block_q`` must be a positive
    multiple of 8 (sublanes), ``block_kv`` a positive multiple of 128
    (lanes), ``words_per_step`` a positive divisor of 128.  The output
    is invariant to all three (property-tested).
    """
    b, sq, hq, dw = q_packed.shape
    bk, skv, hkv, dwk = k_packed.shape
    if bk != b or dwk != dw:
        raise ValueError(f"q/k packed shapes disagree: "
                         f"{q_packed.shape} vs {k_packed.shape}")
    if v.shape[:3] != (b, skv, hkv):
        raise ValueError(f"k/v shapes disagree: {k_packed.shape} vs "
                         f"{v.shape}")
    if hkv < 1 or hq % hkv:
        raise ValueError(f"Hq={hq} not a multiple of Hkv={hkv}")
    group = hq // hkv
    dv = v.shape[-1]

    bq = DEFAULT_BLOCK_Q if block_q is None else block_q
    bkv = DEFAULT_BLOCK_KV if block_kv is None else block_kv
    check_block_sublanes("block_q", bq)
    check_block_lanes("block_kv", bkv)
    check_words_per_step("words_per_step", words_per_step)
    bq = min(bq, _ceil_mult(sq, _SUBLANE))
    bkv = min(bkv, _ceil_mult(skv, _LANE))

    sq_p = _ceil_mult(sq, bq)
    skv_p = _ceil_mult(skv, bkv)
    dw_p = _ceil_mult(dw, _LANE)
    dv_p = _ceil_mult(dv, _LANE)
    n_kv_blocks = skv_p // bkv

    def lay_out(x, s_mult, last_mult):
        x = B.pad_to_multiple(x, s_mult, axis=1)
        x = B.pad_to_multiple(x, last_mult, axis=3)
        h = x.shape[2]
        return x.transpose(0, 2, 1, 3).reshape(b * h, x.shape[1], -1)

    qp = lay_out(q_packed, bq, _LANE)                    # (B*Hq, Sq_p, Dw_p)
    kp = lay_out(k_packed, bkv, _LANE)                   # (B*Hkv, Skv_p, Dw_p)
    vp = lay_out(v.astype(jnp.float32), bkv, _LANE)      # (B*Hkv, Skv_p, Dv_p)

    def q_map(bh, iq, kb):
        return (bh, iq, 0)

    def kv_map(bh, iq, kb):
        # GQA: query head bh % Hq reads KV head (bh % Hq) // group.
        return ((bh // hq) * hkv + (bh % hq) // group, kb, 0)

    out = pl.pallas_call(
        functools.partial(
            _attention_kernel, d_true=d_true, skv_true=skv, causal=causal,
            window=window, softcap=attn_softcap, q_offset=q_offset,
            n_kv_blocks=n_kv_blocks, block_q=bq, block_kv=bkv,
            words_per_step=words_per_step),
        grid=(b * hq, sq_p // bq, n_kv_blocks),
        in_specs=[pl.BlockSpec((1, bq, dw_p), q_map),
                  pl.BlockSpec((1, bkv, dw_p), kv_map),
                  pl.BlockSpec((1, bkv, dv_p), kv_map)],
        out_specs=pl.BlockSpec((1, bq, dv_p), q_map),
        out_shape=jax.ShapeDtypeStruct((b * hq, sq_p, dv_p), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bq, _LANE), jnp.float32),
                        pltpu.VMEM((bq, _LANE), jnp.float32),
                        pltpu.VMEM((bq, dv_p), jnp.float32)],
        interpret=interpret,
    )(qp, kp, vp)
    out = out.reshape(b, hq, sq_p, dv_p)[:, :, :sq, :dv]
    return out.transpose(0, 2, 1, 3)
