"""Public jit'd wrappers for the Pallas kernels.

On a real TPU the kernels run compiled; on CPU (this container, CI) they
run in ``interpret=True`` mode, which executes the kernel body in Python
with identical semantics — the correctness contract is enforced against
``ref.py`` either way.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import binarize as B
from repro.kernels import binary_matmul as _bmm
from repro.kernels import bitpack as _bp
from repro.kernels import ref as _ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def binary_matmul(a: jax.Array, b: jax.Array, *,
                  backend: str = "auto") -> jax.Array:
    """End-to-end binary GEMM on real-valued operands.

    ``a``: (M, K), ``b``: (N, K).  Sign-binarizes both, packs, and runs the
    XNOR-popcount GEMM.  Returns (M, N) int32.

    backend: 'pallas' | 'jnp' | 'ref' | 'auto' (pallas on TPU, jnp else).
    """
    if backend == "auto":
        backend = "pallas" if _on_tpu() else "jnp"
    if backend == "ref":
        return _ref.binary_matmul_ref(a, b)
    k = a.shape[-1]
    a_p = B.pack_bits(a)
    b_p = B.pack_bits(b)
    return binary_matmul_packed(a_p, b_p, k_true=k, backend=backend)


def binary_matmul_packed(a_packed: jax.Array, b_packed: jax.Array, *,
                         k_true: int, backend: str = "auto") -> jax.Array:
    """Binary GEMM on pre-packed operands (weights packed once, paper C2)."""
    if backend == "auto":
        backend = "pallas" if _on_tpu() else "jnp"
    if backend == "pallas":
        return _bmm.binary_matmul_packed(a_packed, b_packed, k_true=k_true,
                                         interpret=not _on_tpu())
    return B.packed_matmul(a_packed, b_packed, k_true)


def bitpack(x: jax.Array, *, backend: str = "auto") -> jax.Array:
    """Sign-binarize + pack along the last axis -> uint32 words."""
    if backend == "auto":
        backend = "pallas" if _on_tpu() else "jnp"
    if backend == "pallas":
        orig_shape = x.shape
        x2 = x.reshape(-1, orig_shape[-1])
        out = _bp.bitpack(x2, interpret=not _on_tpu())
        return out.reshape(*orig_shape[:-1], out.shape[-1])
    return B.pack_bits(x)
