"""Public jit'd wrappers for the Pallas kernels.

On a real TPU the kernels run compiled; on CPU (this container, CI) they
run in ``interpret=True`` mode, which executes the kernel body in Python
with identical semantics — the correctness contract is enforced against
``ref.py`` either way.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import binarize as B
from repro.kernels import binary_conv as _bconv
from repro.kernels import binary_matmul as _bmm
from repro.kernels import bitpack as _bp
from repro.kernels import fused_epilogue as _fe
from repro.kernels import ref as _ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(backend: str) -> str:
    """Single point of backend resolution for EVERY public dispatcher.

    Re-implementing the 'auto' check inline used to let a typo like
    ``backend="pallsa"`` fall through to the jnp path silently; routing
    everything here makes an unknown backend a loud ValueError.
    """
    if backend == "auto":
        return "pallas" if _on_tpu() else "jnp"
    if backend not in ("pallas", "jnp", "ref"):
        raise ValueError(f"unknown backend {backend!r}")
    return backend


def _words_per_step(words_per_step: int | None) -> int:
    return (_bmm.DEFAULT_WORDS_PER_STEP if words_per_step is None
            else words_per_step)


def binary_matmul(a: jax.Array, b: jax.Array, *, backend: str = "auto",
                  words_per_step: int | None = None) -> jax.Array:
    """End-to-end binary GEMM on real-valued operands.

    ``a``: (M, K), ``b``: (N, K).  Sign-binarizes both, packs, and runs the
    XNOR-popcount GEMM.  Returns (M, N) int32.

    backend: 'pallas' | 'jnp' | 'ref' | 'auto' (pallas on TPU, jnp else).
    Packing goes through the :func:`bitpack` dispatcher, so the pallas
    backend packs with the pallas kernel (it used to fall back to the
    host-side ``pack_bits`` even when a Pallas GEMM followed).
    """
    backend = _resolve(backend)
    if backend == "ref":
        return _ref.binary_matmul_ref(a, b)
    k = a.shape[-1]
    a_p = bitpack(a, backend=backend)
    b_p = bitpack(b, backend=backend)
    return binary_matmul_packed(a_p, b_p, k_true=k, backend=backend,
                                words_per_step=words_per_step)


def binary_matmul_packed(a_packed: jax.Array, b_packed: jax.Array, *,
                         k_true: int, backend: str = "auto",
                         words_per_step: int | None = None) -> jax.Array:
    """Binary GEMM on pre-packed operands (weights packed once, paper C2).

    ``words_per_step`` packed words are contracted per kernel loop step
    (pallas backend; ``None`` auto-sizes).  The output is invariant to
    it; invalid values (non-divisors of the 128-lane group) raise like
    the conv ``block_oh``/``block_n`` knobs do.
    """
    backend = _resolve(backend)
    if backend == "pallas":
        return _bmm.binary_matmul_packed(
            a_packed, b_packed, k_true=k_true,
            words_per_step=_words_per_step(words_per_step),
            interpret=not _on_tpu())
    return B.packed_matmul(a_packed, b_packed, k_true)


def binary_matmul_bn_sign_packed(a_packed: jax.Array, b_packed: jax.Array,
                                 tau: jax.Array, flip: jax.Array, *,
                                 k_true: int, backend: str = "auto",
                                 words_per_step: int | None = None
                                 ) -> jax.Array:
    """Fused packed GEMM + BN-sign-fold + re-bitpack (the dense analogue
    of ``binary_conv2d_bn_sign_packed``).

    Returns (M, ceil(N/32)) uint32 — the next binary layer's input,
    without the (M, N) int32 activation ever leaving the kernel.
    Bit-identical to ``bn_sign_pack(binary_matmul_packed(...))``.
    """
    backend = _resolve(backend)
    if backend == "pallas":
        return _bmm.binary_matmul_bn_sign_packed(
            a_packed, b_packed, tau, flip, k_true=k_true,
            words_per_step=_words_per_step(words_per_step),
            interpret=not _on_tpu())
    return _ref.binary_matmul_bn_sign_packed_ref(a_packed, b_packed, tau,
                                                 flip, k_true)


def binary_dense_stack_packed(stages: list, x_packed: jax.Array, *,
                              backend: str = "auto",
                              resident: bool | None = None,
                              block_m: int | None = None,
                              words_per_step: int | None = None,
                              vmem_budget_bytes: int | None = None
                              ) -> jax.Array:
    """A chain of hidden dense layers, each GEMM + BN-sign + re-bitpack.

    ``stages``: list of ``{"w_packed", "k_true", "tau", "flip"}``;
    ``x_packed``: (M, Kw₀) packed activation.  Returns the packed uint32
    activation after the last stage — bit-identical to chaining
    :func:`binary_matmul_bn_sign_packed`.

    pallas backend: when the whole stack's weights + folded thresholds
    fit the VMEM budget (``dense_stack_fits_vmem``), the stack runs as
    ONE kernel launch with an in-kernel stage loop over the resident
    weights; otherwise it falls back to one fused launch per layer.
    ``resident`` overrides the auto decision (True forces the single
    launch, False forces per-layer).
    """
    backend = _resolve(backend)
    if not stages:                  # empty stack: identity on every backend
        return x_packed
    if backend != "pallas":
        return _ref.binary_dense_stack_packed_ref(stages, x_packed)
    weights = [s["w_packed"] for s in stages]
    bm = _bmm.STACK_BLOCK_M if block_m is None else block_m
    ws = _words_per_step(words_per_step)
    if resident is None:
        resident = _bmm.dense_stack_fits_vmem(
            weights, budget=vmem_budget_bytes, block_m=bm,
            words_per_step=ws)
    if resident:
        return _bmm.binary_dense_stack_packed(
            x_packed, weights,
            [s["tau"] for s in stages], [s["flip"] for s in stages],
            k_trues=tuple(int(s["k_true"]) for s in stages),
            block_m=bm, words_per_step=ws, interpret=not _on_tpu())
    h = x_packed
    for s in stages:
        h = _bmm.binary_matmul_bn_sign_packed(
            h, s["w_packed"], s["tau"], s["flip"], k_true=s["k_true"],
            words_per_step=ws, interpret=not _on_tpu())
    return h


def bitpack(x: jax.Array, *, backend: str = "auto") -> jax.Array:
    """Sign-binarize + pack along the last axis -> uint32 words."""
    backend = _resolve(backend)
    if backend == "pallas":
        orig_shape = x.shape
        x2 = x.reshape(-1, orig_shape[-1])
        out = _bp.bitpack(x2, interpret=not _on_tpu())
        return out.reshape(*orig_shape[:-1], out.shape[-1])
    return B.pack_bits(x)


# ---------------------------------------------------------------------------
# Binary 2-D convolution (kernels/binary_conv.py) + fused epilogue
# ---------------------------------------------------------------------------

def binary_conv2d_packed(plan: dict, x_packed: jax.Array, *,
                         backend: str = "auto",
                         block_oh: int | None = None,
                         block_n: int | None = None) -> jax.Array:
    """Packed binary conv on a ``make_conv_plan`` plan.  Returns int32

    (B, OH, OW, C_out) — exact integer conv of the ±1 tensors with true
    zero padding (pad-as-(−1) + correction, paper C5).

    backend: 'pallas' (in-kernel im2col, no patch matrix in HBM) |
    'jnp'/'ref' (im2col outside, the pre-subsystem path) | 'auto'.
    ``block_oh``/``block_n`` tile the Pallas grid over (OH rows, C_out);
    ``None`` auto-sizes.  ``block_n`` must be a multiple of 128 — invalid
    values raise instead of being silently clamped up.
    """
    backend = _resolve(backend)
    if backend == "pallas":
        return _bconv.binary_conv2d_packed(
            x_packed, plan["w_packed"], plan["correction"],
            kh=plan["kh"], kw=plan["kw"], stride=plan["stride"],
            pads=plan["pads"], out_hw=plan["out_hw"], c_out=plan["c_out"],
            k_true=plan["k_true"], block_oh=block_oh, block_n=block_n,
            interpret=not _on_tpu())
    return _ref.binary_conv2d_packed_ref(
        x_packed, plan["w_packed"], plan["correction"], kh=plan["kh"],
        kw=plan["kw"], stride=plan["stride"], pads=plan["pads"],
        c_out=plan["c_out"], k_true=plan["k_true"])


def binary_conv2d_bn_sign_packed(plan: dict, folded: dict,
                                 x_packed: jax.Array, *,
                                 backend: str = "auto",
                                 block_oh: int | None = None,
                                 block_n: int | None = None) -> jax.Array:
    """Fused conv + BN-sign-fold + re-bitpack.  Returns packed uint32

    (B, OH, OW, ceil(C_out/32)) — the next binary conv layer's input,
    without the int32 activation ever leaving the kernel un-packed.
    ``folded``: {"tau", "flip"} from ``core.binary_layers.fold_bn_sign``.
    Block knobs as in :func:`binary_conv2d_packed`.
    """
    backend = _resolve(backend)
    if backend == "pallas":
        return _bconv.binary_conv2d_bn_sign_packed(
            x_packed, plan["w_packed"], plan["correction"], folded["tau"],
            folded["flip"], kh=plan["kh"], kw=plan["kw"],
            stride=plan["stride"], pads=plan["pads"], out_hw=plan["out_hw"],
            c_out=plan["c_out"], k_true=plan["k_true"], block_oh=block_oh,
            block_n=block_n, interpret=not _on_tpu())
    return _ref.binary_conv2d_bn_sign_packed_ref(
        x_packed, plan["w_packed"], plan["correction"], folded["tau"],
        folded["flip"], kh=plan["kh"], kw=plan["kw"], stride=plan["stride"],
        pads=plan["pads"], c_out=plan["c_out"], k_true=plan["k_true"])


def bitplane_conv2d_packed(plan: dict, x_uint8: jax.Array, *,
                           backend: str = "auto",
                           block_oh: int | None = None,
                           block_n: int | None = None) -> jax.Array:
    """First-layer fixed-precision conv (paper C4) on a

    ``make_bitplane_conv_plan`` plan.  ``x_uint8``: (B, H, W, C_in) raw
    integer input.  Returns (B, OH, OW, C_out) int32 == the exact integer
    conv of the raw input against sign(W) with true zero padding.

    'pallas': plane extraction/packing is pure jnp bit ops
    (``pack_bitplanes_uint8``) and the conv is ONE kernel launch — an
    in-kernel plane loop over the VMEM-resident plane stack with the 2^i
    weighting and rowsum pad correction folded into the epilogue.
    'jnp'/'ref': the pre-fusion sequential 8-plane oracle.
    """
    backend = _resolve(backend)
    nbits = plan["nbits"]
    if backend == "pallas":
        x_planes = B.pack_bitplanes_uint8(x_uint8, nbits)
        return _bconv.bitplane_conv2d_packed(
            x_planes, plan["w_packed"], plan["rowsum"], kh=plan["kh"],
            kw=plan["kw"], stride=plan["stride"], pads=plan["pads"],
            out_hw=plan["out_hw"], c_out=plan["c_out"],
            k_true=plan["k_true"], nbits=nbits, block_oh=block_oh,
            block_n=block_n, interpret=not _on_tpu())
    return _ref.bitplane_conv2d_packed_ref(
        x_uint8, plan["w_packed"], plan["rowsum"], kh=plan["kh"],
        kw=plan["kw"], stride=plan["stride"], pads=plan["pads"],
        c_out=plan["c_out"], k_true=plan["k_true"], nbits=nbits)


def bn_sign_pack(x: jax.Array, tau: jax.Array, flip: jax.Array, *,
                 backend: str = "auto") -> jax.Array:
    """Fused sign(BN(x)) + bit-pack along the last axis.

    ``x``: (..., C) int32 (or any real) raw layer output.  Returns
    (..., ceil(C/32)) uint32 — bit-identical to
    ``pack_bits(apply_bn_sign_folded({tau, flip}, x))``.
    """
    backend = _resolve(backend)
    lead = x.shape[:-1]
    if backend == "pallas":
        x2 = x.reshape(-1, x.shape[-1])
        out = _fe.bn_sign_pack(x2, tau, flip, interpret=not _on_tpu())
        return out.reshape(*lead, out.shape[-1])
    return _ref.bn_sign_pack_ref(x, tau, flip)


def binary_conv2d(x: jax.Array, w: jax.Array, *, stride: int = 1,
                  padding: str = "SAME", backend: str = "auto",
                  block_oh: int | None = None,
                  block_n: int | None = None) -> jax.Array:
    """End-to-end binary conv on real-valued operands (mirrors

    ``binary_matmul``): sign-binarizes + channel-packs ``x``, packs ``w``
    per tap, and runs the XNOR-popcount conv.

    ``x``: (B, H, W, C_in) real, ``w``: (C_out, KH, KW, C_in) real.
    Returns (B, OH, OW, C_out) int32 == the integer dots of
    ``conv(sign(x), sign(w))`` with true zero padding.
    ``block_oh``/``block_n`` forward to :func:`binary_conv2d_packed`.
    """
    plan = _bconv.make_conv_plan(w, input_hw=x.shape[1:3], stride=stride,
                                 padding=padding)
    x2 = x.reshape(-1, x.shape[-1])
    x_p = bitpack(x2, backend=backend).reshape(*x.shape[:-1], -1)
    return binary_conv2d_packed(plan, x_p, backend=backend,
                                block_oh=block_oh, block_n=block_n)
