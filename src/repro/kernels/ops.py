"""Public jit'd wrappers for the Pallas kernels.

On a real TPU the kernels run compiled; on CPU (this container, CI) they
run in ``interpret=True`` mode, which executes the kernel body in Python
with identical semantics — the correctness contract is enforced against
``ref.py`` either way.

Shared argument semantics (every dispatcher in this module):

* ``backend``: ``'pallas'`` (the kernel subsystem), ``'jnp'`` (pure-jnp
  path), ``'ref'`` (the slow oracle), or ``'auto'`` (pallas on TPU, jnp
  elsewhere).  Any other string raises ``ValueError`` — backend typos
  never silently fall through to a different implementation
  (see :func:`_resolve`).
* Grid/blocking knobs (``block_oh``, ``block_n``, ``block_m``,
  ``block_kw``, ``words_per_step``) only affect the pallas backend, are
  *validated* rather than clamped, and never change the output
  (property-tested).  ``None`` always means "auto-size".
* **VMEM preflight**: before any Pallas launch, the dispatcher runs the
  shape-only static estimator (``analysis.vmem``) against the per-core
  budget (16 MiB default; ``REPRO_VMEM_BUDGET_BYTES`` overrides).  An
  over-budget launch raises ``analysis.vmem.VmemBudgetError`` with a
  per-term breakdown at Python call time — before jit traces, compiles,
  or (on CPU) interprets anything.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro import telemetry
from repro.analysis import vmem as _vmem
from repro.core import binarize as B
from repro.kernels import binary_attention as _batt
from repro.kernels import binary_conv as _bconv
from repro.kernels import binary_matmul as _bmm
from repro.kernels import bitpack as _bp
from repro.kernels import fused_epilogue as _fe
from repro.kernels import ref as _ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(backend: str) -> str:
    """Single point of backend resolution for EVERY public dispatcher.

    Re-implementing the 'auto' check inline used to let a typo like
    ``backend="pallsa"`` fall through to the jnp path silently; routing
    everything here makes an unknown backend a loud ValueError.
    """
    if backend == "auto":
        return "pallas" if _on_tpu() else "jnp"
    if backend not in ("pallas", "jnp", "ref"):
        raise ValueError(f"unknown backend {backend!r}")
    return backend


def _words_per_step(words_per_step: int | None) -> int:
    return (_bmm.DEFAULT_WORDS_PER_STEP if words_per_step is None
            else words_per_step)


def dispatch_batch(m: int, kw_words: int) -> str:
    """The GEMV-vs-GEMM batch-dispatch seam (paper §6.2).

    Given a flush of ``m`` rows contracting ``kw_words`` packed uint32
    words of K, returns which dense grid the Pallas backend lowers to:

    * ``'gemv'`` — ``m`` ≤ 8 (the TPU sublane minimum) and the
      lane-padded K extent fits the resident activation block
      (≤ 4096 words = 128K logical K).  N-major 1-D grid: the packed
      activation is pinned in VMEM, weight row blocks stream past it,
      no cross-step accumulator.  The single-query / small-batch
      serving path.
    * ``'gemm'`` — everything else.  The (M tiles, N tiles, K blocks)
      blocked grid with a VMEM accumulator.

    This is the ONE routing rule: :func:`binary_matmul_packed`,
    :func:`binary_matmul_bn_sign_packed`, and the serving layer
    (``train.serve.PackedInferenceServer``) all consult it, so a
    batching policy can never disagree with the kernels about which
    launch shape a flush takes (asserted on traced grids in
    ``tests/test_serve_batching.py``).

    Raises ``ValueError`` if ``m`` or ``kw_words`` is not a positive
    integer.

    Every routing decision bumps ``ops.dispatch.gemv`` /
    ``ops.dispatch.gemm`` on the process-wide telemetry registry
    (``telemetry.default()``) — dispatch has no object to hang a
    registry on, and the counter pair is the CI invariant "a batch-1
    serve never took the GEMM grid" (``docs/observability.md``).
    """
    if m < 1 or kw_words < 1:
        raise ValueError(
            f"dispatch_batch needs positive (m, kw_words), got "
            f"({m}, {kw_words})")
    route = _bmm.dispatch_batch(m, kw_words)
    telemetry.default().metrics.counter(f"ops.dispatch.{route}").inc()
    return route


def binary_matmul(a: jax.Array, b: jax.Array, *, backend: str = "auto",
                  words_per_step: int | None = None) -> jax.Array:
    """End-to-end binary GEMM on real-valued operands.

    ``a``: (M, K), ``b``: (N, K).  Sign-binarizes both, packs, and runs the
    XNOR-popcount GEMM.  Returns (M, N) int32.

    backend: 'pallas' | 'jnp' | 'ref' | 'auto' (pallas on TPU, jnp else);
    unknown strings raise ``ValueError``.  Packing goes through the
    :func:`bitpack` dispatcher, so the pallas backend packs with the
    pallas kernel (it used to fall back to the host-side ``pack_bits``
    even when a Pallas GEMM followed).  ``words_per_step`` forwards to
    :func:`binary_matmul_packed` (pallas only; must be a positive
    divisor of 128 — anything else raises ``ValueError``).
    """
    backend = _resolve(backend)
    if backend == "ref":
        return _ref.binary_matmul_ref(a, b)
    k = a.shape[-1]
    a_p = bitpack(a, backend=backend)
    b_p = bitpack(b, backend=backend)
    return binary_matmul_packed(a_p, b_p, k_true=k, backend=backend,
                                words_per_step=words_per_step)


def binary_matmul_packed(a_packed: jax.Array, b_packed: jax.Array, *,
                         k_true: int, backend: str = "auto",
                         words_per_step: int | None = None) -> jax.Array:
    """Binary GEMM on pre-packed operands (weights packed once, paper C2).

    ``a_packed``: (M, Kw) uint32, ``b_packed``: (N, Kw) uint32; ``k_true``
    is the logical K before packing.  Returns (M, N) int32.

    backend: 'pallas' | 'jnp' | 'ref' | 'auto'; unknown strings raise
    ``ValueError``.  On the pallas backend ``words_per_step`` packed
    words are contracted per kernel loop step (``None`` auto-sizes to
    8); the output is invariant to it, and invalid values — anything
    that is not a positive divisor of the 128-lane group — raise
    ``ValueError`` like the conv ``block_oh``/``block_n`` knobs do.
    M ≤ 8 with a VMEM-sized K lowers to the N-major GEMV grid
    (:func:`dispatch_batch`).
    """
    backend = _resolve(backend)
    if backend == "pallas":
        ws = _words_per_step(words_per_step)
        _vmem.preflight(_vmem.gemm_estimate(
            a_packed.shape[0], b_packed.shape[0], a_packed.shape[1],
            words_per_step=ws))
        return _bmm.binary_matmul_packed(
            a_packed, b_packed, k_true=k_true, words_per_step=ws,
            interpret=not _on_tpu())
    return B.packed_matmul(a_packed, b_packed, k_true)


def binary_matmul_bn_sign_packed(a_packed: jax.Array, b_packed: jax.Array,
                                 tau: jax.Array, flip: jax.Array, *,
                                 k_true: int, backend: str = "auto",
                                 words_per_step: int | None = None
                                 ) -> jax.Array:
    """Fused packed GEMM + BN-sign-fold + re-bitpack (the dense analogue
    of ``binary_conv2d_bn_sign_packed``).

    ``tau``/``flip``: the per-output-channel folded BN threshold from
    ``core.binary_layers.fold_bn_sign``.  Returns (M, ceil(N/32)) uint32
    — the next binary layer's input, without the (M, N) int32 activation
    ever leaving the kernel.  Bit-identical to
    ``bn_sign_pack(binary_matmul_packed(...))``.

    backend: 'pallas' | 'jnp' | 'ref' | 'auto' ('jnp' and 'ref' both run
    the pure oracle); unknown strings raise ``ValueError``.
    ``words_per_step`` as in :func:`binary_matmul_packed` (non-divisors
    of 128 raise ``ValueError``).  M ≤ 8 takes the fused GEMV grid
    (:func:`dispatch_batch`).
    """
    backend = _resolve(backend)
    if backend == "pallas":
        ws = _words_per_step(words_per_step)
        _vmem.preflight(_vmem.gemm_estimate(
            a_packed.shape[0], b_packed.shape[0], a_packed.shape[1],
            words_per_step=ws, fused=True))
        return _bmm.binary_matmul_bn_sign_packed(
            a_packed, b_packed, tau, flip, k_true=k_true,
            words_per_step=ws, interpret=not _on_tpu())
    return _ref.binary_matmul_bn_sign_packed_ref(a_packed, b_packed, tau,
                                                 flip, k_true)


def binary_dense_stack_packed(stages: list, x_packed: jax.Array, *,
                              backend: str = "auto",
                              resident: bool | None = None,
                              block_m: int | None = None,
                              words_per_step: int | None = None,
                              vmem_budget_bytes: int | None = None
                              ) -> jax.Array:
    """A chain of hidden dense layers, each GEMM + BN-sign + re-bitpack.

    ``stages``: list of ``{"w_packed", "k_true", "tau", "flip"}``;
    ``x_packed``: (M, Kw₀) packed activation.  Returns the packed uint32
    activation after the last stage — bit-identical to chaining
    :func:`binary_matmul_bn_sign_packed`.  An empty ``stages`` list is
    the identity on every backend.

    backend: 'pallas' | 'jnp' | 'ref' | 'auto'; unknown strings raise
    ``ValueError``.  pallas backend: when the whole stack's weights +
    folded thresholds fit the VMEM budget
    (``binary_matmul.dense_stack_fits_vmem``; override the default
    8 MiB with ``vmem_budget_bytes``), the stack runs as ONE kernel
    launch with an in-kernel stage loop over the resident weights;
    otherwise it falls back to one fused launch per layer.  ``resident``
    overrides the auto decision (True forces the single launch, False
    forces per-layer).  ``block_m`` tiles the M axis (must be a positive
    multiple of 8 — the TPU sublane granularity — else ``ValueError``);
    ``words_per_step`` as in :func:`binary_matmul_packed` (non-divisors
    of 128 raise ``ValueError``).
    """
    backend = _resolve(backend)
    if not stages:                  # empty stack: identity on every backend
        return x_packed
    if backend != "pallas":
        return _ref.binary_dense_stack_packed_ref(stages, x_packed)
    weights = [s["w_packed"] for s in stages]
    bm = _bmm.STACK_BLOCK_M if block_m is None else block_m
    _fe.check_block_sublanes("block_m", bm)
    ws = _words_per_step(words_per_step)
    if resident is None:
        resident = _bmm.dense_stack_fits_vmem(
            weights, budget=vmem_budget_bytes, block_m=bm,
            words_per_step=ws)
    if resident:
        _vmem.preflight(_vmem.dense_stack_estimate(
            [tuple(w.shape) for w in weights], block_m=bm,
            words_per_step=ws))
        return _bmm.binary_dense_stack_packed(
            x_packed, weights,
            [s["tau"] for s in stages], [s["flip"] for s in stages],
            k_trues=tuple(int(s["k_true"]) for s in stages),
            block_m=bm, words_per_step=ws, interpret=not _on_tpu())
    h = x_packed
    for s in stages:
        _vmem.preflight(_vmem.gemm_estimate(
            h.shape[0], s["w_packed"].shape[0], s["w_packed"].shape[1],
            words_per_step=ws, fused=True))
        h = _bmm.binary_matmul_bn_sign_packed(
            h, s["w_packed"], s["tau"], s["flip"], k_true=s["k_true"],
            words_per_step=ws, interpret=not _on_tpu())
    return h


def binary_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                     causal: bool = True, window: int | None = None,
                     attn_softcap: float | None = None, q_offset: int = 0,
                     backend: str = "auto", block_q: int | None = None,
                     block_kv: int | None = None,
                     words_per_step: int | None = None) -> jax.Array:
    """Flash-style blocked binary attention (``kernels/binary_attention``).

    ``q``: (B, Sq, Hq, D), ``k``: (B, Skv, Hkv, D), ``v``:
    (B, Skv, Hkv, Dv) — all real-valued.  Q and K are sign-binarized and
    packed along head_dim through the :func:`bitpack` dispatcher; every
    QKᵀ score is then the XNOR-popcount identity
    (D − 2·popcount) · D^(−1/2), softmaxed online over KV tiles (the
    (Sq, Skv) score matrix never hits HBM on the pallas backend), and
    averaged against the float V.  ``Hq % Hkv == 0`` groups query heads
    over KV heads (GQA/MQA).  ``causal`` masks qpos < kpos (``q_offset``
    aligns decode queries), ``window`` masks qpos − kpos ≥ window
    (sliding-window local layers), ``attn_softcap`` applies the logit
    tanh cap before masking.  Returns (B, Sq, Hq, Dv) float32.

    backend: 'pallas' | 'jnp' | 'ref' | 'auto' ('jnp'/'ref' both run
    ``ref.binary_attention_ref``, the exact-softmax oracle); unknown
    strings raise ``ValueError``.  Block knobs (pallas only) validate by
    raising, like ``block_oh``/``block_n``/``words_per_step`` everywhere
    else: ``block_q`` must be a positive multiple of 8 (sublanes),
    ``block_kv`` a positive multiple of 128 (lanes), ``words_per_step``
    a positive divisor of 128.  The output is invariant to all three
    (property-tested).  ``window`` must be a positive int on every
    backend.
    """
    if window is not None and window < 1:
        raise ValueError(f"window must be a positive int, got {window!r}")
    backend = _resolve(backend)
    if backend != "pallas":
        return _ref.binary_attention_ref(
            q, k, v, causal=causal, window=window,
            attn_softcap=attn_softcap, q_offset=q_offset)
    d = q.shape[-1]
    _vmem.preflight(_vmem.attention_estimate(
        q.shape[0], q.shape[2], q.shape[1], k.shape[1],
        B.packed_width(d), v.shape[-1],
        block_q=_batt.DEFAULT_BLOCK_Q if block_q is None else block_q,
        block_kv=_batt.DEFAULT_BLOCK_KV if block_kv is None else block_kv))
    q_p = bitpack(q, backend=backend)
    k_p = bitpack(k, backend=backend)
    return _batt.binary_attention_packed(
        q_p, k_p, v, d_true=d, causal=causal, window=window,
        attn_softcap=attn_softcap, q_offset=q_offset, block_q=block_q,
        block_kv=block_kv, words_per_step=_words_per_step(words_per_step),
        interpret=not _on_tpu())


def bitpack(x: jax.Array, *, backend: str = "auto") -> jax.Array:
    """Sign-binarize + pack along the last axis -> uint32 words.

    ``x``: (..., K) real-valued; values ≥ 0 encode to bit 1, < 0 to
    bit 0, LSB-first, 32 per word.  Returns (..., ceil(K/32)) uint32
    with zero-bit tails (exact under the XOR-popcount identity, see
    ``docs/kernels.md``).

    backend: 'pallas' | 'jnp' | 'ref' | 'auto' ('jnp'/'ref' both run
    ``binarize.pack_bits``); unknown strings raise ``ValueError``.
    """
    backend = _resolve(backend)
    if backend == "pallas":
        orig_shape = x.shape
        x2 = x.reshape(-1, orig_shape[-1])
        _vmem.preflight(_vmem.bitpack_estimate(x2.shape[0], x2.shape[1]))
        out = _bp.bitpack(x2, interpret=not _on_tpu())
        return out.reshape(*orig_shape[:-1], out.shape[-1])
    return B.pack_bits(x)


# ---------------------------------------------------------------------------
# Binary 2-D convolution (kernels/binary_conv.py) + fused epilogue
# ---------------------------------------------------------------------------

def _conv_preflight(plan: dict, x: jax.Array, *, block_oh: int | None,
                    block_n: int | None, fused: bool = False,
                    nbits: int = 1) -> None:
    """Shared VMEM preflight for the three conv dispatchers: resolve the
    block knobs exactly like the wrapper will, then budget-check the
    launch (spatial axes are the last three of ``x`` for both the
    (B, H, W, Cw) image and the (nbits, B, H, W, Cw) plane stack)."""
    bn = _bconv.resolve_block_n(block_n, plan["c_out"])
    oh, ow = plan["out_hw"]
    boh = _bconv.resolve_block_oh(block_oh, oh, ow)
    (pt, pb), (pl, pr) = plan["pads"]
    h, w, cw = x.shape[-3], x.shape[-2], x.shape[-1]
    batch = x.shape[0] if nbits == 1 else x.shape[1]
    _vmem.preflight(_vmem.conv_estimate(
        batch, (h + pt + pb, w + pl + pr), cw, plan["kh"], plan["kw"],
        plan["c_out"], plan["out_hw"], block_n=bn, block_oh=boh,
        fused=fused, nbits=nbits))


def binary_conv2d_packed(plan: dict, x_packed: jax.Array, *,
                         backend: str = "auto",
                         block_oh: int | None = None,
                         block_n: int | None = None) -> jax.Array:
    """Packed binary conv on a ``make_conv_plan`` plan.  Returns int32

    (B, OH, OW, C_out) — exact integer conv of the ±1 tensors with true
    zero padding (pad-as-(−1) + correction, paper C5).

    backend: 'pallas' (in-kernel im2col, no patch matrix in HBM) |
    'jnp'/'ref' (im2col outside, the pre-subsystem path) | 'auto';
    unknown strings raise ``ValueError``.  ``block_oh``/``block_n`` tile
    the Pallas grid over (OH rows, C_out); ``None`` auto-sizes.
    ``block_oh`` must be a positive multiple of 8 (sublane granularity)
    and ``block_n`` a positive multiple of 128 (lane granularity) —
    invalid values raise ``ValueError`` instead of being silently
    clamped up.  The output is invariant to both (property-tested).
    """
    backend = _resolve(backend)
    if backend == "pallas":
        _conv_preflight(plan, x_packed, block_oh=block_oh, block_n=block_n)
        return _bconv.binary_conv2d_packed(
            x_packed, plan["w_packed"], plan["correction"],
            kh=plan["kh"], kw=plan["kw"], stride=plan["stride"],
            pads=plan["pads"], out_hw=plan["out_hw"], c_out=plan["c_out"],
            k_true=plan["k_true"], block_oh=block_oh, block_n=block_n,
            interpret=not _on_tpu())
    return _ref.binary_conv2d_packed_ref(
        x_packed, plan["w_packed"], plan["correction"], kh=plan["kh"],
        kw=plan["kw"], stride=plan["stride"], pads=plan["pads"],
        c_out=plan["c_out"], k_true=plan["k_true"])


def binary_conv2d_bn_sign_packed(plan: dict, folded: dict,
                                 x_packed: jax.Array, *,
                                 backend: str = "auto",
                                 block_oh: int | None = None,
                                 block_n: int | None = None) -> jax.Array:
    """Fused conv + BN-sign-fold + re-bitpack.  Returns packed uint32

    (B, OH, OW, ceil(C_out/32)) — the next binary conv layer's input,
    without the int32 activation ever leaving the kernel un-packed.
    ``folded``: {"tau", "flip"} from ``core.binary_layers.fold_bn_sign``.

    backend and block knobs exactly as in :func:`binary_conv2d_packed`
    (unknown backends and off-granularity blocks raise ``ValueError``);
    the 128-lane ``block_n`` check also lands every output block on a
    32-bit pack seam.
    """
    backend = _resolve(backend)
    if backend == "pallas":
        _conv_preflight(plan, x_packed, block_oh=block_oh, block_n=block_n,
                        fused=True)
        return _bconv.binary_conv2d_bn_sign_packed(
            x_packed, plan["w_packed"], plan["correction"], folded["tau"],
            folded["flip"], kh=plan["kh"], kw=plan["kw"],
            stride=plan["stride"], pads=plan["pads"], out_hw=plan["out_hw"],
            c_out=plan["c_out"], k_true=plan["k_true"], block_oh=block_oh,
            block_n=block_n, interpret=not _on_tpu())
    return _ref.binary_conv2d_bn_sign_packed_ref(
        x_packed, plan["w_packed"], plan["correction"], folded["tau"],
        folded["flip"], kh=plan["kh"], kw=plan["kw"], stride=plan["stride"],
        pads=plan["pads"], c_out=plan["c_out"], k_true=plan["k_true"])


def bitplane_conv2d_packed(plan: dict, x_uint8: jax.Array, *,
                           backend: str = "auto",
                           block_oh: int | None = None,
                           block_n: int | None = None) -> jax.Array:
    """First-layer fixed-precision conv (paper C4) on a

    ``make_bitplane_conv_plan`` plan.  ``x_uint8``: (B, H, W, C_in) raw
    integer input.  Returns (B, OH, OW, C_out) int32 == the exact integer
    conv of the raw input against sign(W) with true zero padding.

    backend: 'pallas' — plane extraction/packing is pure jnp bit ops
    (``pack_bitplanes_uint8``) and the conv is ONE kernel launch (an
    in-kernel plane loop over the VMEM-resident plane stack with the 2^i
    weighting and rowsum pad correction folded into the epilogue);
    'jnp'/'ref' — the pre-fusion sequential 8-plane oracle; 'auto' as
    everywhere.  Unknown backends raise ``ValueError``; ``block_oh`` /
    ``block_n`` validate exactly as in :func:`binary_conv2d_packed`
    (``ValueError`` off sublane/lane granularity).
    """
    backend = _resolve(backend)
    nbits = plan["nbits"]
    if backend == "pallas":
        x_planes = B.pack_bitplanes_uint8(x_uint8, nbits)
        _conv_preflight(plan, x_planes, block_oh=block_oh, block_n=block_n,
                        nbits=nbits)
        return _bconv.bitplane_conv2d_packed(
            x_planes, plan["w_packed"], plan["rowsum"], kh=plan["kh"],
            kw=plan["kw"], stride=plan["stride"], pads=plan["pads"],
            out_hw=plan["out_hw"], c_out=plan["c_out"],
            k_true=plan["k_true"], nbits=nbits, block_oh=block_oh,
            block_n=block_n, interpret=not _on_tpu())
    return _ref.bitplane_conv2d_packed_ref(
        x_uint8, plan["w_packed"], plan["rowsum"], kh=plan["kh"],
        kw=plan["kw"], stride=plan["stride"], pads=plan["pads"],
        c_out=plan["c_out"], k_true=plan["k_true"], nbits=nbits)


def bn_sign_pack(x: jax.Array, tau: jax.Array, flip: jax.Array, *,
                 backend: str = "auto") -> jax.Array:
    """Fused sign(BN(x)) + bit-pack along the last axis.

    ``x``: (..., C) int32 (or any real) raw layer output; ``tau``/``flip``
    the folded BN threshold (``fold_bn_sign``).  Returns
    (..., ceil(C/32)) uint32 — bit-identical to
    ``pack_bits(apply_bn_sign_folded({tau, flip}, x))``.

    backend: 'pallas' | 'jnp' | 'ref' | 'auto' ('jnp'/'ref' both run the
    oracle); unknown strings raise ``ValueError``.
    """
    backend = _resolve(backend)
    lead = x.shape[:-1]
    if backend == "pallas":
        x2 = x.reshape(-1, x.shape[-1])
        _vmem.preflight(_vmem.bn_sign_pack_estimate(x2.shape[0],
                                                    x2.shape[1]))
        out = _fe.bn_sign_pack(x2, tau, flip, interpret=not _on_tpu())
        return out.reshape(*lead, out.shape[-1])
    return _ref.bn_sign_pack_ref(x, tau, flip)


def binary_conv2d(x: jax.Array, w: jax.Array, *, stride: int = 1,
                  padding: str = "SAME", backend: str = "auto",
                  block_oh: int | None = None,
                  block_n: int | None = None) -> jax.Array:
    """End-to-end binary conv on real-valued operands (mirrors

    ``binary_matmul``): sign-binarizes + channel-packs ``x``, packs ``w``
    per tap, and runs the XNOR-popcount conv.

    ``x``: (B, H, W, C_in) real, ``w``: (C_out, KH, KW, C_in) real.
    Returns (B, OH, OW, C_out) int32 == the integer dots of
    ``conv(sign(x), sign(w))`` with true zero padding.

    backend as everywhere (unknown strings raise ``ValueError``);
    ``block_oh``/``block_n`` forward to :func:`binary_conv2d_packed`
    with the same validation (``ValueError`` off sublane/lane
    granularity).
    """
    plan = _bconv.make_conv_plan(w, input_hw=x.shape[1:3], stride=stride,
                                 padding=padding)
    x2 = x.reshape(-1, x.shape[-1])
    x_p = bitpack(x2, backend=backend).reshape(*x.shape[:-1], -1)
    return binary_conv2d_packed(plan, x_p, backend=backend,
                                block_oh=block_oh, block_n=block_n)
