"""Pallas TPU kernel: sign-binarize + bit-pack along the last axis (C1/C3).

Turns a real-valued (M, K) tensor into (M, K/32) uint32 words, LSB-first —
the activation-packing step between binary layers (the paper packs weights
once at load; *activations* must be packed every layer, so this is the
recurring packing cost the kernel optimizes; paper §6.3 notes it).

TPU layout note (paper C3 adapted): we pack the **last (feature/channel)
axis**, which is the lane axis on TPU and the axis jnp keeps contiguous —
the same "pack along channels" choice the paper makes so im2col unrolling
needs no re-layout.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import binarize as B
from repro.kernels.fused_epilogue import (check_block_lanes,
                                          check_block_sublanes)


def _bitpack_kernel(x_ref, o_ref, *, block_kw: int):
    x = x_ref[...]                                     # (bm, block_kw * 32)
    bm = x.shape[0]
    bits = (x >= 0).astype(jnp.uint32)
    bits = bits.reshape(bm, block_kw, B.WORD_BITS)
    shifts = jnp.arange(B.WORD_BITS, dtype=jnp.uint32)
    o_ref[...] = (bits << shifts).sum(axis=-1, dtype=jnp.uint32)


@functools.partial(jax.jit, static_argnames=("block_m", "block_kw",
                                             "interpret"))
def bitpack(x: jax.Array, *, block_m: int = 256, block_kw: int = 128,
            interpret: bool = False) -> jax.Array:
    """Sign-binarize + pack ``x``: (M, K) real -> (M, ceil(K/32)) uint32.

    Padded tail elements pack as 0-bits (they are materialized as -1.0,
    which encodes to bit 0 — matching ``core.binarize.pack_bits`` on the
    zero-padded bit tensor).
    """
    m, k = x.shape
    kw = B.packed_width(k)

    check_block_sublanes("block_m", block_m)
    block_m = min(block_m, _ceil_mult(m, 8))
    check_block_lanes("block_kw", block_kw)
    block_kw = min(block_kw, _ceil_mult(kw, 128))
    block_k = block_kw * B.WORD_BITS

    # Pad K with -1.0 so padded positions encode to bit 0.
    x_p = B.pad_to_multiple(x, block_k, axis=1, value=-1.0)
    x_p = B.pad_to_multiple(x_p, block_m, axis=0, value=-1.0)
    mp, kp = x_p.shape
    grid = (mp // block_m, kp // block_k)

    out = pl.pallas_call(
        functools.partial(_bitpack_kernel, block_kw=block_kw),
        grid=grid,
        in_specs=[pl.BlockSpec((block_m, block_k), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((block_m, block_kw), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, kp // B.WORD_BITS), jnp.uint32),
        interpret=interpret,
    )(x_p)
    return out[:m, :kw]


def _ceil_mult(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m
