"""Pallas TPU kernel: fused BN-sign-fold + re-bitpack epilogue.

Between binary layers the inference path is  int32 GEMM/conv output ->
sign(BN(x)) -> ±1 -> bit-pack for the next packed layer.  Done naively
that round-trips every activation through HBM three times (int32 out,
float ±1, packed words).  This kernel fuses the folded-BN threshold
compare (``fold_bn_sign``: sign(BN(x)) == flip·sign(x − tau)) with the
re-bitpack, so one pass turns the raw int32 accumulator output into the
next layer's packed uint32 words.

Used standalone after layers whose producer can't fuse the epilogue
itself (the bit-plane first layer, whose int32 output accumulates over
8 plane convs, and the dense stack); the binary-conv kernel inlines the
same epilogue directly (``binary_conv.binary_conv2d_bn_sign_packed``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import binarize as B

_LANE = 128


def check_block_lanes(name: str, value: int) -> None:
    """Reject channel-axis block sizes the TPU lane layout can't honor.

    Every channel-blocked kernel in this package tiles the minor axis in
    lane groups of 128; a user block below (or not a multiple of) that
    used to be silently clamped *up*, making the knob a no-op.  Raising
    keeps mis-tuned configs visible (tests/test_conv_properties.py).
    """
    if value < _LANE or value % _LANE != 0:
        raise ValueError(
            f"{name} must be a positive multiple of {_LANE} (TPU lane "
            f"granularity), got {value}")


def check_block_sublanes(name: str, value: int) -> None:
    """Same contract for sublane-axis (row) block sizes: multiples of 8."""
    if value < 8 or value % 8 != 0:
        raise ValueError(
            f"{name} must be a positive multiple of 8 (TPU sublane "
            f"granularity), got {value}")


def check_words_per_step(name: str, value: int) -> None:
    """Contraction-vectorization knob: packed words contracted per step.

    Must be a positive divisor of the 128-lane group so every lane-padded
    K block splits into whole steps (1, 2, 4, ..., 128).  Like the block
    knobs, invalid values raise instead of being silently adjusted
    (tests/test_dense_properties.py).
    """
    if value < 1 or _LANE % value != 0:
        raise ValueError(
            f"{name} must be a positive divisor of {_LANE} (TPU lane "
            f"granularity), got {value}")


def bn_sign_bits_to_words(y: jax.Array, tau: jax.Array,
                          flip: jax.Array) -> jax.Array:
    """The epilogue contract, shared by every kernel that inlines it.

    bit = (y >= tau) XNOR (flip > 0): the bit encoding of
    sign(BN(y)) = flip * sign(y − tau)  (core.binary_layers.fold_bn_sign),
    packed LSB-first along the last axis.  ``y``: (m, c) with c a multiple
    of 32; ``tau``/``flip``: broadcastable (1, c).
    """
    ge = y.astype(jnp.float32) >= tau
    bits = (ge == (flip > 0)).astype(jnp.uint32)
    m, c = bits.shape
    bits = bits.reshape(m, c // B.WORD_BITS, B.WORD_BITS)
    shifts = jnp.arange(B.WORD_BITS, dtype=jnp.uint32)
    return (bits << shifts).sum(axis=-1, dtype=jnp.uint32)


def pad_bn_params(tau: jax.Array, flip: jax.Array,
                  multiple: int) -> tuple[jax.Array, jax.Array]:
    """Pad per-channel tau/flip up to ``multiple`` so padded channels pack

    as 0-bits (the pack_bits tail convention): tau=+inf makes the compare
    False, flip=+1 makes the bit (False == True) == 0."""
    c = tau.shape[-1]
    tau_p = B.pad_to_multiple(tau.reshape(1, c).astype(jnp.float32),
                              multiple, 1, value=jnp.float32(jnp.inf))
    flip_p = B.pad_to_multiple(flip.reshape(1, c).astype(jnp.float32),
                               multiple, 1, value=1.0)
    return tau_p, flip_p


def _bn_sign_pack_kernel(x_ref, tau_ref, flip_ref, o_ref):
    o_ref[...] = bn_sign_bits_to_words(x_ref[...], tau_ref[...],
                                       flip_ref[...])


@functools.partial(jax.jit, static_argnames=("block_m", "block_cw",
                                             "interpret"))
def bn_sign_pack(x: jax.Array, tau: jax.Array, flip: jax.Array, *,
                 block_m: int = 256, block_cw: int = _LANE,
                 interpret: bool = False) -> jax.Array:
    """Fused sign(BN(x)) + bit-pack: (M, C) int32 -> (M, ceil(C/32)) uint32.

    ``tau``/``flip``: per-channel folded BN threshold and sign flip.
    Bit-identical to ``pack_bits(apply_bn_sign_folded({tau, flip}, x))``.
    Channels padded up to the block pack as 0-bits (tau=+inf, flip=+1),
    matching the ``pack_bits`` zero-bit tail convention.
    """
    m, c = x.shape
    cw = B.packed_width(c)

    check_block_sublanes("block_m", block_m)
    block_m = min(block_m, _ceil_mult(m, 8))
    check_block_lanes("block_cw", block_cw)
    block_cw = min(block_cw, _ceil_mult(cw, _LANE))
    block_c = block_cw * B.WORD_BITS

    x_p = B.pad_to_multiple(B.pad_to_multiple(x, block_c, 1), block_m, 0)
    tau_p, flip_p = pad_bn_params(tau, flip, block_c)
    mp, cp = x_p.shape
    grid = (mp // block_m, cp // block_c)

    out = pl.pallas_call(
        _bn_sign_pack_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_c), lambda i, j: (i, j)),
            pl.BlockSpec((1, block_c), lambda i, j: (0, j)),
            pl.BlockSpec((1, block_c), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_cw), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, cp // B.WORD_BITS), jnp.uint32),
        interpret=interpret,
    )(x_p, tau_p, flip_p)
    return out[:m, :cw]


def _ceil_mult(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m
