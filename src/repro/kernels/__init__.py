# The paper's compute hot-spots as Pallas TPU kernels (see
# docs/kernels.md): binary_matmul (the dense megakernel suite —
# vectorized XNOR-popcount GEMM, fused BN-sign-repack epilogue,
# single-launch hidden stack, GEMV serving grid), bitpack
# (sign + bit-pack), binary_conv (fused in-kernel-im2col binary conv),
# fused_epilogue (BN-sign-fold + re-bitpack).  ops.py is the
# backend-dispatch façade; ref.py holds the pure-jnp oracles.
