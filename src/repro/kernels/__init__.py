# The paper's compute hot-spots as Pallas TPU kernels (see
# docs/kernels.md): binary_matmul (XNOR-popcount GEMM), bitpack
# (sign + bit-pack), binary_conv (fused in-kernel-im2col binary conv),
# fused_epilogue (BN-sign-fold + re-bitpack).  ops.py is the
# backend-dispatch façade; ref.py holds the pure-jnp oracles.
