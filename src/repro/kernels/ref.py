"""Pure-jnp oracles for every Pallas kernel in this package.

Each ``*_ref`` function defines the exact semantics a kernel must match
bit-for-bit (integer outputs) or to float tolerance (float outputs).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import binarize as B


def binary_matmul_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """Reference binary GEMM on *real-valued* operands.

    ``a``: (M, K), ``b``: (N, K) — any real dtype.  Both are sign-binarized
    to ±1 and contracted exactly: out[m, n] = sign(a[m]) . sign(b[n]).
    Returns (M, N) int32.
    """
    a_b = B.sign_pm1(a.astype(jnp.float32))
    b_b = B.sign_pm1(b.astype(jnp.float32))
    return jnp.dot(a_b, b_b.T).astype(jnp.int32)


def binary_matmul_packed_ref(a_packed: jax.Array, b_packed: jax.Array,
                             k: int) -> jax.Array:
    """Reference packed binary GEMM (paper eq. 2) — XOR + popcount form."""
    return B.packed_matmul(a_packed, b_packed, k)


def bitpack_ref(x: jax.Array) -> jax.Array:
    """Reference sign-binarize + pack along last axis -> uint32 words."""
    return B.pack_bits(x)


def binary_matmul_bn_sign_packed_ref(a_packed: jax.Array,
                                     b_packed: jax.Array, tau: jax.Array,
                                     flip: jax.Array, k: int) -> jax.Array:
    """Reference fused dense epilogue: packed GEMM, then BN-sign + pack."""
    return bn_sign_pack_ref(B.packed_matmul(a_packed, b_packed, k), tau,
                            flip)


def binary_dense_stack_packed_ref(stages: list,
                                  x_packed: jax.Array) -> jax.Array:
    """Reference hidden dense stack: per-layer fused epilogue, chained.

    Defines the exact semantics of the single-launch stack kernel
    (``binary_matmul.binary_dense_stack_packed``) AND its per-layer
    fallback — both must match it bit-for-bit.
    """
    h = x_packed
    for s in stages:
        h = binary_matmul_bn_sign_packed_ref(h, s["w_packed"], s["tau"],
                                             s["flip"], s["k_true"])
    return h


def bitplane_dot_ref(x_uint8: jax.Array, w: jax.Array) -> jax.Array:
    """Reference first-layer bit-plane dot == exact integer GEMM."""
    return jnp.dot(x_uint8.astype(jnp.int32),
                   B.sign_pm1(w.astype(jnp.float32)).astype(jnp.int32).T)


# ---------------------------------------------------------------------------
# Binary conv2d (paper C5/C6) — the jnp backend AND the kernel oracle.
# This path im2cols *outside* the kernel, materializing the full
# (B·H'·W', KH·KW·Cw) patch matrix — exactly what the Pallas conv kernel
# (kernels/binary_conv.py) exists to avoid.
# ---------------------------------------------------------------------------

def extract_patches_packed(x_packed: jax.Array, kh: int, kw: int,
                           stride: int, pads) -> jax.Array:
    """im2col over channel-packed words (free-lift layout, paper C3/C6).

    ``x_packed``: (B, H, W, Cw) uint32.  Spatial zero-word padding encodes
    all-(−1) pixels — the paper's "treat pad as −1" convention.
    Returns (B, H', W', KH*KW*Cw).
    """
    xp = jnp.pad(x_packed, ((0, 0), pads[0], pads[1], (0, 0)),
                 constant_values=0)                    # 0-words == all -1
    bsz, hp, wp, cw = xp.shape
    out_h = (hp - kh) // stride + 1
    out_w = (wp - kw) // stride + 1
    cols = []
    for di in range(kh):
        for dj in range(kw):
            sl = xp[:, di:di + out_h * stride:stride,
                    dj:dj + out_w * stride:stride, :]
            cols.append(sl)
    return jnp.concatenate(cols, axis=-1)


def binary_conv2d_packed_ref(x_packed: jax.Array, w_packed: jax.Array,
                             correction: jax.Array, *, kh: int, kw: int,
                             stride: int, pads, c_out: int,
                             k_true: int) -> jax.Array:
    """Reference packed conv: im2col -> XNOR GEMM -> +correction (int32)."""
    patches = extract_patches_packed(x_packed, kh, kw, stride, pads)
    bsz, oh, ow, kcw = patches.shape
    flat = patches.reshape(bsz * oh * ow, kcw)
    out = B.packed_matmul(flat, w_packed, k_true)
    out = out.reshape(bsz, oh, ow, c_out)
    return out + correction[None]


def bitplane_conv2d_packed_ref(x_uint8: jax.Array, w_packed: jax.Array,
                               rowsum: jax.Array, *, kh: int, kw: int,
                               stride: int, pads, c_out: int, k_true: int,
                               nbits: int) -> jax.Array:
    """Reference first-layer conv (paper C4): the 8-plane SEQUENTIAL path.

    One packed conv per bit plane (plane bit b -> ±1 via 2b−1), recombined
    with the plane identity  x·w = 1/2 Σ_i 2^i (p̂_i ⊛ w + rowsum)  where
    the all-taps ``rowsum`` absorbs both the {0,1}->±1 shift and the
    zero-pad correction (pad pixels have every plane bit 0 == −1).  This
    is exactly what the model ran pre-fusion — the single-launch Pallas
    kernel (``binary_conv.bitplane_conv2d_packed``) must match it
    bit-for-bit, and both equal the integer conv of the raw input.
    """
    acc = None
    zero_corr = None
    for i in range(nbits):
        plane = ((x_uint8.astype(jnp.uint32) >> i) & 1)
        plane_pm1 = 2.0 * plane.astype(jnp.float32) - 1.0
        xp = B.pack_bits(plane_pm1)
        if zero_corr is None:
            patches = extract_patches_packed(xp, kh, kw, stride, pads)
            zero_corr = jnp.zeros(patches.shape[1:3] + (c_out,), jnp.int32)
        d = binary_conv2d_packed_ref(xp, w_packed, zero_corr, kh=kh, kw=kw,
                                     stride=stride, pads=pads, c_out=c_out,
                                     k_true=k_true)
        term = (d + rowsum[None, None, None, :]) << i
        acc = term if acc is None else acc + term
    return acc >> 1


def bn_sign_pack_ref(x: jax.Array, tau: jax.Array,
                     flip: jax.Array) -> jax.Array:
    """Reference fused BN-sign + pack: threshold to ±1, then bit-pack."""
    ge = x.astype(jnp.float32) >= tau
    pm1 = jnp.where(ge, 1.0, -1.0) * flip
    return B.pack_bits(pm1)


def binary_conv2d_bn_sign_packed_ref(x_packed: jax.Array,
                                     w_packed: jax.Array,
                                     correction: jax.Array, tau: jax.Array,
                                     flip: jax.Array, *, kh: int, kw: int,
                                     stride: int, pads, c_out: int,
                                     k_true: int) -> jax.Array:
    """Reference fused conv epilogue: conv, then BN-sign + re-bitpack."""
    y = binary_conv2d_packed_ref(x_packed, w_packed, correction, kh=kh,
                                 kw=kw, stride=stride, pads=pads,
                                 c_out=c_out, k_true=k_true)
    return bn_sign_pack_ref(y, tau, flip)


def binary_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                         causal: bool = True, window: int | None = None,
                         attn_softcap: float | None = None,
                         q_offset: int = 0) -> jax.Array:
    """Reference binary attention (the ``binary_attention`` oracle).

    ``q``: (B, Sq, Hq, D), ``k``: (B, Skv, Hkv, D), ``v``:
    (B, Skv, Hkv, Dv) — real-valued.  Q and K are sign-binarized to ±1
    (so q·k == D − 2·mismatches, the XNOR-popcount identity the kernel
    computes on packed words), scaled by D^(−1/2), optionally
    soft-capped, masked (causal keeps qpos ≥ kpos with ``q_offset``
    aligning decode queries; ``window`` keeps qpos − kpos < window),
    softmaxed *exactly* (one pass, not the online recurrence), and
    averaged against the real-valued V.  GQA: query head h attends KV
    head h // (Hq // Hkv).  Returns (B, Sq, Hq, Dv) float32 — the
    kernel matches to float tolerance (the integer score path is
    bit-exact; only the softmax association order differs).
    """
    b, sq, hq, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    assert hkv >= 1 and hq % hkv == 0, (hq, hkv)
    g = hq // hkv
    qb = B.sign_pm1(q.astype(jnp.float32))
    kb = jnp.repeat(B.sign_pm1(k.astype(jnp.float32)), g, axis=2)
    vf = jnp.repeat(v.astype(jnp.float32), g, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", qb, kb) * jnp.float32(d) ** -0.5
    if attn_softcap is not None:
        s = attn_softcap * jnp.tanh(s / attn_softcap)
    qpos = q_offset + jnp.arange(sq)[:, None]
    kpos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask = mask & (qpos >= kpos)
    if window is not None:
        mask = mask & (qpos - kpos < window)
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, vf)
