"""Pure-jnp oracles for every Pallas kernel in this package.

Each ``*_ref`` function defines the exact semantics a kernel must match
bit-for-bit (integer outputs) or to float tolerance (float outputs).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import binarize as B


def binary_matmul_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """Reference binary GEMM on *real-valued* operands.

    ``a``: (M, K), ``b``: (N, K) — any real dtype.  Both are sign-binarized
    to ±1 and contracted exactly: out[m, n] = sign(a[m]) . sign(b[n]).
    Returns (M, N) int32.
    """
    a_b = B.sign_pm1(a.astype(jnp.float32))
    b_b = B.sign_pm1(b.astype(jnp.float32))
    return jnp.dot(a_b, b_b.T).astype(jnp.int32)


def binary_matmul_packed_ref(a_packed: jax.Array, b_packed: jax.Array,
                             k: int) -> jax.Array:
    """Reference packed binary GEMM (paper eq. 2) — XOR + popcount form."""
    return B.packed_matmul(a_packed, b_packed, k)


def bitpack_ref(x: jax.Array) -> jax.Array:
    """Reference sign-binarize + pack along last axis -> uint32 words."""
    return B.pack_bits(x)


def bitplane_dot_ref(x_uint8: jax.Array, w: jax.Array) -> jax.Array:
    """Reference first-layer bit-plane dot == exact integer GEMM."""
    return jnp.dot(x_uint8.astype(jnp.int32),
                   B.sign_pm1(w.astype(jnp.float32)).astype(jnp.int32).T)
