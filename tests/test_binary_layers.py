"""Binary layer tests: pad-correction identity (C5), BN-fold, packed conv."""
from _hypothesis_compat import hypothesis, st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import binarize as B
from repro.core import binary_layers as L
from repro.kernels import ops as kops

settings = hypothesis.settings(max_examples=15, deadline=None)


def _pack_act(x_pm1):
    p = kops.bitpack(x_pm1.reshape(-1, x_pm1.shape[-1]), backend="jnp")
    return p.reshape(*x_pm1.shape[:-1], -1)


@settings
@hypothesis.given(h=st.integers(4, 10), c_in=st.integers(1, 40),
                  c_out=st.integers(1, 8), stride=st.sampled_from([1, 2]),
                  seed=st.integers(0, 2**31 - 1))
def test_conv_pad_correction_identity(h, c_in, c_out, stride, seed):
    """Paper §5.2: packed conv (pad treated as -1) + correction matrix ==
    true zero-padded conv, exactly."""
    kx, kw = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(kx, (2, h, h, c_in))
    params = L.init_binary_conv2d(kw, 3, 3, c_in, c_out)
    want = L.apply_binary_conv2d_float(params, x, stride=stride,
                                       padding="SAME")
    packed = L.pack_binary_conv2d(params, input_hw=(h, h), stride=stride,
                                  padding="SAME")
    got = L.apply_binary_conv2d_packed(packed, _pack_act(B.sign_pm1(x)),
                                       backend="jnp")
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(want).astype(np.int32))


def test_conv_valid_padding():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (1, 8, 8, 16))
    params = L.init_binary_conv2d(jax.random.fold_in(key, 1), 3, 3, 16, 4)
    want = L.apply_binary_conv2d_float(params, x, padding="VALID")
    packed = L.pack_binary_conv2d(params, input_hw=(8, 8), padding="VALID")
    got = L.apply_binary_conv2d_packed(packed, _pack_act(B.sign_pm1(x)),
                                       backend="jnp")
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(want).astype(np.int32))


@settings
@hypothesis.given(c=st.integers(1, 32), seed=st.integers(0, 2**31 - 1))
def test_bn_sign_fold(c, seed):
    """fold_bn_sign: threshold compare == sign(BN(x)) for continuous x."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    bn = {
        "gamma": jax.random.uniform(ks[0], (c,), minval=0.2, maxval=2.0)
        * jnp.where(jax.random.bernoulli(ks[4], 0.4, (c,)), -1.0, 1.0),
        "beta": jax.random.normal(ks[1], (c,)),
        "mean": jax.random.normal(ks[2], (c,)) * 5,
        "var": jax.random.uniform(ks[3], (c,), minval=0.1, maxval=3.0),
    }
    x = jax.random.normal(jax.random.fold_in(ks[0], 9), (17, c)) * 10
    want = B.sign_pm1(L.apply_batchnorm(bn, x))
    got = L.apply_bn_sign_folded(L.fold_bn_sign(bn), x)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_bitplane_dense_packed_exact():
    key = jax.random.PRNGKey(1)
    params = L.init_binary_dense(key, 50, 12)
    x = jax.random.randint(jax.random.fold_in(key, 2), (6, 50), 0,
                           256).astype(jnp.uint8)
    want = L.apply_bitplane_dense_float(params, x)
    packed = L.pack_bitplane_dense(params)
    got = L.apply_bitplane_dense_packed(packed, x, backend="jnp")
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(want).astype(np.int32))


def test_binary_dense_packed_exact():
    key = jax.random.PRNGKey(2)
    params = L.init_binary_dense(key, 70, 9)
    x = jax.random.normal(jax.random.fold_in(key, 1), (5, 70))
    want = L.apply_binary_dense_float(params, x)
    got = L.apply_binary_dense_packed(L.pack_binary_dense(params), x,
                                      backend="jnp")
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(want).astype(np.int32))


def test_maxpool_int_and_float():
    x = jnp.arange(16, dtype=jnp.int32).reshape(1, 4, 4, 1)
    y = L.maxpool2d(x, 2)
    np.testing.assert_array_equal(np.asarray(y[0, :, :, 0]),
                                  np.array([[5, 7], [13, 15]]))
    xf = x.astype(jnp.float32)
    np.testing.assert_array_equal(np.asarray(L.maxpool2d(xf, 2)),
                                  np.asarray(y).astype(np.float32))
