"""Property suite locking down the dense megakernel subsystem
(kernels/binary_matmul.py).

Invariants, sampled over the awkward-shape grid in ``strategies.py``:

* fused GEMM + BN-sign-repack epilogue == separate GEMM -> ``bn_sign_pack``
  == the float oracle, every backend, including pack-seam tails (K and N
  not multiples of 32),
* the contraction is invariant to ``words_per_step`` (plain, fused, and
  stack kernels), and invalid values raise like ``block_oh``/``block_n``,
* the single-launch hidden stack == per-layer fused launches == the jnp
  oracle, and the resident path traces to exactly ONE ``pallas_call``
  (``bmlp_forward_packed``'s hidden stack included — the acceptance
  criterion),
* the GEMV/serving path (M ≤ 8, N-major grid) is bit-exact across the
  sublane boundary,
* the block knobs of the rebuilt GEMM validate like the conv grid knobs
  (raise instead of silently clamping),
* ``apply_bitplane_dense_packed`` (first-layer dense, paper C4) == the
  float oracle on both backends — previously only exercised indirectly
  through ``bmlp_forward_packed``.
"""
from _hypothesis_compat import hypothesis, st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
import strategies as S

from repro.core import binarize as B
from repro.core import binary_layers as L
from repro.kernels import binary_matmul as BMM
from repro.kernels import ops, ref
from repro.models import cnn
from repro.utils.jaxpr import count_pallas_calls

settings = hypothesis.settings(max_examples=8, deadline=None)


def _rand_folded(key, c):
    tau = jax.random.normal(key, (c,)) * 3
    flip = jnp.where(jax.random.bernoulli(jax.random.fold_in(key, 1), 0.4,
                                          (c,)), -1.0, 1.0)
    return tau, flip


def _rand_gemm(key, m, k, n):
    a = jax.random.normal(key, (m, k))
    b = jax.random.normal(jax.random.fold_in(key, 1), (n, k))
    return a, b, B.pack_bits(a), B.pack_bits(b)


def _rand_stack(key, k_in, widths):
    stages = []
    for i, n in enumerate(widths):
        sub = jax.random.fold_in(key, 100 + i)
        w = jax.random.normal(sub, (n, k_in))
        tau, flip = _rand_folded(jax.random.fold_in(sub, 1), n)
        stages.append({"w_packed": B.pack_bits(w), "k_true": k_in,
                       "tau": tau, "flip": flip})
        k_in = n
    return stages


# ---------------------------------------------------------------------------
# Fused epilogue == separate GEMM -> bn_sign_pack == float oracle
# ---------------------------------------------------------------------------

@settings
@hypothesis.given(case=S.dense_cases(), ws=S.words_per_steps(),
                  seed=S.seeds())
def test_fused_epilogue_matches_separate_and_float(case, ws, seed):
    key = jax.random.PRNGKey(seed)
    a, b, ap, bp = _rand_gemm(key, case.m, case.k, case.n)
    tau, flip = _rand_folded(jax.random.fold_in(key, 2), case.n)
    # Float oracle: threshold + pack the exact integer GEMM.
    want = np.asarray(ref.bn_sign_pack_ref(ref.binary_matmul_ref(a, b),
                                           tau, flip))
    # Separate kernels: GEMM, then the standalone epilogue.
    sep = ops.bn_sign_pack(
        ops.binary_matmul_packed(ap, bp, k_true=case.k, backend="pallas",
                                 words_per_step=ws),
        tau, flip, backend="pallas")
    np.testing.assert_array_equal(np.asarray(sep), want,
                                  err_msg=f"separate path diverged {case}")
    for backend in ("pallas", "jnp"):
        got = ops.binary_matmul_bn_sign_packed(
            ap, bp, tau, flip, k_true=case.k, backend=backend,
            words_per_step=ws)
        np.testing.assert_array_equal(
            np.asarray(got), want,
            err_msg=f"{backend} fused epilogue diverged on {case} ws={ws}")


@settings
@hypothesis.given(case=S.dense_cases(), ws=S.words_per_steps(),
                  seed=S.seeds())
def test_gemm_invariant_to_words_per_step(case, ws, seed):
    """Any words_per_step == the single-word (pre-vectorization) scheme,
    through both the blocked-K and the GEMV grids."""
    key = jax.random.PRNGKey(seed)
    _, _, ap, bp = _rand_gemm(key, case.m, case.k, case.n)
    base = BMM.binary_matmul_packed(ap, bp, k_true=case.k, words_per_step=1,
                                    interpret=True)
    got = ops.binary_matmul_packed(ap, bp, k_true=case.k, backend="pallas",
                                   words_per_step=ws)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(base))


def test_gemv_path_bit_exact_across_sublane_boundary():
    """M = 8 takes the N-major GEMV grid, M = 9 the blocked grid — both
    must match the oracle (and each other's shared rows)."""
    key = jax.random.PRNGKey(5)
    a = jax.random.normal(key, (9, 500))
    b = jax.random.normal(jax.random.fold_in(key, 1), (300, 500))
    want = np.asarray(ref.binary_matmul_ref(a, b))
    kwp = B.packed_width(500)
    assert BMM.dispatch_batch(8, kwp) == "gemv"
    assert BMM.dispatch_batch(9, kwp) == "gemm"
    assert BMM.dispatch_batch(1, BMM._GEMV_MAX_KW + 128) == "gemm"
    for m in (1, 8, 9):
        got = BMM.binary_matmul_packed(B.pack_bits(a[:m]), B.pack_bits(b),
                                       k_true=500, interpret=True)
        np.testing.assert_array_equal(np.asarray(got), want[:m])


# ---------------------------------------------------------------------------
# Single-launch hidden stack
# ---------------------------------------------------------------------------

@settings
@hypothesis.given(m=st.sampled_from((1, 8, 13)), k_in=st.sampled_from(
    (33, 64, 100)), widths=S.dense_stack_widths(), seed=S.seeds())
def test_stack_resident_equals_per_layer_equals_oracle(m, k_in, widths,
                                                       seed):
    key = jax.random.PRNGKey(seed)
    stages = _rand_stack(key, k_in, widths)
    xp = B.pack_bits(jax.random.normal(jax.random.fold_in(key, 9),
                                       (m, k_in)))
    want = np.asarray(ref.binary_dense_stack_packed_ref(stages, xp))
    for mode in (True, False, None):
        got = ops.binary_dense_stack_packed(stages, xp, backend="pallas",
                                            resident=mode)
        np.testing.assert_array_equal(
            np.asarray(got), want,
            err_msg=f"stack resident={mode} diverged {widths} m={m}")
    got = ops.binary_dense_stack_packed(stages, xp, backend="jnp")
    np.testing.assert_array_equal(np.asarray(got), want)


def test_stack_launch_counts():
    """Resident stack == ONE pallas_call; per-layer == one per stage;
    an over-budget stack auto-falls back to per-layer."""
    key = jax.random.PRNGKey(3)
    stages = _rand_stack(key, 64, (48, 96, 40))
    xp = B.pack_bits(jax.random.normal(jax.random.fold_in(key, 9), (4, 64)))
    n_res = count_pallas_calls(
        lambda v: ops.binary_dense_stack_packed(stages, v, backend="pallas",
                                                resident=True), xp)
    n_per = count_pallas_calls(
        lambda v: ops.binary_dense_stack_packed(stages, v, backend="pallas",
                                                resident=False), xp)
    n_auto = count_pallas_calls(
        lambda v: ops.binary_dense_stack_packed(stages, v,
                                                backend="pallas"), xp)
    assert (n_res, n_per, n_auto) == (1, 3, 1), (n_res, n_per, n_auto)
    # Auto decision honors the budget: zero budget -> per-layer fallback.
    n_tight = count_pallas_calls(
        lambda v: ops.binary_dense_stack_packed(stages, v, backend="pallas",
                                                vmem_budget_bytes=0), xp)
    assert n_tight == 3, n_tight


def test_stack_vmem_budget_is_shape_math():
    """The residency decision needs only shapes (so every shard of a
    sharded forward agrees), and the flagship BMLP hidden stack fits the
    default budget."""
    w4096 = jax.ShapeDtypeStruct((4096, 128), jnp.uint32)
    assert BMM.dense_stack_fits_vmem([w4096, w4096])
    big = jax.ShapeDtypeStruct((8192, 4096), jnp.uint32)
    assert not BMM.dense_stack_fits_vmem([big, big])
    small = BMM.dense_stack_vmem_bytes([w4096])
    assert small < BMM.dense_stack_vmem_bytes([w4096, w4096])


def test_bmlp_hidden_stack_is_single_kernel_launch():
    """The acceptance criterion: bmlp_forward_packed's hidden stack
    traces to exactly ONE pallas_call on the VMEM-resident path.

    Launch budget of the whole forward: 2·nbits for the bit-plane first
    layer (per-plane pack + GEMM), 1 standalone epilogue, H launches for
    the H-layer hidden stack (1 when resident), 1 output GEMM."""
    key = jax.random.PRNGKey(7)
    spec = cnn.BMLPSpec(sizes=(20, 64, 96, 64, 10), nbits_input=2)
    packed = cnn.pack_bmlp(cnn.init_bmlp(key, spec), spec)
    x = jax.random.randint(jax.random.fold_in(key, 1), (3, 20), 0,
                           4).astype(jnp.uint8)
    base = 2 * spec.nbits_input + 1 + 1         # bit-plane + epi + output
    n_res = count_pallas_calls(
        lambda v: cnn.bmlp_forward_packed(packed, v, backend="pallas",
                                          dense_stack="auto"), x)
    n_per = count_pallas_calls(
        lambda v: cnn.bmlp_forward_packed(packed, v, backend="pallas",
                                          dense_stack="per_layer"), x)
    assert n_res == base + 1, (n_res, base)     # hidden stack == 1 launch
    assert n_per == base + 2, (n_per, base)     # two hidden layers
    # And both modes agree numerically with the jnp path.
    want = cnn.bmlp_forward_packed(packed, x, backend="jnp")
    for mode in ("auto", "resident", "per_layer"):
        got = cnn.bmlp_forward_packed(packed, x, backend="pallas",
                                      dense_stack=mode)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-6, atol=1e-6)


def test_bcnn_dense_tail_uses_fused_stack():
    """The BCNN classifier tail: dense hidden layers contribute exactly
    one launch on the resident path, and the unpacked int32 dense
    activation never appears between them."""
    key = jax.random.PRNGKey(9)
    spec = cnn.BCNNSpec(input_hw=(8, 8), c_in=3,
                        stages=(cnn.ConvStage(16, pool=True),),
                        dense=(48, 64, 10))
    packed = cnn.pack_bcnn(cnn.init_bcnn(key, spec), spec)
    x = jax.random.randint(jax.random.fold_in(key, 1), (2, 8, 8, 3), 0,
                           256).astype(jnp.uint8)
    n_res = count_pallas_calls(
        lambda v: cnn.bcnn_forward_packed(packed, v, backend="pallas",
                                          dense_stack="auto"), x)
    n_per = count_pallas_calls(
        lambda v: cnn.bcnn_forward_packed(packed, v, backend="pallas",
                                          dense_stack="per_layer"), x)
    assert n_per - n_res == 1, (n_res, n_per)   # 2 hidden layers -> 1
    want = cnn.bcnn_forward_packed(packed, x, backend="jnp")
    got = cnn.bcnn_forward_packed(packed, x, backend="pallas")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# Knob validation (the conv-knob contract, extended to the dense suite)
# ---------------------------------------------------------------------------

def _tiny_gemm():
    key = jax.random.PRNGKey(11)
    _, _, ap, bp = _rand_gemm(key, 16, 64, 32)
    return ap, bp


@pytest.mark.parametrize("bad_ws", [0, -1, 3, 5, 7, 48, 200])
def test_words_per_step_invalid_raises(bad_ws):
    """Non-divisors of the 128-lane group raise — on the plain GEMM, the
    fused epilogue, the stack, and through the ops dispatchers."""
    ap, bp = _tiny_gemm()
    tau = jnp.zeros((32,))
    flip = jnp.ones((32,))
    with pytest.raises(ValueError, match="words_per_step"):
        BMM.binary_matmul_packed(ap, bp, k_true=64, words_per_step=bad_ws,
                                 interpret=True)
    with pytest.raises(ValueError, match="words_per_step"):
        ops.binary_matmul_packed(ap, bp, k_true=64, backend="pallas",
                                 words_per_step=bad_ws)
    with pytest.raises(ValueError, match="words_per_step"):
        ops.binary_matmul_bn_sign_packed(ap, bp, tau, flip, k_true=64,
                                         backend="pallas",
                                         words_per_step=bad_ws)
    stages = [{"w_packed": bp, "k_true": 64, "tau": tau, "flip": flip}]
    with pytest.raises(ValueError, match="words_per_step"):
        ops.binary_dense_stack_packed(stages, ap, backend="pallas",
                                      resident=True,
                                      words_per_step=bad_ws)


def test_gemm_block_knobs_raise():
    """The rebuilt GEMM validates its blocks like the conv grid does
    (regression: they used to be silently clamped)."""
    ap, bp = _tiny_gemm()
    with pytest.raises(ValueError, match="block_m"):
        BMM.binary_matmul_packed(ap, bp, k_true=64, block_m=4,
                                 interpret=True)
    with pytest.raises(ValueError, match="block_n"):
        BMM.binary_matmul_packed(ap, bp, k_true=64, block_n=64,
                                 interpret=True)
    with pytest.raises(ValueError, match="block_kw"):
        BMM.binary_matmul_packed(ap, bp, k_true=64, block_kw=100,
                                 interpret=True)
    with pytest.raises(ValueError, match="block_m"):
        BMM.binary_dense_stack_packed(
            ap, [bp], [jnp.zeros((32,))], [jnp.ones((32,))], k_trues=(64,),
            block_m=3, interpret=True)


# ---------------------------------------------------------------------------
# First-layer bit-plane dense (paper C4) vs the float oracle
# ---------------------------------------------------------------------------

@settings
@hypothesis.given(m=st.sampled_from((1, 4, 9)), k=st.sampled_from(
    (20, 32, 50, 100)), n=st.sampled_from((10, 33, 64)),
    nbits=st.sampled_from((1, 4, 8)), seed=S.seeds())
def test_bitplane_dense_packed_matches_float(m, k, n, nbits, seed):
    """apply_bitplane_dense_packed == x.int32 @ sign(W)^T exactly, both
    backends (previously only covered through bmlp_forward_packed)."""
    key = jax.random.PRNGKey(seed)
    params = L.init_binary_dense(key, k, n)
    x = jax.random.randint(jax.random.fold_in(key, 1), (m, k), 0,
                           1 << nbits).astype(jnp.uint8)
    want = np.asarray(L.apply_bitplane_dense_float(params, x)
                      ).astype(np.int32)
    packed = L.pack_bitplane_dense(params, nbits=nbits)
    for backend in ("jnp", "pallas"):
        got = L.apply_bitplane_dense_packed(packed, x, backend=backend)
        np.testing.assert_array_equal(
            np.asarray(got), want,
            err_msg=f"{backend} bitplane dense diverged m={m} k={k} n={n}")


def test_bitplane_dense_uint8_edges_exact():
    """Constant 0 and 255 inputs: every plane all-0 / all-1."""
    params = L.init_binary_dense(jax.random.PRNGKey(0), 40, 16)
    packed = L.pack_bitplane_dense(params)
    for fill in (0, 255):
        x = jnp.full((3, 40), fill, jnp.uint8)
        want = np.asarray(L.apply_bitplane_dense_float(params, x)
                          ).astype(np.int32)
        for backend in ("jnp", "pallas"):
            got = L.apply_bitplane_dense_packed(packed, x, backend=backend)
            np.testing.assert_array_equal(np.asarray(got), want)
