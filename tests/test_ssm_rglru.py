"""Recurrence oracles: SSD chunked scan vs the sequential state recurrence,

RG-LRU associative scan vs a per-step loop, chunk-size invariance."""
from _hypothesis_compat import hypothesis, st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import rglru as R
from repro.models import ssm as S

settings = hypothesis.settings(max_examples=10, deadline=None)


def ssd_sequential(x, a, b, c):
    """h_t = exp(a_t) h_{t-1} + B_t x_t ;  y_t = C_t h_t   (per head)."""
    bsz, s, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    hpg = h // g
    bh = jnp.repeat(b, hpg, axis=2)
    ch = jnp.repeat(c, hpg, axis=2)

    def step(state, t):
        xt, at, bt, ct = t
        state = state * jnp.exp(at)[..., None, None] \
            + jnp.einsum("bhn,bhp->bhpn", bt, xt)
        y = jnp.einsum("bhpn,bhn->bhp", state, ct)
        return state, y

    ts = (jnp.moveaxis(x, 1, 0), jnp.moveaxis(a, 1, 0),
          jnp.moveaxis(jnp.swapaxes(bh, 1, 1), 1, 0),
          jnp.moveaxis(ch, 1, 0))
    state0 = jnp.zeros((bsz, h, p, n), jnp.float32)
    final, ys = jax.lax.scan(step, state0, ts)
    return jnp.moveaxis(ys, 0, 1), final


@settings
@hypothesis.given(nc=st.integers(1, 4), chunk=st.sampled_from([2, 4, 8]),
                  seed=st.integers(0, 2**31 - 1))
def test_ssd_chunked_vs_sequential(nc, chunk, seed):
    bsz, h, p, g, n = 2, 4, 8, 2, 8
    s = nc * chunk
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (bsz, s, h, p))
    a = -jax.random.uniform(ks[1], (bsz, s, h), minval=0.01, maxval=1.0)
    b = jax.random.normal(ks[2], (bsz, s, g, n)) * 0.3
    c = jax.random.normal(ks[3], (bsz, s, g, n)) * 0.3
    y_chunk, st_chunk = S.ssd_chunked(x, a, b, c, chunk)
    y_seq, st_seq = ssd_sequential(x, a, b, c)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_seq),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st_chunk), np.asarray(st_seq),
                               rtol=1e-4, atol=1e-4)


def test_ssd_chunk_size_invariance():
    bsz, s, h, p, g, n = 1, 16, 2, 4, 1, 4
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (bsz, s, h, p))
    a = -jax.random.uniform(ks[1], (bsz, s, h), minval=0.01, maxval=0.5)
    b = jax.random.normal(ks[2], (bsz, s, g, n)) * 0.3
    c = jax.random.normal(ks[3], (bsz, s, g, n)) * 0.3
    y2, s2 = S.ssd_chunked(x, a, b, c, 2)
    y8, s8 = S.ssd_chunked(x, a, b, c, 8)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y8), rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s8), rtol=1e-4,
                               atol=1e-4)


def test_mamba2_prefill_state_matches_decode_continuation():
    """Forward(S) state == state after S decode steps; continuation
    logitss agree (covered end-to-end in test_decode_consistency; this
    isolates the SSM block)."""
    cfg = get_config("mamba2-1.3b", reduced=True)
    key = jax.random.PRNGKey(1)
    params = S.init_mamba2(key, cfg)
    u = jax.random.normal(jax.random.fold_in(key, 1), (2, 8, cfg.d_model),
                          jnp.float32).astype(cfg.activation_dtype)
    out_full, cache_full = S.mamba2_forward(params, cfg, u,
                                            return_cache=True)
    cache = S.init_mamba2_cache(cfg, 2)
    outs = []
    for t in range(8):
        o, cache = S.mamba2_decode(params, cfg, u[:, t:t + 1], cache)
        outs.append(o)
    out_steps = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(out_steps.astype(jnp.float32)),
        np.asarray(out_full.astype(jnp.float32)), rtol=3e-2, atol=3e-2)
    np.testing.assert_allclose(np.asarray(cache["state"]),
                               np.asarray(cache_full["state"]), rtol=2e-2,
                               atol=2e-2)


def test_rglru_assoc_scan_vs_loop():
    cfg = get_config("recurrentgemma-9b", reduced=True)
    key = jax.random.PRNGKey(2)
    params = R.init_rglru_block(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 3), (2, 10, cfg.d_model),
                          jnp.float32).astype(cfg.activation_dtype)
    out_full, cache_full = R.rglru_block_forward(params, cfg, x,
                                                 return_cache=True)
    cache = R.init_rglru_cache(cfg, 2)
    outs = []
    for t in range(10):
        o, cache = R.rglru_block_decode(params, cfg, x[:, t:t + 1], cache)
        outs.append(o)
    out_steps = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(out_steps.astype(jnp.float32)),
        np.asarray(out_full.astype(jnp.float32)), rtol=3e-2, atol=3e-2)
    np.testing.assert_allclose(np.asarray(cache["h"]),
                               np.asarray(cache_full["h"]), rtol=2e-2,
                               atol=2e-2)


def test_rglru_decay_bounded():
    """RG-LRU gate: 0 < a < 1 always (stability invariant)."""
    cfg = get_config("recurrentgemma-9b", reduced=True)
    params = R.init_rglru_block(jax.random.PRNGKey(5), cfg)
    x = jax.random.normal(jax.random.PRNGKey(6), (1, 32, 64)) * 10
    a, _ = R._gates(params, cfg, x)
    assert float(a.min()) > 0.0
    assert float(a.max()) <= 1.0      # == 1.0 only at fp32 round-off
    assert float(a.mean()) < 0.999
