"""Static-analysis subsystem (``repro.analysis`` — docs/analysis.md):
the four passes must each PASS on the repo's healthy code paths and
CATCH a seeded instance of its target defect — an unpacked HBM escape,
a VMEM over-budget launch, an off-plan collective, an unvalidated
block knob."""
import os

import numpy as np
import pytest

import jax.numpy as jnp

from repro.analysis import (PallasLaunch, count_pallas_calls,
                            estimate_forward, gemm_estimate,
                            pallas_launches, preflight, vmem_budget)
from repro.analysis import vmem as VM
from repro.analysis.collectives import (check_data_parallel, check_mesh,
                                        check_model_parallel)
from repro.analysis.lint import lint_paths, lint_source
from repro.analysis.packedness import analyze_packedness, model_policy
from repro.analysis.report import report_ok
from repro.kernels import ops as kops
from repro.kernels.binary_matmul import (STACK_VMEM_BUDGET,
                                         dense_stack_fits_vmem,
                                         dense_stack_vmem_bytes)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _gemm(a, b):
    return kops.binary_matmul_packed(a, b, k_true=256, backend="pallas")


def _packed(m, n, kw=8):
    return (np.zeros((m, kw), np.uint32), np.zeros((n, kw), np.uint32))


# ---------------------------------------------------------------------------
# graph traversal (shared core; utils/jaxpr re-exports it)
# ---------------------------------------------------------------------------

def test_pallas_launches_one_gemm():
    a, b = _packed(64, 128)
    launches = pallas_launches(_gemm, a, b)
    assert len(launches) == 1 and isinstance(launches[0], PallasLaunch)
    assert launches[0].kernel == "_gemm_kernel"
    assert len(launches[0].grid) == 3
    assert count_pallas_calls(_gemm, a, b) == 1


def test_utils_jaxpr_is_a_shim():
    from repro.utils import jaxpr as UJ
    assert UJ.pallas_launches is pallas_launches
    assert UJ._kernel_name is UJ.kernel_name


# ---------------------------------------------------------------------------
# packedness dataflow pass
# ---------------------------------------------------------------------------

def test_packedness_clean_on_epilogue_bridge():
    # int32 GEMM output bridging into the standalone BN-sign-repack is
    # the sanctioned unpacked crossing — no escape.
    def legal(a, b, tau, flip):
        y = _gemm(a, b)
        return kops.bn_sign_pack(y, tau, flip, backend="pallas")

    a, b = _packed(16, 128)
    tau = np.zeros(128, np.float32)
    flip = np.ones(128, np.float32)
    rep = analyze_packedness(legal, a, b, tau, flip, policy="strict")
    assert rep.complete and not rep.escapes
    assert rep.launch_count == 2
    assert rep.hbm_values.get("unpacked", 0) >= 1   # the bridge itself
    # Peak = the lane-padded repack staging array (16, 128*32) live
    # alongside the (16, 128) bridge: (16*4096 + 16*128) * 4 bytes.
    assert rep.max_live_unpacked_bytes == (16 * 4096 + 16 * 128) * 4
    assert rep.max_unpacked_shape == (16, 4096)


def test_packedness_catches_seeded_escape():
    # Host-side re-binarization of a kernel's int32 output, fed back
    # through the generic bitpack kernel: the classic silent leak.
    def leaky(a, b):
        y = _gemm(a, b)
        s = jnp.where(y >= 0, 1.0, -1.0).astype(jnp.float32)
        return kops.bitpack(s, backend="pallas")

    rep = analyze_packedness(leaky, *_packed(16, 128), policy="strict")
    assert rep.escapes, "seeded unpacked HBM escape not flagged"
    esc = rep.escapes[0]
    assert esc.producer == "_gemm_kernel"
    assert esc.consumer == "_bitpack_kernel"
    assert not rep.ok


def test_packedness_float_residual_policy_launders():
    # The binary LM's residual stream is float by design: int -> float
    # ends the taint under 'float-residual' but not under 'strict'.
    def residual(a, b):
        y = _gemm(a, b).astype(jnp.float32)
        return kops.bitpack(y, backend="pallas")

    args = _packed(16, 128)
    assert analyze_packedness(residual, *args, policy="strict").escapes
    rep = analyze_packedness(residual, *args, policy="float-residual")
    assert not rep.escapes and rep.complete
    assert model_policy("transformer") == "float-residual"
    assert model_policy("bcnn") == model_policy("bmlp") == "strict"


def test_packedness_rejects_unknown_policy():
    with pytest.raises(ValueError, match="policy"):
        analyze_packedness(_gemm, *_packed(8, 128), policy="lenient")


# ---------------------------------------------------------------------------
# VMEM preflight pass
# ---------------------------------------------------------------------------

def test_dense_stack_bytes_delegate_exact():
    # The legacy hand-rolled arithmetic and the shared estimator must
    # agree byte-for-byte (the estimator IS the old formula now).
    weights = [np.zeros((128, 25), np.uint32), np.zeros((10, 4), np.uint32)]
    est = VM.dense_stack_estimate([w.shape for w in weights])
    assert dense_stack_vmem_bytes(weights) == est.total == 54688


def test_dense_stack_crossover_pinned():
    # Regression-pin the residency crossover at the 8 MiB stack budget:
    # a (4096, 128)-word stage fits, an (8192, 256) stage does not.
    fits = [np.zeros((4096, 128), np.uint32)]
    over = [np.zeros((8192, 256), np.uint32)]
    assert dense_stack_fits_vmem(fits)
    assert not dense_stack_fits_vmem(over)
    assert dense_stack_vmem_bytes(fits) <= STACK_VMEM_BUDGET
    assert dense_stack_vmem_bytes(over) > STACK_VMEM_BUDGET


def test_preflight_raises_with_breakdown():
    est = gemm_estimate(1024, 8192, 4096, block_n=1024, block_kw=4096)
    assert not est.fits()
    with pytest.raises(VM.VmemBudgetError) as ei:
        preflight(est)
    msg = str(ei.value)
    assert "b_block" in msg and "REPRO_VMEM_BUDGET_BYTES" in msg


def test_ops_preflight_catches_seeded_over_budget(monkeypatch):
    # The dispatcher must refuse the launch BEFORE tracing when the
    # budget (env-overridable) is exceeded.
    monkeypatch.setenv("REPRO_VMEM_BUDGET_BYTES", "4096")
    assert vmem_budget() == 4096
    with pytest.raises(VM.VmemBudgetError):
        kops.bitpack(np.zeros((256, 512), np.float32), backend="pallas")
    monkeypatch.delenv("REPRO_VMEM_BUDGET_BYTES")
    # Same call is fine under the default 16 MiB budget.
    out = kops.bitpack(np.zeros((256, 512), np.float32), backend="pallas")
    assert out.shape == (256, 16)


def test_gemm_estimate_tracks_dispatch_route():
    assert gemm_estimate(1, 1000, 64).kernel == "gemv"
    assert gemm_estimate(64, 1000, 64).kernel == "gemm"
    # GEMV pins the activation block (1 buffer), GEMM streams it (2).
    gv = {t.name: t for t in gemm_estimate(1, 1000, 64).terms}
    gm = {t.name: t for t in gemm_estimate(64, 1000, 64).terms}
    assert gv["a_block"].buffers == 1 and gm["a_block"].buffers == 2
    assert "acc_scratch" in gm and "acc_scratch" not in gv


def test_traced_estimator_matches_launch():
    a, b = _packed(64, 128)
    ests = estimate_forward(_gemm, a, b)
    assert len(ests) == 1
    est = ests[0]
    assert est.kernel == "_gemm_kernel" and len(est.grid) == 3
    assert est.fits() and est.total > 0
    assert any(t.name.startswith("scratch") for t in est.terms)
    cell = est.to_json()
    assert cell["bytes"] == est.total and cell["fits"] is True


# ---------------------------------------------------------------------------
# sharding (collectives) pass
# ---------------------------------------------------------------------------

_AG = ('  %ag = u32[8,16]{1,0} all-gather(u32[2,16]{1,0} %p), '
       'replica_groups={{0,1,2,3}}\n')
_AR = ('  %ar = f32[128]{0} all-reduce(f32[128]{0} %x), '
       'to_apply=%add\n')


def test_collectives_model_parallel_allows_all_gather_only():
    rep = check_model_parallel(_AG)
    assert rep.ok and rep.kinds == {"all-gather": 1}
    rep = check_model_parallel(_AG + _AR)
    assert not rep.ok
    assert any("all-reduce" in v for v in rep.violations)
    assert rep.kinds == {"all-gather": 1, "all-reduce": 1}


def test_collectives_data_parallel_must_be_silent():
    assert check_data_parallel("ENTRY %main { ROOT %x = f32[] }").ok
    rep = check_data_parallel(_AG)
    assert not rep.ok and "collective-free" in rep.violations[0]


def test_check_mesh_dispatches_on_model_degree():
    assert not check_mesh(_AG, (8, 1)).ok      # data mesh: any = bad
    assert check_mesh(_AG, (4, 2)).ok          # model mesh: AG fine
    assert not check_mesh(_AR, (4, 2)).ok      # off-plan collective


# ---------------------------------------------------------------------------
# repo lint pass
# ---------------------------------------------------------------------------

def test_lint_repo_clean():
    assert lint_paths([os.path.join(REPO, "src")]) == []


def test_lint_catches_unrouted_backend():
    src = ("def run(x, backend='auto'):\n"
           "    if backend == 'pallas':\n"
           "        return x + 1\n"
           "    return x\n")
    rules = {v.rule for v in lint_source(src, "src/repro/kernels/fake.py")}
    assert "R001" in rules          # backend neither resolved nor forwarded
    assert "R004" in rules          # string-matching backend outside ops.py


def test_lint_catches_unvalidated_knob():
    src = ("def conv(x, *, block_n=128):\n"
           "    return x[:block_n]\n")
    out = lint_source(src, "src/repro/kernels/fake.py")
    assert any(v.rule == "R002" and "block_n" in v.message for v in out)
    # Validated spelling passes.
    good = ("def conv(x, *, block_n=128):\n"
            "    check_block_lanes('block_n', block_n)\n"
            "    return x[:block_n]\n")
    assert not [v for v in lint_source(good, "src/repro/kernels/fake.py")
                if v.rule == "R002"]


def test_lint_catches_hardcoded_interpret():
    src = "def f(x):\n    return pl.pallas_call(k, interpret=True)(x)\n"
    out = lint_source(src, "src/repro/models/fake.py")
    assert any(v.rule == "R003" for v in out)
    # Outside kernels/, R001/R002 don't apply but R003 still does.
    assert not any(v.rule in ("R001", "R002") for v in out)


# ---------------------------------------------------------------------------
# merged report invariants
# ---------------------------------------------------------------------------

def test_report_ok_flags_each_cell_kind():
    report = {"cells": {
        "packedness/bmlp": {"escapes": ["k -> k2: leak"], "complete": True},
        "vmem/bmlp_b8": [{"kernel": "gemm", "grid": [1], "bytes": 99,
                          "fits": False}],
        "lint": {"violations": ["x.py:1: R003 bad"]},
        "sharding/bmlp_4x2": {"violations": ["off-plan"], "kinds": {}},
    }}
    bad = report_ok(report)
    assert len(bad) == 4
    clean = {"cells": {
        "packedness/bmlp": {"escapes": [], "complete": True},
        "vmem/bmlp_b8": [{"kernel": "gemm", "grid": [1], "bytes": 9,
                          "fits": True}],
        "lint": {"violations": []},
        "sharding/bmlp_4x2": {"violations": [], "kinds": {}},
    }}
    assert report_ok(clean) == []


def test_probes_reexport_diff_reports():
    from repro.analysis.report import diff_reports as canonical
    from repro.telemetry.probes import diff_reports
    assert diff_reports is canonical
    assert diff_reports({"a": 1}, {"a": 2}) == ["a: 1 -> 2"]
