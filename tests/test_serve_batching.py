"""Serving-layer tests: the packed-inference server + the LM driver.

PackedInferenceServer (train/serve.py): queue lifecycle under a
simulated clock (ragged arrival order, deadline flush, no head-of-line
blocking, eviction/backpressure), pack-once weight-cache semantics
across config swaps, scratch-pool steady state, bit-exactness of served
outputs against the direct packed forwards over a
(model, batch, backend) matrix, and the GEMV-vs-GEMM launch-shape
contract of the ``kernels.ops.dispatch_batch`` seam.

BatchedServer (LM): request accounting + slot-cache hygiene —
regression for two silent-loss bugs: requests in flight (or still
queued) when the shared cache ran out of positions were returned in
NEITHER ``done`` nor an error, and a freed slot's next occupant
inherited the previous request's stale KV rows.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels import ops as kops
from repro.models import cnn
from repro.models import model as M
from repro.train import serve as SV
from repro.utils.jaxpr import pallas_grids


# ---------------------------------------------------------------------------
# PackedInferenceServer fixtures
# ---------------------------------------------------------------------------

def _bmlp(sizes=(96, 128, 64, 10)):
    spec = cnn.BMLPSpec(sizes=sizes)
    params = cnn.init_bmlp(jax.random.PRNGKey(0), spec)
    return params, spec, "bmlp"


def _bcnn():
    spec = cnn.BCNNSpec(input_hw=(8, 8), c_in=3,
                        stages=(cnn.ConvStage(32),
                                cnn.ConvStage(64, pool=True)),
                        dense=(96, 10))
    params = cnn.init_bcnn(jax.random.PRNGKey(1), spec)
    return params, spec, "bcnn"


def _server(**kw):
    clock = SV.SimClock()
    kw.setdefault("max_batch", 8)
    kw.setdefault("default_deadline", 0.010)
    return SV.PackedInferenceServer(clock=clock, **kw), clock


def _inputs(n, shape, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, 256, (n, *shape), dtype=np.uint8)


def _direct(params, spec, kind, xs, backend):
    packed = (cnn.pack_bcnn if kind == "bcnn" else cnn.pack_bmlp)(params,
                                                                 spec)
    fwd = (cnn.bcnn_forward_packed if kind == "bcnn"
           else cnn.bmlp_forward_packed)
    return np.asarray(fwd(packed, jnp.asarray(xs), backend=backend))


# ---------------------------------------------------------------------------
# Queue lifecycle (simulated clock)
# ---------------------------------------------------------------------------

def test_deadline_flush():
    """A partial batch waits for riders until the OLDEST deadline
    expires, then flushes everything pending — not just the expired
    prefix."""
    params, spec, kind = _bmlp()
    srv, clock = _server()
    srv.register("m", params, spec, kind=kind, backend="jnp")
    xs = _inputs(3, srv.engine().example_shape)
    srv.submit(xs[0], deadline=0.010)
    clock.advance(0.004)
    srv.submit(xs[1], deadline=0.010)          # deadline at t=0.014
    srv.submit(xs[2], deadline=0.050)          # far-future deadline
    assert srv.step() == []                    # t=0.004: nothing due
    clock.advance(0.004)
    assert srv.step() == []                    # t=0.008: still early
    clock.advance(0.004)                       # t=0.012: oldest expired
    done = srv.step()
    assert [r.rid for r in done] == [0, 1, 2]  # FIFO, all ride the flush
    assert srv.pending() == 0
    assert len(srv.flushes) == 1 and srv.flushes[0].batch == 3


def test_full_window_flushes_without_deadline():
    params, spec, kind = _bmlp()
    srv, _ = _server(max_batch=4)
    srv.register("m", params, spec, kind=kind, backend="jnp")
    xs = _inputs(9, srv.engine().example_shape)
    for x in xs:
        srv.submit(x)
    done = srv.step()                          # two full windows, no clock
    assert len(done) == 8
    assert srv.pending() == 1                  # the ragged tail waits
    assert [f.batch for f in srv.flushes] == [4, 4]


def test_ragged_arrivals_no_head_of_line_blocking():
    """A request arriving after a flush started rides the NEXT flush;
    it can neither delay the in-flight window nor be starved by it."""
    params, spec, kind = _bmlp()
    srv, clock = _server(max_batch=4)
    srv.register("m", params, spec, kind=kind, backend="jnp")
    xs = _inputs(6, srv.engine().example_shape)
    first = [srv.submit(x, deadline=0.005) for x in xs[:3]]
    clock.advance(0.006)
    done = srv.step()                          # deadline flush of 0..2
    assert [r.rid for r in done] == first
    late = [srv.submit(x, deadline=0.005) for x in xs[3:]]
    assert srv.step() == []                    # late arrivals not yet due
    clock.advance(0.006)
    done = srv.step()
    assert [r.rid for r in done] == late
    assert [f.batch for f in srv.flushes] == [3, 3]


def test_submission_order_preserved_across_windows():
    params, spec, kind = _bmlp()
    srv, clock = _server(max_batch=4)
    srv.register("m", params, spec, kind=kind, backend="jnp")
    xs = _inputs(10, srv.engine().example_shape)
    rids = [srv.submit(x) for x in xs]
    clock.advance(1.0)
    done = srv.step()
    assert [r.rid for r in done] == rids       # FIFO across 4+4+2 windows
    assert [f.batch for f in srv.flushes] == [4, 4, 2]


def test_cancel_and_backpressure():
    params, spec, kind = _bmlp()
    srv, _ = _server(max_queue=3)
    srv.register("m", params, spec, kind=kind, backend="jnp")
    xs = _inputs(4, srv.engine().example_shape)
    rids = [srv.submit(x) for x in xs[:3]]
    with pytest.raises(RuntimeError, match="backpressure"):
        srv.submit(xs[3])
    assert srv.cancel(rids[1])                 # evict a queued request
    assert not srv.cancel(rids[1])             # already gone
    srv.submit(xs[3])                          # slot freed
    done = srv.flush()
    assert [r.rid for r in done] == [rids[0], rids[2], 3]


def test_serve_backpressure_is_atomic():
    """serve() sheds the WHOLE batch when it would overflow max_queue —
    it never strands a half-submitted prefix in the queue."""
    params, spec, kind = _bmlp()
    srv, _ = _server(max_queue=4)
    srv.register("m", params, spec, kind=kind, backend="jnp")
    xs = _inputs(6, srv.engine().example_shape)
    with pytest.raises(RuntimeError, match="backpressure"):
        srv.serve(list(xs))
    assert srv.pending() == 0                  # nothing submitted
    got = np.stack(srv.serve(list(xs[:4])))    # within bound: works
    assert np.array_equal(got, _direct(params, spec, kind, xs[:4], "jnp"))


def test_use_swaps_model_after_force_flush():
    pa, sa, ka = _bmlp((96, 128, 64, 10))
    pb, sb, kb = _bmlp((96, 64, 10))
    srv, _ = _server()
    srv.register("a", pa, sa, kind=ka, backend="jnp")
    srv.register("b", pb, sb, kind=kb, backend="jnp")
    assert srv.active == "a"
    xs = _inputs(2, srv.engine().example_shape)
    rids = [srv.submit(x) for x in xs]
    done = srv.use("b")                        # pending work flushed first
    assert [r.rid for r in done] == rids
    assert srv.active == "b" and srv.pending() == 0


# ---------------------------------------------------------------------------
# Pack-once weight cache + scratch pool
# ---------------------------------------------------------------------------

def test_cache_hit_after_config_swap():
    """Swapping configs and back re-packs NOTHING: the packed tree and
    the compiled forwards of both models stay warm."""
    pa, sa, ka = _bmlp((96, 128, 64, 10))
    pb, sb, kb = _bmlp((96, 64, 10))
    srv, _ = _server()
    srv.register("a", pa, sa, kind=ka, backend="jnp")
    srv.register("b", pb, sb, kind=kb, backend="jnp")
    assert (srv.cache.misses, srv.cache.hits) == (2, 0)
    eng_a = srv.engine("a")
    srv.use("b")
    srv.use("a")                               # swap away and back
    srv.register("a", pa, sa, kind=ka, backend="jnp")   # re-register too
    assert srv.cache.misses == 2               # never re-packed
    assert srv.cache.hits == 1
    assert srv.engine("a") is eng_a            # engine (jit cache) kept
    xs = _inputs(2, eng_a.example_shape)
    assert np.array_equal(np.stack(srv.serve(list(xs))),
                          _direct(pa, sa, ka, xs, "jnp"))


def test_invalidate_forces_repack():
    pa, sa, ka = _bmlp()
    srv, _ = _server()
    srv.register("a", pa, sa, kind=ka, backend="jnp")
    srv.invalidate("a")
    assert srv.active is None
    srv.register("a", pa, sa, kind=ka, backend="jnp")
    assert srv.cache.misses == 2               # repacked after invalidate


def test_invalidate_active_model_flushes_pending_first():
    """Queued requests were admitted under the old weights: invalidating
    the active model serves them (old engine) instead of stranding them
    against a dead key."""
    pa, sa, ka = _bmlp()
    srv, clock = _server()
    srv.register("a", pa, sa, kind=ka, backend="jnp")
    xs = _inputs(2, srv.engine().example_shape)
    rids = [srv.submit(x) for x in xs]
    done = srv.invalidate("a")
    assert [r.rid for r in done] == rids
    assert srv.pending() == 0 and srv.active is None
    clock.advance(1.0)
    assert srv.step() == []                    # no crash on a dead key


def test_take_recovers_foreign_flush_completions():
    """A request drained by ANOTHER caller's serve()/flush() is not
    lost: its completion stays claimable via take(rid)."""
    params, spec, kind = _bmlp()
    srv, _ = _server()
    srv.register("m", params, spec, kind=kind, backend="jnp")
    xs = _inputs(3, srv.engine().example_shape)
    rid = srv.submit(xs[0])                    # caller A, polling step()
    srv.serve(list(xs[1:]))                    # caller B drains the queue
    assert srv.step() == []                    # A's poll: already flushed
    got = srv.take(rid)
    assert got is not None and got.rid == rid
    assert np.array_equal(
        got.result, _direct(params, spec, kind, xs[:1], "jnp")[0])
    assert srv.take(rid) is None               # claimed exactly once


def test_scratch_pool_steady_state_zero_allocations():
    """Once a bucket is warm, serving allocates no new staging buffers:
    the same array is reused flush after flush."""
    params, spec, kind = _bmlp()
    srv, _ = _server(max_batch=4)
    srv.register("m", params, spec, kind=kind, backend="jnp")
    eng = srv.engine()
    xs = _inputs(4, eng.example_shape)
    srv.serve(list(xs))                        # warm the 4-bucket
    allocs = srv.pool.allocations
    buf = srv.pool.batch_buffer(4, eng.example_shape)
    for _ in range(3):
        srv.serve(list(xs))
    assert srv.pool.allocations == allocs
    assert srv.pool.batch_buffer(4, eng.example_shape) is buf


# ---------------------------------------------------------------------------
# Bit-exactness: served == direct packed forward
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["jnp", "pallas"])
@pytest.mark.parametrize("batch", [1, 5, 12])
@pytest.mark.parametrize("build", [_bmlp, _bcnn], ids=["bmlp", "bcnn"])
def test_served_outputs_bit_exact(build, batch, backend):
    """Padding to buckets and splitting into windows never changes a
    row: served outputs == the direct ``*_forward_packed`` on the exact
    submitted batch, bit-for-bit, on both backends."""
    params, spec, kind = build()
    srv, _ = _server(max_batch=8)
    srv.register("m", params, spec, kind=kind, backend=backend)
    xs = _inputs(batch, srv.engine().example_shape, seed=batch)
    got = np.stack(srv.serve(list(xs)))
    want = _direct(params, spec, kind, xs, backend)
    assert got.shape == want.shape
    assert np.array_equal(got, want)
    # every flush was recorded with the route the kernels actually took
    assert all(f.route in ("gemv", "gemm") for f in srv.flushes)


# ---------------------------------------------------------------------------
# The dispatch_batch seam + launch-shape evidence
# ---------------------------------------------------------------------------

def test_dispatch_batch_rule():
    assert kops.dispatch_batch(1, 25) == "gemv"
    assert kops.dispatch_batch(8, 4096) == "gemv"     # boundary: fits
    assert kops.dispatch_batch(9, 25) == "gemm"       # M over sublane min
    assert kops.dispatch_batch(1, 4097) == "gemm"     # K over GEMV bound
    assert kops.dispatch_batch(32, 128) == "gemm"
    with pytest.raises(ValueError):
        kops.dispatch_batch(0, 25)
    with pytest.raises(ValueError):
        kops.dispatch_batch(4, 0)


def test_server_route_matches_dispatch_batch():
    params, spec, kind = _bmlp()
    srv, _ = _server(max_batch=32)
    srv.register("m", params, spec, kind=kind, backend="jnp")
    kw = srv.engine().kw_words
    assert srv.route_for(1) == "gemv" == kops.dispatch_batch(1, kw)
    assert srv.route_for(3) == "gemv"                 # bucket 4 still ≤ 8
    assert srv.route_for(9) == "gemm"                 # bucket 16
    assert srv.route_for(32) == "gemm" == kops.dispatch_batch(32, kw)


def test_launch_shapes_gemv_vs_gemm():
    """The launch-shape contract behind ``dispatch_batch``: a batch-1
    flush lowers every dense contraction to the 1-D N-major GEMV grid
    (NO 3-D blocked-GEMM launch in the whole trace), while a batch-32
    flush lowers its contractions to the 3-D (M, N, K) grid."""
    params, spec, kind = _bmlp()
    packed = cnn.pack_bmlp(params, spec)
    fwd = cnn.make_packed_forward(packed, backend="pallas",
                                  dense_stack="per_layer")
    shape = cnn.packed_input_shape(packed)

    g1 = pallas_grids(lambda x: fwd(x), np.zeros((1, *shape), np.uint8))
    assert g1, "no pallas launches traced"
    assert not [g for g in g1 if len(g) == 3], g1     # zero GEMM grids
    assert [g for g in g1 if len(g) == 1], g1         # GEMV grids present

    g32 = pallas_grids(lambda x: fwd(x), np.zeros((32, *shape), np.uint8))
    assert [g for g in g32 if len(g) == 3], g32       # blocked GEMM grids

    # and the server's per-flush records agree with the traced shapes
    srv, _ = _server(max_batch=32)
    srv.register("m", params, spec, kind=kind, backend="pallas")
    eng = srv.engine()
    srv.serve(list(_inputs(1, eng.example_shape)))
    srv.serve(list(_inputs(32, eng.example_shape)))
    assert [f.route for f in srv.flushes] == ["gemv", "gemm"]
    assert [f.bucket for f in srv.flushes] == [1, 32]


def test_serve_beyond_mailbox_cap():
    """serve() collects its results from the flush returns directly, so
    it works for request counts beyond the bounded take() mailbox."""
    params, spec, kind = _bmlp()
    srv, _ = _server(max_batch=8, completed_mailbox=4)
    srv.register("m", params, spec, kind=kind, backend="jnp")
    xs = _inputs(24, srv.engine().example_shape)   # 24 >> cap (16)
    got = np.stack(srv.serve(list(xs)))
    assert np.array_equal(got, _direct(params, spec, kind, xs, "jnp"))


def test_history_is_bounded():
    """served/flushes are observability history, capped like the
    mailbox — a long-running server cannot leak request objects."""
    params, spec, kind = _bmlp()
    srv, _ = _server(max_batch=4, completed_mailbox=2)
    srv.register("m", params, spec, kind=kind, backend="jnp")
    cap = srv._completed_cap
    xs = _inputs(4 * cap, srv.engine().example_shape)
    for x in xs:
        srv.serve([x])
    assert len(srv.served) <= cap
    assert len(srv.flushes) <= cap
    assert len(srv._completed) <= cap


def test_register_validation():
    params, spec, _ = _bmlp()
    srv, _ = _server()
    with pytest.raises(ValueError, match="kind"):
        srv.register("m", params, spec, kind="mlp")
    with pytest.raises(RuntimeError, match="no model"):
        srv.submit(np.zeros((96,), np.uint8))
    with pytest.raises(RuntimeError, match="no model"):
        srv.route_for(1)
    srv.register("m", params, spec, kind="bmlp", backend="jnp")
    with pytest.raises(KeyError):
        srv.use("nope")


# ---------------------------------------------------------------------------
# BatchedServer (LM decode driver)
# ---------------------------------------------------------------------------

def _lm_server(slots=2, max_len=8):
    cfg = get_config("starcoder2-3b", reduced=True)
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    return SV.BatchedServer(cfg, params, slots, max_len)


def _lm_reqs(n, prompt_len=2, max_new=2):
    return [SV.Request(rid=i,
                       prompt=jnp.arange(prompt_len, dtype=jnp.int32) + i,
                       max_new=max_new)
            for i in range(n)]


def test_every_request_accounted_for():
    """More requests than the cache can serve: completed ones come back
    finished, the rest come back flagged truncated (never dropped)."""
    srv = _lm_server(slots=2, max_len=5)
    reqs = _lm_reqs(5, prompt_len=2, max_new=2)
    out = srv.submit_and_run(reqs)
    assert {r.rid for r in out} == {r.rid for r in reqs}
    finished = [r for r in out if not r.truncated]
    truncated = [r for r in out if r.truncated]
    assert finished and truncated
    for r in finished:
        assert len(r.out) == r.max_new
    for r in truncated:
        assert len(r.out) < r.max_new       # includes never-started (0)


def test_all_complete_when_cache_suffices():
    srv = _lm_server(slots=2, max_len=16)
    out = srv.submit_and_run(_lm_reqs(4, prompt_len=2, max_new=2))
    assert len(out) == 4
    assert all(not r.truncated and len(r.out) == 2 for r in out)


def test_server_survives_exhaustion_and_retries_truncated():
    """A call that exhausts the cache must not leave the server dead:
    the next call starts a fresh window, and resubmitting the truncated
    requests restarts them cleanly (stale partial output discarded, flag
    cleared) rather than splicing tokens from the aborted window."""
    srv = _lm_server(slots=2, max_len=5)
    first = srv.submit_and_run(_lm_reqs(5, prompt_len=2, max_new=2))
    retry = [r for r in first if r.truncated]
    assert retry
    second = srv.submit_and_run(retry[:2])
    assert len(second) == 2
    assert all(not r.truncated and len(r.out) == 2 for r in second)


def test_freed_slot_cache_is_reset():
    """After a request completes, its slot's cache rows are zeroed so the
    next occupant can't read the previous request's KV state."""
    srv = _lm_server(slots=2, max_len=16)
    srv.submit_and_run(_lm_reqs(2, prompt_len=2, max_new=2))
    for leaf in jax.tree.leaves(srv.cache):
        if hasattr(leaf, "ndim") and leaf.ndim >= 2 and \
                leaf.shape[1] == srv.slots:
            assert not np.asarray(leaf[:, 0]).any()
            assert not np.asarray(leaf[:, 1]).any()


def test_reset_slot_is_slot_local():
    srv = _lm_server(slots=2, max_len=8)
    srv.cache = jax.tree.map(
        lambda a: jnp.ones_like(a) if hasattr(a, "ndim") else a, srv.cache)
    srv._reset_slot(0)
    touched = False
    for leaf in jax.tree.leaves(srv.cache):
        if hasattr(leaf, "ndim") and leaf.ndim >= 2 and \
                leaf.shape[1] == srv.slots:
            assert not np.asarray(leaf[:, 0]).any()
            assert np.asarray(leaf[:, 1]).all()
            touched = True
    assert touched
