"""BatchedServer request accounting + slot-cache hygiene.

Regression for two silent-loss bugs: requests in flight (or still
queued) when the shared cache ran out of positions were returned in
NEITHER ``done`` nor an error, and a freed slot's next occupant
inherited the previous request's stale KV rows.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import model as M
from repro.train import serve as SV


def _server(slots=2, max_len=8):
    cfg = get_config("starcoder2-3b", reduced=True)
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    return SV.BatchedServer(cfg, params, slots, max_len)


def _reqs(n, prompt_len=2, max_new=2):
    return [SV.Request(rid=i,
                       prompt=jnp.arange(prompt_len, dtype=jnp.int32) + i,
                       max_new=max_new)
            for i in range(n)]


def test_every_request_accounted_for():
    """More requests than the cache can serve: completed ones come back
    finished, the rest come back flagged truncated (never dropped)."""
    srv = _server(slots=2, max_len=5)
    reqs = _reqs(5, prompt_len=2, max_new=2)
    out = srv.submit_and_run(reqs)
    assert {r.rid for r in out} == {r.rid for r in reqs}
    finished = [r for r in out if not r.truncated]
    truncated = [r for r in out if r.truncated]
    assert finished and truncated
    for r in finished:
        assert len(r.out) == r.max_new
    for r in truncated:
        assert len(r.out) < r.max_new       # includes never-started (0)


def test_all_complete_when_cache_suffices():
    srv = _server(slots=2, max_len=16)
    out = srv.submit_and_run(_reqs(4, prompt_len=2, max_new=2))
    assert len(out) == 4
    assert all(not r.truncated and len(r.out) == 2 for r in out)


def test_server_survives_exhaustion_and_retries_truncated():
    """A call that exhausts the cache must not leave the server dead:
    the next call starts a fresh window, and resubmitting the truncated
    requests restarts them cleanly (stale partial output discarded, flag
    cleared) rather than splicing tokens from the aborted window."""
    srv = _server(slots=2, max_len=5)
    first = srv.submit_and_run(_reqs(5, prompt_len=2, max_new=2))
    retry = [r for r in first if r.truncated]
    assert retry
    second = srv.submit_and_run(retry[:2])
    assert len(second) == 2
    assert all(not r.truncated and len(r.out) == 2 for r in second)


def test_freed_slot_cache_is_reset():
    """After a request completes, its slot's cache rows are zeroed so the
    next occupant can't read the previous request's KV state."""
    srv = _server(slots=2, max_len=16)
    srv.submit_and_run(_reqs(2, prompt_len=2, max_new=2))
    for leaf in jax.tree.leaves(srv.cache):
        if hasattr(leaf, "ndim") and leaf.ndim >= 2 and \
                leaf.shape[1] == srv.slots:
            assert not np.asarray(leaf[:, 0]).any()
            assert not np.asarray(leaf[:, 1]).any()


def test_reset_slot_is_slot_local():
    srv = _server(slots=2, max_len=8)
    srv.cache = jax.tree.map(
        lambda a: jnp.ones_like(a) if hasattr(a, "ndim") else a, srv.cache)
    srv._reset_slot(0)
    touched = False
    for leaf in jax.tree.leaves(srv.cache):
        if hasattr(leaf, "ndim") and leaf.ndim >= 2 and \
                leaf.shape[1] == srv.slots:
            assert not np.asarray(leaf[:, 0]).any()
            assert np.asarray(leaf[:, 1]).all()
            touched = True
    assert touched
