"""Shared shape strategies for the kernel property suite.

Every strategy is biased toward the awkward geometries the packed conv
path must get bit-exact: C_in not a multiple of 32 (sub-word and
multi-word ragged), 1x1 and even kernels, batch 1, odd spatial sizes,
stride 2, VALID cropping.  Built on ``_hypothesis_compat`` so the same
definitions drive real hypothesis in CI and the deterministic fallback
engine elsewhere.
"""
from __future__ import annotations

from collections import namedtuple

from _hypothesis_compat import st

ConvCase = namedtuple(
    "ConvCase", "batch h w c_in c_out k stride padding")

# Channel counts: sub-word (1, 3, 7, 20, 31), exact word (32, 64), and
# multi-word ragged (33, 40) — the zero-bit-tail paths.
AWKWARD_C_IN = (1, 3, 7, 20, 31, 32, 33, 40, 64)
AWKWARD_C_OUT = (1, 5, 16, 31, 32, 33, 48)


def _valid(case: ConvCase) -> bool:
    # VALID padding needs the kernel to fit; SAME always produces output.
    return case.padding == "SAME" or (case.k <= case.h and case.k <= case.w)


def conv_cases(max_hw: int = 9) -> "st.SearchStrategy":
    """(batch, H, W, C_in, C_out, k, stride, padding) conv geometries.

    Batch 1, stride 1, and SAME are over-weighted (the paper's serving
    shape) but stride 2 / VALID / batch 2 all stay in the sampled grid.
    Spatial sizes span 4..max_hw including odd values.
    """
    return st.tuples(
        st.sampled_from((1, 1, 2)),               # batch (batch-1 biased)
        st.integers(4, max_hw),                   # H (odd included)
        st.integers(4, max_hw),                   # W
        st.sampled_from(AWKWARD_C_IN),
        st.sampled_from(AWKWARD_C_OUT),
        st.sampled_from((1, 2, 3, 3)),            # kernel (1x1 and even)
        st.sampled_from((1, 1, 2)),               # stride
        st.sampled_from(("SAME", "SAME", "VALID")),
    ).map(lambda t: ConvCase(*t)).filter(_valid)


def bitplane_conv_cases(max_hw: int = 8) -> "st.SearchStrategy":
    """First-layer geometries: small C_in (image-like) plus ragged ones."""
    return st.tuples(
        st.sampled_from((1, 1, 2)),
        st.integers(4, max_hw),
        st.integers(4, max_hw),
        st.sampled_from((1, 3, 4, 20, 33)),       # first-layer channels
        st.sampled_from((1, 8, 16, 33)),
        st.sampled_from((1, 3, 3)),
        st.sampled_from((1, 1, 2)),
        st.sampled_from(("SAME", "SAME", "VALID")),
    ).map(lambda t: ConvCase(*t)).filter(_valid)


def uint8_fill() -> "st.SearchStrategy":
    """Input-image fill mode: random bytes or the uint8 edge values.

    0 and 255 exercise the all-zero-plane and all-one-plane corners of
    the bit-plane decomposition (255 = every plane bit set).
    """
    return st.sampled_from(("random", "random", "zeros", "max255"))


def m_tilings() -> "st.SearchStrategy":
    """block_oh choices: None (auto = untiled for small images), single
    output row, and small bands that leave a ragged last tile."""
    return st.sampled_from((None, 1, 2, 3))


def seeds() -> "st.SearchStrategy":
    return st.integers(0, 2**31 - 1)
