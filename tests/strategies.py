"""Shared shape strategies for the kernel property suite.

Every strategy is biased toward the awkward geometries the packed conv
path must get bit-exact: C_in not a multiple of 32 (sub-word and
multi-word ragged), 1x1 and even kernels, batch 1, odd spatial sizes,
stride 2, VALID cropping.  Built on ``_hypothesis_compat`` so the same
definitions drive real hypothesis in CI and the deterministic fallback
engine elsewhere.
"""
from __future__ import annotations

from collections import namedtuple

from _hypothesis_compat import st

ConvCase = namedtuple(
    "ConvCase", "batch h w c_in c_out k stride padding")

DenseCase = namedtuple("DenseCase", "m k n")

# Channel counts: sub-word (1, 3, 7, 20, 31), exact word (32, 64), and
# multi-word ragged (33, 40) — the zero-bit-tail paths.
AWKWARD_C_IN = (1, 3, 7, 20, 31, 32, 33, 40, 64)
AWKWARD_C_OUT = (1, 5, 16, 31, 32, 33, 48)


def _valid(case: ConvCase) -> bool:
    # VALID padding needs the kernel to fit; SAME always produces output.
    return case.padding == "SAME" or (case.k <= case.h and case.k <= case.w)


def conv_cases(max_hw: int = 9) -> "st.SearchStrategy":
    """(batch, H, W, C_in, C_out, k, stride, padding) conv geometries.

    Batch 1, stride 1, and SAME are over-weighted (the paper's serving
    shape) but stride 2 / VALID / batch 2 all stay in the sampled grid.
    Spatial sizes span 4..max_hw including odd values.
    """
    return st.tuples(
        st.sampled_from((1, 1, 2)),               # batch (batch-1 biased)
        st.integers(4, max_hw),                   # H (odd included)
        st.integers(4, max_hw),                   # W
        st.sampled_from(AWKWARD_C_IN),
        st.sampled_from(AWKWARD_C_OUT),
        st.sampled_from((1, 2, 3, 3)),            # kernel (1x1 and even)
        st.sampled_from((1, 1, 2)),               # stride
        st.sampled_from(("SAME", "SAME", "VALID")),
    ).map(lambda t: ConvCase(*t)).filter(_valid)


def bitplane_conv_cases(max_hw: int = 8) -> "st.SearchStrategy":
    """First-layer geometries: small C_in (image-like) plus ragged ones."""
    return st.tuples(
        st.sampled_from((1, 1, 2)),
        st.integers(4, max_hw),
        st.integers(4, max_hw),
        st.sampled_from((1, 3, 4, 20, 33)),       # first-layer channels
        st.sampled_from((1, 8, 16, 33)),
        st.sampled_from((1, 3, 3)),
        st.sampled_from((1, 1, 2)),
        st.sampled_from(("SAME", "SAME", "VALID")),
    ).map(lambda t: ConvCase(*t)).filter(_valid)


def uint8_fill() -> "st.SearchStrategy":
    """Input-image fill mode: random bytes or the uint8 edge values.

    0 and 255 exercise the all-zero-plane and all-one-plane corners of
    the bit-plane decomposition (255 = every plane bit set).
    """
    return st.sampled_from(("random", "random", "zeros", "max255"))


def m_tilings() -> "st.SearchStrategy":
    """block_oh choices: None (auto = untiled for small images), single
    output row, and small bands that leave a ragged last tile."""
    return st.sampled_from((None, 1, 2, 3))


def dense_cases() -> "st.SearchStrategy":
    """(M, K, N) GEMM geometries for the dense megakernel suite.

    K and N sample sub-word, exact-word, and multi-word-ragged values
    (the pack-seam tails of both the contraction and the fused repack
    epilogue); M spans the GEMV serving shapes (1, 2, 8 — the N-major
    grid), the 8/9 sublane boundary, and multi-tile sizes.
    """
    return st.tuples(
        st.sampled_from((1, 2, 8, 9, 13, 40)),
        st.sampled_from((31, 32, 33, 64, 100, 131, 260)),
        st.sampled_from((10, 31, 32, 33, 48, 100, 130)),
    ).map(lambda t: DenseCase(*t))


def words_per_steps() -> "st.SearchStrategy":
    """Contraction-vectorization knob: None (kernel default) plus the
    divisor-of-128 extremes — the output must be invariant to all."""
    return st.sampled_from((None, 1, 2, 8, 32, 128))


def dense_stack_widths() -> "st.SearchStrategy":
    """Hidden-stack layer widths (d_out per stage), pack-seam-ragged
    included — 33/40 leave zero-bit tails the in-kernel repack must
    thread through to the next stage's zero-padded weight words."""
    return st.sampled_from(((64,), (48, 64), (33, 96, 40), (100, 64, 32)))


AttnCase = namedtuple("AttnCase", "batch sq skv hkv group d causal window")


def attention_cases() -> "st.SearchStrategy":
    """(B, Sq, Skv, Hkv, group, head_dim, causal, window) attention
    geometries for the binary-attention kernel suite.

    Ragged on every axis the kernel pads: Sq off the 8-sublane grid,
    Skv off the 128-lane grid, head_dim sub-word (8, 16), exact-word
    (32, 64) and multi-word ragged (33 — the zero-bit-tail path),
    Hq = Hkv·group covering MHA (group 1), GQA and MQA (Hkv 1).
    Sliding-window cases keep Skv ≥ Sq so no query row is fully masked
    (queries align to the sequence end via q_offset = Skv − Sq; a row
    with zero valid keys has no defined softmax and the oracle/kernel
    padding conventions legitimately differ there).
    """
    return st.tuples(
        st.sampled_from((1, 1, 2)),               # batch (batch-1 biased)
        st.sampled_from((1, 3, 5, 9, 17)),        # Sq (off-sublane)
        st.sampled_from((1, 4, 9, 16, 21)),       # Skv (off-lane)
        st.sampled_from((1, 2, 3)),               # Hkv
        st.sampled_from((1, 1, 2, 4)),            # group (Hq = Hkv*group)
        st.sampled_from((8, 16, 32, 33, 64)),     # head_dim
        st.booleans(),                            # causal
        st.sampled_from((None, None, 3, 7)),      # sliding window
    ).map(lambda t: AttnCase(*t)).filter(
        lambda c: c.window is None or c.skv >= c.sq)


def attention_blocks() -> "st.SearchStrategy":
    """(block_q, block_kv) knob choices — None (auto) plus minimum and
    multi-tile sizes; the kernel output must be invariant to all."""
    return st.sampled_from(
        ((None, None), (8, 128), (16, 128), (8, 256), (128, 128)))


def seeds() -> "st.SearchStrategy":
    return st.integers(0, 2**31 - 1)
