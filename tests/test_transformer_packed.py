"""Packed binary-LM forward: kernel routing, bit-exactness, serving.

``transformer_forward_packed`` must (a) trace its attention to the
blocked ``binary_attention`` Pallas launches and every projection to
the dense megakernels (launch-shape evidence via ``utils.jaxpr``),
(b) be bit-exact against the pure-jnp oracle path for registry
configs, and (c) serve through ``PackedInferenceServer`` via the
``packed_kind == 'transformer'`` seam.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels import ops as kops
from repro.models import cnn
from repro.models import transformer as TF
from repro.train import serve as SV
from repro.utils.jaxpr import pallas_launches

ARCHS = ("gemma2-9b", "starcoder2-3b")


def _packed_lm(name, *, max_len=8, seed=0):
    cfg = get_config(name, reduced=True)
    params = TF.init_binary_lm(jax.random.PRNGKey(seed), cfg)
    return TF.pack_transformer(params, cfg, max_len=max_len), cfg


def _tokens(batch, seq, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, 256, (batch, seq), dtype=np.uint8)


@pytest.mark.parametrize("name", ARCHS)
def test_pallas_matches_jnp_oracle(name):
    """Bit-exact: the integer XNOR-popcount score path and the packed
    projections make the pallas and jnp routes produce identical
    logits, not merely close ones."""
    packed, cfg = _packed_lm(name)
    toks = jnp.asarray(_tokens(2, 8))
    out_p = TF.transformer_forward_packed(packed, toks, backend="pallas")
    out_j = TF.transformer_forward_packed(packed, toks, backend="jnp")
    assert out_p.shape == (2, cfg.vocab_size)
    assert out_p.dtype == jnp.float32
    np.testing.assert_array_equal(np.asarray(out_p), np.asarray(out_j))


@pytest.mark.parametrize("name", ARCHS)
def test_launch_shapes(name):
    """The forward is made of Pallas launches: one blocked attention
    per layer — grid (B·Hq, Sq tiles, KV tiles) — plus the dense
    megakernel grids for Q/K/V/O, FFN, and the LM head."""
    packed, cfg = _packed_lm(name)
    toks = jnp.asarray(_tokens(2, 8))

    def fwd(t):
        return TF.transformer_forward_packed(packed, t, backend="pallas")

    launches = pallas_launches(fwd, toks)
    attn = [l for l in launches if "attention" in l.kernel]
    assert len(attn) == cfg.num_layers
    hq = cfg.num_heads
    for l in attn:
        # S=8 fits one q tile and one kv tile; heads ride the first axis.
        assert l.grid == (2 * hq, 1, 1), l
    dense = [l for l in launches if "attention" not in l.kernel]
    # 4 attention projections + 2 FFN matmuls per layer, 1 head readout,
    # plus a bitpack launch in front of each packed matmul.
    assert len(dense) >= 6 * cfg.num_layers + 1
    kinds = {l.kernel for l in dense}
    assert any("matmul" in k or "gemm" in k or "gemv" in k for k in kinds), \
        kinds


def test_dense_stack_validated():
    packed, _ = _packed_lm("gemma2-9b")
    toks = jnp.asarray(_tokens(1, 8))
    with pytest.raises(ValueError, match="dense_stack"):
        TF.transformer_forward_packed(packed, toks, dense_stack="residnet")


# ---------------------------------------------------------------------------
# packed_kind seam (models/cnn.py)
# ---------------------------------------------------------------------------

def test_packed_tree_seam():
    packed, cfg = _packed_lm("gemma2-9b")
    assert cnn.packed_kind(packed) == "transformer"
    assert cnn.packed_input_shape(packed) == (8,)
    widths = [blk[k]["w_packed"].shape[1]
              for blk in packed["blocks"]
              for k in ("wq", "wk", "wv", "wo", "w1", "w2")]
    widths.append(packed["head"]["w_packed"].shape[1])
    assert cnn.packed_dense_kw_words(packed) == max(widths)
    fwd = cnn.make_packed_forward(packed, backend="jnp")
    out = fwd(jnp.asarray(_tokens(1, 8)))
    assert out.shape == (1, cfg.vocab_size)
    with pytest.raises(ValueError, match="pack_transformer"):
        cnn.packed_kind({"bogus": 1})


# ---------------------------------------------------------------------------
# Serving through PackedInferenceServer
# ---------------------------------------------------------------------------

def _server(**kw):
    clock = SV.SimClock()
    kw.setdefault("max_batch", 8)
    kw.setdefault("default_deadline", 0.010)
    return SV.PackedInferenceServer(clock=clock, **kw), clock


def test_serves_registry_config():
    """Any registry config serves: register the packed LM, push tokens
    through the queue, get the same logits as the direct forward, on
    the GEMV route (reduced LM widths fit the resident block)."""
    packed, cfg = _packed_lm("gemma2-9b")
    srv, _ = _server()
    srv.register("lm", packed=packed, backend="jnp")
    assert srv.engine("lm").kind == "transformer"
    xs = list(_tokens(5, 8))
    got = srv.serve(xs)
    direct = TF.transformer_forward_packed(
        packed, jnp.asarray(np.stack(xs)), backend="jnp")
    assert len(got) == 5
    for i, g in enumerate(got):
        np.testing.assert_array_equal(g, np.asarray(direct[i]))
    kw = cnn.packed_dense_kw_words(packed)
    assert srv.route_for(5) == kops.dispatch_batch(8, kw) == "gemv"


def test_register_from_params_and_spec():
    """The params+spec route: spec is the ArchConfig, params come from
    init_binary_lm; the weight cache packs once (default max_len=16)."""
    cfg = get_config("starcoder2-3b", reduced=True)
    params = TF.init_binary_lm(jax.random.PRNGKey(3), cfg)
    srv, _ = _server()
    srv.register("lm", params, cfg, kind="transformer", backend="jnp")
    assert (srv.cache.misses, srv.cache.hits) == (1, 0)
    assert cnn.packed_input_shape(srv.engine("lm").packed) == (16,)
    xs = list(_tokens(3, 16, seed=1))
    got = srv.serve(xs)
    assert len(got) == 3 and got[0].shape == (cfg.vocab_size,)
    srv.register("lm", params, cfg, kind="transformer", backend="jnp")
    assert srv.cache.misses == 1 and srv.cache.hits == 1


def test_transformer_mesh_serving_rejected():
    packed, _ = _packed_lm("gemma2-9b")
    srv, _ = _server()
    with pytest.raises(ValueError, match="mesh"):
        srv.register("lm", packed=packed, backend="jnp", mesh=object())


def test_unknown_kind_message_names_transformer():
    srv, _ = _server()
    with pytest.raises(ValueError, match="transformer"):
        srv.register("m", {}, None, kind="rnn")
