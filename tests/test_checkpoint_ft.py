"""Checkpoint round-trip / atomicity / reshard + fault-tolerance loop."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (AsyncCheckpointer, latest_step,
                              load_checkpoint, save_checkpoint)
from repro.runtime.elastic import remesh_plan
from repro.runtime.fault_tolerance import (StepFailure, Supervisor,
                                           SupervisorConfig)


def _tree(key):
    return {"a": jax.random.normal(key, (8, 4)),
            "nested": {"b": jnp.arange(10), "step": jnp.int32(3)}}


def test_roundtrip(tmp_path):
    tree = _tree(jax.random.PRNGKey(0))
    save_checkpoint(str(tmp_path), 7, tree)
    assert latest_step(str(tmp_path)) == 7
    restored, meta = load_checkpoint(str(tmp_path), 7, tree)
    assert meta["step"] == 7
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_ignores_tmp(tmp_path):
    tree = _tree(jax.random.PRNGKey(1))
    save_checkpoint(str(tmp_path), 3, tree)
    os.makedirs(tmp_path / "step_00000009.tmp")   # crashed write
    assert latest_step(str(tmp_path)) == 3


def test_async_checkpointer(tmp_path):
    ck = AsyncCheckpointer(str(tmp_path))
    tree = _tree(jax.random.PRNGKey(2))
    ck.save(1, tree)
    ck.save(2, tree)     # waits for in-flight save
    ck.wait()
    assert latest_step(str(tmp_path)) == 2


def test_restore_with_reshard(tmp_path):
    """Elastic: restore under a different sharding (mesh change)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    save_checkpoint(str(tmp_path), 0, tree)
    mesh = jax.make_mesh((1,), ("data",))
    sh = {"w": NamedSharding(mesh, P("data"))}
    restored, _ = load_checkpoint(str(tmp_path), 0, tree, sh)
    assert restored["w"].sharding == sh["w"]
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(tree["w"]))


def test_supervisor_restart_and_resume(tmp_path):
    """Inject a failure at step 7; supervisor restarts from the step-4
    checkpoint and completes all 12 steps with a bit-identical data
    cursor (state counts steps applied exactly once after recovery)."""
    failed = {"done": False}

    def init_state():
        return {"x": jnp.float32(0.0)}

    def step_fn(state, i):
        if i == 7 and not failed["done"]:
            failed["done"] = True
            raise StepFailure("simulated node loss")
        return {"x": state["x"] + 1.0}, {}

    sup = Supervisor(SupervisorConfig(ckpt_dir=str(tmp_path), ckpt_every=5,
                                      min_deadline_s=10.0),
                     init_state, step_fn)
    state, report = sup.run(12)
    assert report.restarts == 1
    assert report.steps_done == 12
    # restarted from ckpt at step 4 (x=5.0) and re-ran 5..11
    assert float(state["x"]) == 12.0


def test_supervisor_straggler_redispatch(tmp_path):
    import time
    calls = {"n": 0}

    def init_state():
        return {"x": jnp.float32(0)}

    def step_fn(state, i):
        calls["n"] += 1
        if i == 5 and calls["n"] == 6:
            time.sleep(0.15)          # straggler step (deadline 0.1 x 3)
        return {"x": state["x"] + 1}, {}

    cfg = SupervisorConfig(ckpt_dir=str(tmp_path), ckpt_every=100,
                           min_deadline_s=0.05, deadline_factor=2.0)
    sup = Supervisor(cfg, init_state, step_fn)
    state, report = sup.run(8)
    assert report.steps_done == 8
    assert report.stragglers_redispatched >= 1


def test_supervisor_metrics_mirror_report(tmp_path):
    """The telemetry registry is the SupervisorReport's aggregatable
    twin: restart/straggler/heartbeat counts and the step gauge stay in
    lock-step with the report through failures and restarts."""
    from repro.telemetry import MetricsRegistry

    failed = {"done": False}

    def init_state():
        return {"x": jnp.float32(0.0)}

    def step_fn(state, i):
        if i == 3 and not failed["done"]:
            failed["done"] = True
            raise StepFailure("simulated node loss")
        return {"x": state["x"] + 1.0}, {}

    m = MetricsRegistry()
    sup = Supervisor(SupervisorConfig(ckpt_dir=str(tmp_path), ckpt_every=2,
                                      min_deadline_s=10.0),
                     init_state, step_fn, metrics=m)
    _, report = sup.run(6)
    assert report.restarts == 1
    assert m.value("supervisor.restarts") == report.restarts
    assert m.value("supervisor.heartbeats") == report.heartbeats
    assert m.value("supervisor.stragglers_redispatched") == \
        report.stragglers_redispatched
    assert m.value("supervisor.steps_done") == report.steps_done == 6
    # snapshot is the cross-process view: counters survive a merge
    other = MetricsRegistry()
    other.merge(m.snapshot())
    assert other.value("supervisor.heartbeats") == report.heartbeats
    # default: a supervisor with no shared registry still records
    sup2 = Supervisor(SupervisorConfig(ckpt_dir=str(tmp_path / "b"),
                                       ckpt_every=100, min_deadline_s=10.0),
                      init_state, step_fn)
    _, r2 = sup2.run(2)
    assert sup2.metrics.value("supervisor.heartbeats") == r2.heartbeats


def test_async_checkpointer_surfaces_worker_failure(tmp_path):
    """A save that raises in the background thread is re-raised from
    wait() — and from the NEXT save() — instead of being silently lost
    (the supervisor must not believe a checkpoint landed when it
    didn't)."""
    bad = tmp_path / "not_a_dir"
    bad.write_text("")                 # ckpt_dir is a FILE: makedirs raises
    ck = AsyncCheckpointer(str(bad))
    tree = _tree(jax.random.PRNGKey(3))
    ck.save(1, tree)                   # starts the doomed worker
    with pytest.raises(OSError):
        ck.wait()
    # the error is delivered exactly once; the checkpointer is reusable
    ck.wait()
    ck.save(2, tree)
    with pytest.raises(OSError):
        ck.save(3, tree)               # save() waits first -> re-raises


def test_supervisor_straggler_redispatch_applies_step_once(tmp_path):
    """Regression: the speculative re-dispatch must rerun step i from
    the PRE-step state.  With a counting step function the final state
    equals the step count even though one step ran twice (the double-
    apply bug made x == steps + 1)."""
    import time
    calls = {"n": 0}

    def init_state():
        return {"x": jnp.float32(0)}

    def step_fn(state, i):
        calls["n"] += 1
        if i == 5 and calls["n"] == 6:
            time.sleep(0.15)          # straggler: first attempt only
        return {"x": state["x"] + 1}, {}

    cfg = SupervisorConfig(ckpt_dir=str(tmp_path), ckpt_every=100,
                           min_deadline_s=0.05, deadline_factor=2.0)
    sup = Supervisor(cfg, init_state, step_fn)
    state, report = sup.run(8)
    assert report.stragglers_redispatched >= 1
    assert calls["n"] == 8 + report.stragglers_redispatched
    # every step applied exactly once, re-dispatches included
    assert float(state["x"]) == 8.0


def test_remesh_plan():
    assert remesh_plan(256, prefer_model=16).shape == (16, 16)
    assert remesh_plan(192, prefer_model=16).shape == (12, 16)
    # largest power-of-two divisor <= prefer_model when it no longer
    # divides
    assert remesh_plan(24, prefer_model=16).shape == (3, 8)


def test_remesh_plan_non_power_of_two_survivors():
    """Non-pow2 survivor counts (6, 3 devices): the model degree drops
    to the largest power-of-two divisor, down to 1 for odd counts."""
    assert remesh_plan(6, prefer_model=4).shape == (3, 2)
    assert remesh_plan(6, prefer_model=2).shape == (3, 2)   # 2 divides 6
    assert remesh_plan(3, prefer_model=4).shape == (3, 1)
    assert remesh_plan(1, prefer_model=8).shape == (1, 1)
    # the degree never grows past prefer_model on a shrink
    assert remesh_plan(8, prefer_model=2).shape == (4, 2)
    # min_model <= prefer_model may raise the degree above the
    # power-of-two divisor when it divides (non-pow2 degree is legal)
    assert remesh_plan(6, prefer_model=4, min_model=3).shape == (2, 3)


def test_remesh_plan_validation():
    with pytest.raises(ValueError, match="n_devices"):
        remesh_plan(0, prefer_model=2)
    with pytest.raises(ValueError, match="n_devices"):
        remesh_plan(-4, prefer_model=2)
    with pytest.raises(ValueError, match="prefer_model"):
        remesh_plan(4, prefer_model=0)
    with pytest.raises(ValueError, match="min_model"):
        remesh_plan(6, prefer_model=4, min_model=4)
    # min_model may never GROW the degree past prefer_model (regression:
    # 8 devices, prefer 2, min 4 used to return a (2, 4) mesh)
    with pytest.raises(ValueError, match="exceeds prefer_model"):
        remesh_plan(8, prefer_model=2, min_model=4)
