"""Direct unit tests for the compiled-HLO collective parser
(``utils/hlo.py``) on synthetic HLO text — previously exercised only
through the 8-device ``verify_sharded`` subprocess sweep.  The byte
model and the regex shapes are the contract the sharding analyzers
(``analysis.collectives``) and the probes build on."""
from repro.utils.hlo import collective_bytes, collective_kinds


def test_all_gather_output_bytes():
    hlo = ("  %ag = u32[8,16]{1,0} all-gather(u32[2,16]{1,0} %p), "
           "dimensions={0}\n")
    assert collective_kinds(hlo) == {"all-gather": 1}
    got = collective_bytes(hlo)
    assert got["all-gather"] == 8 * 16 * 4        # u32 output bytes
    assert got["total"] == got["all-gather"]


def test_all_reduce_double_counted():
    # all-reduce ~ reduce-scatter + all-gather ring: 2x the bytes.
    hlo = "  %ar = f32[128]{0} all-reduce(f32[128]{0} %x), to_apply=%a\n"
    got = collective_bytes(hlo)
    assert got["all-reduce"] == 2 * 128 * 4


def test_tuple_shaped_collective():
    hlo = ("  %cp = (f32[64]{0}, f32[64]{0}) collective-permute("
           "f32[64]{0} %x, f32[64]{0} %y)\n")
    assert collective_kinds(hlo) == {"collective-permute": 1}
    assert collective_bytes(hlo)["collective-permute"] == 2 * 64 * 4


def test_async_start_variant_matches():
    hlo = ("  %ag = bf16[32,8]{1,0} all-gather-start(bf16[4,8]{1,0} %p), "
           "dimensions={0}\n")
    assert collective_kinds(hlo) == {"all-gather": 1}
    assert collective_bytes(hlo)["all-gather"] == 32 * 8 * 2


def test_mixed_module_accumulates_per_kind():
    hlo = (
        "  %a = u32[16]{0} all-gather(u32[4]{0} %p), dimensions={0}\n"
        "  %b = u32[8]{0} all-gather(u32[2]{0} %q), dimensions={0}\n"
        "  %c = s32[4]{0} reduce-scatter(s32[16]{0} %r), to_apply=%add\n"
    )
    kinds = collective_kinds(hlo)
    assert kinds == {"all-gather": 2, "reduce-scatter": 1}
    got = collective_bytes(hlo)
    assert got["all-gather"] == (16 + 8) * 4
    assert got["reduce-scatter"] == 4 * 4
    assert got["total"] == got["all-gather"] + got["reduce-scatter"]


def test_non_collective_ops_ignored():
    hlo = ("  %d = f32[1024]{0} dot(f32[1024,64]{1,0} %w, f32[64]{0} %x)\n"
           "  %g = u32[8]{0} gather(u32[64]{0} %t, s32[8]{0} %i)\n")
    assert collective_kinds(hlo) == {}
    assert collective_bytes(hlo)["total"] == 0.0
