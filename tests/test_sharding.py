"""Sharding resolver unit tests + small-mesh lower/compile integration.

The production mesh is exercised by launch/dryrun.py (512 fake devices in
its own process); here we verify the RULES on a small in-process mesh.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.distributed import sharding as SH
from repro.models import model as M


def mesh_1x1():
    return jax.make_mesh((1, 1), ("data", "model"))


def fake_mesh(shape=(2, 4), axes=("data", "model")):
    # abstract mesh for spec resolution only (no device placement needed)
    import numpy as np
    devs = np.array(jax.devices() * (int(np.prod(shape)) //
                                     len(jax.devices()) + 1))
    return Mesh(devs[:int(np.prod(shape))].reshape(shape), axes)


def test_param_specs_rules():
    cfg = get_config("gemma2-9b", reduced=True)
    params = jax.eval_shape(lambda: M.init_model(jax.random.PRNGKey(0),
                                                 cfg))
    mesh = fake_mesh((2, 2))
    specs = SH.param_specs(params, mesh)
    # column-parallel qkv: (stack, d_in, d_out) -> (None, data, model)
    seg0 = specs["stack"][0]
    wq_spec = seg0[0]["attn"]["wq"]["w"]
    assert wq_spec == P(None, "data", "model")
    wo_spec = seg0[0]["attn"]["wo"]["w"]
    assert wo_spec == P(None, "model", "data")
    # embeddings: vocab over model
    assert specs["embed"]["table"] == P("model", "data")
    # norms replicated
    assert specs["ln_out"]["scale"] == P()


def test_param_specs_moe_expert_parallel():
    cfg = get_config("qwen3-moe-30b-a3b", reduced=True)
    params = jax.eval_shape(lambda: M.init_model(jax.random.PRNGKey(0),
                                                 cfg))
    mesh = fake_mesh((2, 2))
    specs = SH.param_specs(params, mesh)
    we = specs["stack"][0][0]["mlp"]["we_up"]["we"]
    assert we == P(None, "model", "data", None)    # (L, E, D, F)
    wd = specs["stack"][0][0]["mlp"]["we_down"]["we"]
    assert wd == P(None, "model", None, "data")


def test_divisibility_fallback():
    """whisper-base vocab 51865 % 16 != 0 -> vocab axis dropped."""
    cfg = get_config("whisper-base", reduced=False)
    params = jax.eval_shape(lambda: M.init_model(jax.random.PRNGKey(0),
                                                 cfg))
    mesh = fake_mesh((16, 16))
    specs = SH.param_specs(params, mesh)
    assert specs["embed"]["table"][0] is None      # 51865 not divisible


def test_batch_specs_seq_sharding():
    mesh = fake_mesh((2, 2))
    batch = {"tokens": jax.ShapeDtypeStruct((4, 64), jnp.int32)}
    bs = SH.batch_specs(batch, mesh)
    assert bs["tokens"] == P("data", None)         # pod absent -> data only
    bs_seq = SH.batch_specs(batch, mesh, shard_seq=True)
    assert bs_seq["tokens"] == P(None, "data")


def test_cache_specs():
    mesh = fake_mesh((2, 2))
    cache = {"k": jax.ShapeDtypeStruct((4, 2, 64, 2, 16), jnp.bfloat16),
             "v": jax.ShapeDtypeStruct((4, 2, 64, 2, 16), jnp.bfloat16)}
    cs = SH.cache_specs(cache, mesh)
    assert cs["k"] == P(None, "data", None, "model", None)
    cs_seq = SH.cache_specs(cache, mesh, shard_seq=True)
    assert cs_seq["k"] == P(None, None, "data", "model", None)


def test_lower_compile_small_mesh():
    """End-to-end lower+compile of a sharded train step on a 1x1 mesh
    (in-process analogue of the dry-run)."""
    from repro.train import trainer as TR
    cfg = get_config("starcoder2-3b", reduced=True)
    tc = TR.TrainConfig()
    mesh = mesh_1x1()
    state = jax.eval_shape(
        lambda: TR.init_train_state(jax.random.PRNGKey(0), cfg, tc))
    pspecs = SH.param_specs(state["params"], mesh)
    state_specs = {"params": pspecs,
                   "opt": {"mu": pspecs, "nu": pspecs, "step": P()}}
    batch = {"tokens": jax.ShapeDtypeStruct((2, 16), jnp.int32),
             "labels": jax.ShapeDtypeStruct((2, 16), jnp.int32)}
    ns = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                                is_leaf=lambda x: isinstance(x, P))
    step = jax.jit(TR.make_train_step(cfg, tc),
                   in_shardings=(ns(state_specs),
                                 ns(SH.batch_specs(batch, mesh))))
    with mesh:
        compiled = step.lower(state, batch).compile()
    assert compiled.cost_analysis() is not None
    mem = compiled.memory_analysis()
    assert mem is not None


def test_collective_bytes_parser():
    from repro.launch import dryrun as DR
    hlo = """
  %ag = bf16[16,128]{1,0} all-gather(%x), replica_groups={}
  %ar = f32[64]{0} all-reduce-start(%y), to_apply=%sum
  %rs = f32[8,8]{1,0} reduce-scatter(%z), dimensions={0}
  %a2a = (f32[4,4]{1,0}, f32[4,4]{1,0}) all-to-all(%p, %q)
"""
    got = DR.collective_bytes(hlo)
    assert got["all-gather"] == 16 * 128 * 2
    assert got["all-reduce"] == 64 * 4 * 2        # 2x ring factor
    assert got["reduce-scatter"] == 64 * 4
    assert got["all-to-all"] == 2 * 16 * 4
    assert got["total"] == sum(v for k, v in got.items() if k != "total")
