"""Property-testing layer: real ``hypothesis`` when installed, otherwise
a small deterministic fallback engine with the same surface.

CI installs hypothesis (requirements-dev.txt) and runs the suite with
``--hypothesis-seed=0``; environments without it (e.g. a bare container)
used to *skip* every property test via an inert shim.  The fallback now
actually RUNS each property: strategies draw from a seeded
``random.Random`` keyed on the test name, so the sampled shape grid is
identical run-to-run and a failure reproduces immediately.  Supported
surface (the subset ``tests/strategies.py`` uses): ``st.integers``,
``st.floats``, ``st.booleans``, ``st.sampled_from``, ``st.just``,
``st.one_of``, ``st.tuples``, ``st.lists``, ``.map``/``.filter``,
``@hypothesis.given`` (keyword style) and
``hypothesis.settings(max_examples=, deadline=)`` in either decorator
order.

Usage in test modules::

    from _hypothesis_compat import hypothesis, st
"""
from __future__ import annotations

try:
    import hypothesis
    import hypothesis.strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:

    import random
    import types
    import zlib

    HAVE_HYPOTHESIS = False
    _DEFAULT_MAX_EXAMPLES = 10

    class _Strategy:
        """A draw function ``random.Random -> value`` with map/filter."""

        def __init__(self, draw):
            self._draw = draw

        def map(self, f):
            return _Strategy(lambda r: f(self._draw(r)))

        def filter(self, pred):
            def draw(r):
                for _ in range(1000):
                    v = self._draw(r)
                    if pred(v):
                        return v
                raise RuntimeError("filter predicate rejected 1000 draws")
            return _Strategy(draw)

        def example(self):
            return self._draw(random.Random(0))

    def _integers(min_value, max_value):
        return _Strategy(lambda r: r.randint(min_value, max_value))

    def _floats(min_value, max_value):
        return _Strategy(lambda r: r.uniform(min_value, max_value))

    def _booleans():
        return _Strategy(lambda r: r.random() < 0.5)

    def _sampled_from(seq):
        seq = list(seq)
        return _Strategy(lambda r: seq[r.randrange(len(seq))])

    def _just(value):
        return _Strategy(lambda r: value)

    def _one_of(*strategies):
        if len(strategies) == 1 and isinstance(strategies[0], (list, tuple)):
            strategies = tuple(strategies[0])
        return _Strategy(
            lambda r: strategies[r.randrange(len(strategies))]._draw(r))

    def _tuples(*strategies):
        return _Strategy(lambda r: tuple(s._draw(r) for s in strategies))

    def _lists(elements, *, min_size=0, max_size=8):
        return _Strategy(lambda r: [elements._draw(r) for _ in
                                    range(r.randint(min_size, max_size))])

    def _given(**strategy_kwargs):
        def deco(fn):
            # NOT functools.wraps: copying __wrapped__ would make pytest
            # read the inner signature and treat the strategy kwargs as
            # fixtures.  The runner must look zero-argument.
            def runner():
                n = getattr(runner, "_max_examples", _DEFAULT_MAX_EXAMPLES)
                base = zlib.crc32(fn.__qualname__.encode())
                for i in range(n):
                    rng = random.Random(base + i)
                    kwargs = {k: s._draw(rng)
                              for k, s in strategy_kwargs.items()}
                    try:
                        fn(**kwargs)
                    except Exception as e:
                        raise AssertionError(
                            f"property falsified on example {i}: "
                            f"{kwargs!r}") from e
            runner.__name__ = fn.__name__
            runner.__qualname__ = fn.__qualname__
            runner.__doc__ = fn.__doc__
            # Support BOTH decorator orders: @settings above @given sets
            # _max_examples on the runner later; @given above @settings
            # already set it on the inner fn — propagate it up.
            if hasattr(fn, "_max_examples"):
                runner._max_examples = fn._max_examples
            return runner
        return deco

    def _settings(*, max_examples=_DEFAULT_MAX_EXAMPLES, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    hypothesis = types.SimpleNamespace(given=_given, settings=_settings)
    st = types.SimpleNamespace(
        integers=_integers, booleans=_booleans, sampled_from=_sampled_from,
        just=_just, one_of=_one_of, tuples=_tuples, lists=_lists,
        floats=_floats)

__all__ = ["hypothesis", "st", "HAVE_HYPOTHESIS"]
