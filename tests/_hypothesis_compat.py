"""Import shim so the suite runs with or without ``hypothesis``.

``pytest.importorskip`` at module level would skip *every* test in a
module, including the plain parametrized ones that don't need
hypothesis.  Instead: re-export the real library when available, and
otherwise substitute stubs where ``@hypothesis.given(...)`` turns the
property test into a single skipped test and strategy constructors are
inert.  Usage in test modules::

    from _hypothesis_compat import hypothesis, st
"""
from __future__ import annotations

try:
    import hypothesis
    import hypothesis.strategies as st
except ModuleNotFoundError:
    import types

    import pytest

    def _given(*_args, **_kwargs):
        def deco(fn):
            def stub():
                pytest.skip("hypothesis not installed "
                            "(pip install -r requirements-dev.txt)")
            stub.__name__ = fn.__name__
            stub.__doc__ = fn.__doc__
            return stub
        return deco

    def _settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco

    def _strategy(*_args, **_kwargs):
        return None

    hypothesis = types.SimpleNamespace(given=_given, settings=_settings)
    st = types.SimpleNamespace(
        integers=_strategy, floats=_strategy, booleans=_strategy,
        sampled_from=_strategy, lists=_strategy, tuples=_strategy,
        just=_strategy, one_of=_strategy)

__all__ = ["hypothesis", "st"]
