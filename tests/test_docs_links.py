"""Docs integrity: every intra-repo reference in docs/*.md + README.md
must point at a file that exists.

Two classes of reference are checked:

* markdown links ``[text](target)`` with a relative target (external
  schemes and pure #anchors are skipped) — resolved against the file's
  own directory;
* backticked repo paths like ``src/repro/train/serve.py`` or
  ``tests/test_serve_batching.py::test_x`` — resolved against the
  file's directory first, then the repo root (docs habitually name
  root-relative paths).

The CI ``docs`` job runs this file; it also rides tier-1 so a PR that
moves a file learns about dangling docs immediately.
"""
import os
import re

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_MD_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
# backtick tokens that look like repo file paths (optionally with a
# ::test suffix); globs and bench-row names don't match the extension
_MD_PATH = re.compile(
    r"`([\w][\w./-]*\.(?:py|md|json|yml|yaml|txt))(?:::[\w.\[\]-]+)?`")


def _doc_files():
    files = [os.path.join(ROOT, "README.md")]
    docs = os.path.join(ROOT, "docs")
    for name in sorted(os.listdir(docs)):
        if name.endswith(".md"):
            files.append(os.path.join(docs, name))
    return files


def _repo_file_suffixes():
    suffixes = set()
    for dirpath, dirnames, filenames in os.walk(ROOT):
        dirnames[:] = [d for d in dirnames
                       if not d.startswith(".") and d != "__pycache__"]
        for f in filenames:
            rel = os.path.relpath(os.path.join(dirpath, f), ROOT)
            parts = rel.replace(os.sep, "/").split("/")
            for i in range(len(parts)):
                suffixes.add("/".join(parts[i:]))
    return suffixes


_SUFFIXES = _repo_file_suffixes()


def _resolve(base_dir: str, target: str) -> bool:
    target = target.split("#", 1)[0]
    if not target:
        return True                       # same-file anchor
    cand = os.path.normpath(os.path.join(base_dir, target))
    alt = os.path.normpath(os.path.join(ROOT, target))
    if os.path.exists(cand) or os.path.exists(alt):
        return True
    # docs shorthand: a module named relative to its package
    # (`binary_matmul.py`, `kernels/ops.py`) resolves if some repo file
    # ends with that path; truly dangling names still fail.
    return os.path.normpath(target).replace(os.sep, "/") in _SUFFIXES


@pytest.mark.parametrize("path", _doc_files(),
                         ids=lambda p: os.path.relpath(p, ROOT))
def test_intra_repo_references_resolve(path):
    text = open(path, encoding="utf-8").read()
    base = os.path.dirname(path)
    broken = []
    for m in _MD_LINK.finditer(text):
        target = m.group(1)
        if re.match(r"^[a-z][a-z0-9+.-]*:", target) or \
                target.startswith("#"):
            continue                      # external scheme / anchor
        if not _resolve(base, target):
            broken.append(f"link -> {target}")
    for m in _MD_PATH.finditer(text):
        if not _resolve(base, m.group(1)):
            broken.append(f"path -> `{m.group(1)}`")
    assert not broken, (
        f"{os.path.relpath(path, ROOT)} has dangling references:\n  "
        + "\n  ".join(broken))


def test_docs_exist():
    """The documented doc set itself: the docs archetype headliners."""
    for rel in ("README.md", "docs/serving.md", "docs/architecture.md",
                "docs/kernels.md"):
        assert os.path.exists(os.path.join(ROOT, rel)), rel
