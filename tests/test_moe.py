"""MoE dispatch tests: oracle equivalence, capacity semantics, weights."""
import dataclasses

from _hypothesis_compat import hypothesis, st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import moe as MoE

settings = hypothesis.settings(max_examples=10, deadline=None)


def _cfg(cf=8.0, top_k=2, experts=4):
    cfg = get_config("qwen3-moe-30b-a3b", reduced=True)
    return dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, capacity_factor=cf, top_k=top_k, num_experts=experts))


@settings
@hypothesis.given(seed=st.integers(0, 2**31 - 1), b=st.integers(1, 3),
                  s=st.sampled_from([4, 8, 16]))
def test_dispatch_matches_dense_oracle_ample_capacity(seed, b, s):
    cfg = _cfg(cf=8.0)
    key = jax.random.PRNGKey(seed)
    params = MoE.init_moe(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 1), (b, s, cfg.d_model)
                          ).astype(cfg.activation_dtype)
    got = MoE.apply_moe(params, cfg, x)
    want = MoE.moe_dense_reference(params, cfg, x)
    np.testing.assert_allclose(np.asarray(got.astype(jnp.float32)),
                               np.asarray(want.astype(jnp.float32)),
                               rtol=5e-2, atol=5e-2)


def test_capacity_drops_tokens():
    """With capacity 4 slots/expert and adversarial routing, overflow
    tokens contribute zero (GShard drop semantics)."""
    cfg = _cfg(cf=0.25, top_k=1, experts=4)
    key = jax.random.PRNGKey(0)
    params = MoE.init_moe(key, cfg)
    # zero router -> all logits tie -> top-1 always picks expert 0
    params["router"]["w"] = jnp.zeros_like(params["router"]["w"])
    x = jax.random.normal(jax.random.fold_in(key, 2), (1, 16, cfg.d_model)
                          ).astype(cfg.activation_dtype)
    got = MoE.apply_moe(params, cfg, x)
    # capacity = max(4, 16*1/4*0.25)=4 -> only 4 of 16 tokens processed
    nonzero_tokens = int((jnp.abs(got[0].astype(jnp.float32)).sum(-1)
                          > 1e-6).sum())
    assert nonzero_tokens == 4


def test_router_weights_normalized():
    cfg = _cfg()
    key = jax.random.PRNGKey(1)
    params = MoE.init_moe(key, cfg)
    x = jax.random.normal(key, (2, 8, cfg.d_model))
    # top-k weights renormalize to 1 -> output scale independent of k
    y = MoE.moe_dense_reference(params, cfg, x)
    assert jnp.isfinite(y.astype(jnp.float32)).all()


def test_shared_expert_added():
    cfg = get_config("llama4-maverick-400b-a17b", reduced=True)
    params = MoE.init_moe(jax.random.PRNGKey(0), cfg)
    assert "shared" in params      # llama4: 1 shared expert
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 4, cfg.d_model)
                          ).astype(cfg.activation_dtype)
    y = MoE.apply_moe(params, cfg, x)
    assert y.shape == x.shape


@settings
@hypothesis.given(seed=st.integers(0, 2**31 - 1))
def test_dispatch_indices_bijective(seed):
    """Every kept (token, k) slot appears in exactly one expert slot."""
    key = jax.random.PRNGKey(seed)
    t, k, e, c = 16, 2, 4, 16     # ample capacity
    sel = jax.random.randint(key, (t, k), 0, e)
    slot_token, slot_flat = MoE._dispatch_indices(sel, e, c)
    flat = np.asarray(slot_flat).ravel()
    kept = flat[flat >= 0]
    assert len(kept) == t * k
    assert len(np.unique(kept)) == t * k
    # expert assignment consistent
    st_np = np.asarray(slot_token)
    sel_np = np.asarray(sel)
    for ei in range(e):
        for ci in range(c):
            f = int(slot_flat[ei, ci])
            if f >= 0:
                assert sel_np[f // k, f % k] == ei
                assert st_np[ei, ci] == f // k
