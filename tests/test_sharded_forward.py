"""Sharded packed BCNN/BMLP forward: spec rules, shard plans, and the
single-device-equivalence plumbing.

Rule/plan tests resolve specs on an abstract mesh (no placement).  The
real multi-device sweep — bit-exactness vs the single-device forward on
an 8-way forced-CPU mesh for (data, model) in {(8,1), (4,2), (2,4)},
zero collectives on the data-parallel path — needs its own process
(device count is fixed at jax init), so it runs
`repro.distributed.verify_sharded` as a subprocess, exactly like the CI
sharding job does.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as SH
from repro.launch.mesh import make_mesh
from repro.models import cnn

from test_sharding import fake_mesh


def small_bcnn(c0=64, c1=48, dense=(128, 10)):
    spec = cnn.BCNNSpec(input_hw=(8, 8), c_in=3,
                        stages=(cnn.ConvStage(c0),
                                cnn.ConvStage(c1, pool=True)),
                        dense=dense)
    params = cnn.init_bcnn(jax.random.PRNGKey(0), spec)
    return cnn.pack_bcnn(params, spec), spec


def small_bmlp(sizes=(784, 128, 96, 10)):
    spec = cnn.BMLPSpec(sizes=sizes)
    params = cnn.init_bmlp(jax.random.PRNGKey(0), spec)
    return cnn.pack_bmlp(params, spec), spec


def test_packed_stage_shards_word_seam():
    """The C_out -> packed-word seam: shard only when every model shard
    owns whole 32-bit words."""
    mesh2 = fake_mesh((4, 2))
    mesh4 = fake_mesh((2, 4))
    assert SH.packed_stage_shards(64, mesh2) == 2     # 64 % 64 == 0
    assert SH.packed_stage_shards(48, mesh2) == 1     # 48 % 64 != 0
    assert SH.packed_stage_shards(64, mesh4) == 1     # 64 % 128 != 0
    assert SH.packed_stage_shards(128, mesh4) == 4
    assert SH.packed_stage_shards(64, fake_mesh((8, 1))) == 1


def test_bcnn_shard_plan_and_specs():
    packed, _ = small_bcnn()
    mesh = fake_mesh((4, 2))
    plan = SH.bcnn_shard_plan(packed, mesh)
    assert plan["conv"] == (2, 1)        # 48-channel stage falls back
    assert plan["dense"] == (2, 1)       # output layer always replicated
    specs = SH.packed_param_specs(packed, mesh)
    assert specs["convs/0/w_packed"] == P("model")
    assert specs["convs/0/rowsum"] == P("model")       # bit-plane stage 0
    assert specs["convs/1/w_packed"] == P()            # fallback
    assert specs["convs/1/correction"] == P()
    assert specs["folded_conv/0/tau"] == P("model")
    assert specs["folded_conv/1/tau"] == P()
    assert specs["denses/0/w_packed"] == P("model")
    assert specs["denses/1/w_packed"] == P()           # logits layer
    assert specs["bn_out/gamma"] == P()
    # statics (plan ints, pads, the spec dataclass) get no spec at all
    assert "convs/0/k_true" not in specs
    assert "spec" not in specs


def test_bcnn_pool_mask_spec_follows_stage():
    packed, _ = small_bcnn(c0=64, c1=64)
    specs = SH.packed_param_specs(packed, fake_mesh((4, 2)))
    assert specs["pool_masks/1"] == P("model")
    packed48, _ = small_bcnn(c0=64, c1=48)
    specs48 = SH.packed_param_specs(packed48, fake_mesh((4, 2)))
    assert specs48["pool_masks/1"] == P()


def test_bmlp_shard_plan_and_specs():
    packed, _ = small_bmlp()
    mesh = fake_mesh((4, 2))
    plan = SH.bmlp_shard_plan(packed, mesh)
    assert plan["layer"] == (2, 1, 1)    # 96 falls back, 10 replicated
    specs = SH.packed_param_specs(packed, mesh)
    assert specs["layers/0/w_packed"] == P("model")
    assert specs["layers/0/w_rowsum"] == P("model")
    assert specs["layers/1/w_packed"] == P()
    assert specs["folded/0/tau"] == P("model")
    assert specs["folded/1/flip"] == P()


def test_packed_kind_rejects_other_trees():
    with pytest.raises(ValueError):
        SH._packed_kind({"not": "a packed tree"})


@pytest.mark.parametrize("kind", ["bcnn", "bmlp"])
def test_sharded_forward_1x1_mesh_equals_unsharded(kind):
    """End-to-end plumbing (partition/rebuild, shard_map, NamedSharding
    placement) on the in-process single-device mesh."""
    mesh = make_mesh((1, 1), ("data", "model"))
    if kind == "bcnn":
        packed, spec = small_bcnn()
        x = jax.random.randint(jax.random.PRNGKey(1), (2, 8, 8, 3), 0,
                               256).astype(jnp.uint8)
        want = cnn.bcnn_forward_packed(packed, x, backend="jnp")
    else:
        packed, spec = small_bmlp()
        x = jax.random.randint(jax.random.PRNGKey(1), (2, 784), 0,
                               256).astype(jnp.uint8)
        want = cnn.bmlp_forward_packed(packed, x, backend="jnp")
    fwd = SH.make_sharded_forward(packed, mesh, backend="jnp")
    np.testing.assert_array_equal(np.asarray(fwd(x)), np.asarray(want))


def test_forward_rejects_sharded_output_layer():
    packed, _ = small_bcnn()
    x = jnp.zeros((1, 8, 8, 3), jnp.uint8)
    with pytest.raises(AssertionError):
        cnn.bcnn_forward_packed(packed, x, backend="jnp",
                                dense_shards=(1, 2))


@pytest.mark.skipif(bool(os.environ.get("REPRO_SKIP_SHARDED_SWEEP")),
                    reason="sweep already run directly (CI sharding job)")
def test_sharded_forward_8dev_sweep_bit_exact():
    """The real thing: 8 forced CPU devices in a fresh process, all three
    mesh shapes, both networks, jnp + pallas backends — bit-identical to
    the single-device forward, collective-free on the data-parallel path,
    all-gather-of-packed-words only on the model-parallel path."""
    from repro.distributed.subproc import run_verifier
    results = run_verifier()
    meshes = {(tuple(r["mesh"]), r["kind"], r["backend"]) for r in results}
    for shape in ((8, 1), (4, 2), (2, 4)):
        assert (shape, "bcnn", "jnp") in meshes
        assert (shape, "bmlp", "jnp") in meshes
    assert any(r["backend"] == "pallas" for r in results)
    for r in results:
        assert r["bitexact"], r
        assert r["ok"], r
        if r["mesh"][1] == 1:
            assert r["collective_bytes"] == 0.0, r
    # the fallback stage really fell back (48 not word-divisible at 2)
    bcnn42 = next(r for r in results
                  if r["kind"] == "bcnn" and r["mesh"] == [4, 2])
    assert bcnn42["shard_plan"]["conv"][1] == 1
    assert bcnn42["shard_plan"]["conv"][0] == 2
