"""Fault-tolerant serving: the injection matrix, request lifecycle
semantics under SimClock, elastic degradation, packed checkpoints.

The fault matrix (transient / persistent / poison / device_loss / slow)
runs in-process on a single device — the 8-device shrunken-mesh
bit-exactness cell rides ``distributed/verify_sharded.py`` (the
``degrade`` cell), which ``tests/test_sharded_forward.py`` runs as a
subprocess.  Every scenario here asserts the two invariants the chaos
CI job enforces end-to-end: each admitted request reaches exactly one
terminal state, and the server keeps serving afterwards.
"""
import jax
import numpy as np
import pytest

from repro.checkpoint import (latest_step, load_packed_checkpoint,
                              save_packed_checkpoint)
from repro.models import cnn
from repro.runtime import (FaultInjector, FaultPlan, FaultSpec,
                           ServingSupervisor)
from repro.runtime.faults import (PersistentFlushError, PoisonRequestError,
                                  TransientFlushError)
from repro.train import serve as SV

SIZES = (64, 64, 10)


@pytest.fixture(scope="module")
def packed():
    spec = cnn.BMLPSpec(sizes=SIZES)
    params = cnn.init_bmlp(jax.random.PRNGKey(0), spec)
    return cnn.pack_bmlp(params, spec)


@pytest.fixture(scope="module")
def batch(packed):
    x = np.asarray(jax.random.randint(jax.random.PRNGKey(1), (8, SIZES[0]),
                                      0, 256), np.uint8)
    want = np.asarray(cnn.bmlp_forward_packed(packed, x, backend="jnp"))
    return x, want


def mk_server(packed, plan=None, **kw):
    clock = SV.SimClock()
    srv = SV.PackedInferenceServer(max_batch=8, default_deadline=0.005,
                                   clock=clock, **kw)
    srv.register("m", packed=packed, backend="jnp")
    inj = FaultInjector(plan).attach(srv) if plan is not None else None
    return srv, clock, inj


def submit_all(srv, x, idx):
    return [srv.submit(x[i]) for i in idx]


def assert_serves_after(srv, clock, x, want):
    """The post-fault invariant: a clean follow-up wave completes ok and
    bit-exact (the fault did not wedge the queue or the engine)."""
    srv.flush_hook = None
    rids = submit_all(srv, x, range(4))
    clock.advance(0.006)     # past deadline, inside any grace window
    done = {r.rid: r for r in srv.step()}
    assert [done[r].status for r in rids] == ["ok"] * 4
    got = np.stack([done[r].result for r in rids])
    np.testing.assert_array_equal(got, want[:4])


# ---------------------------------------------------------------------------
# the fault matrix
# ---------------------------------------------------------------------------

def test_transient_fault_retried_to_ok(packed, batch):
    """A dispatch failure inside the retry budget is invisible to the
    caller: all ok, retries counted, FlushRecord carries the attempts."""
    x, want = batch
    srv, clock, inj = mk_server(
        packed, FaultPlan.of(FaultSpec("transient", times=2)))
    rids = submit_all(srv, x, range(8))
    done = {r.rid: r for r in srv.step()}
    assert [done[r].status for r in rids] == ["ok"] * 8
    np.testing.assert_array_equal(
        np.stack([done[r].result for r in rids]), want)
    m = srv.telemetry.metrics
    assert m.value("serve.retries") == 2
    assert m.value("serve.errors") == 0
    assert srv.flushes[-1].retries == 2
    assert isinstance(inj.injected[0]["kind"], str)
    assert_serves_after(srv, clock, x, want)


def test_transient_beyond_budget_errors_cohort(packed, batch):
    """times > max_retries on a singleton: retries exhaust, the request
    completes as error carrying the LAST exception."""
    x, want = batch
    srv, clock, _ = mk_server(
        packed, FaultPlan.of(FaultSpec("transient", times=99)),
        retry=SV.RetryPolicy(max_retries=1))
    rid = srv.submit(x[0])
    clock.advance(1.0)
    done = srv.step()
    assert [r.status for r in done] == ["error"]
    assert isinstance(done[0].error, TransientFlushError)
    assert done[0].result is None
    assert_serves_after(srv, clock, x, want)


def test_poison_request_isolated_by_bisection(packed, batch):
    """One poison rid errors ALONE; its 7 cohort-mates serve bit-exact;
    bisection (not blanket retry) is what found it."""
    x, want = batch
    srv, clock, _ = mk_server(packed,
                              FaultPlan.of(FaultSpec("poison", rid=3)))
    rids = submit_all(srv, x, range(8))
    done = {r.rid: r for r in srv.step()}
    assert done[3].status == "error"
    assert isinstance(done[3].error, PoisonRequestError)
    ok = [r for r in rids if r != 3]
    assert all(done[r].status == "ok" for r in ok)
    np.testing.assert_array_equal(
        np.stack([done[r].result for r in ok]),
        want[[i for i in range(8) if i != 3]])
    m = srv.telemetry.metrics
    assert m.value("serve.bisections") > 0
    assert m.value("serve.errors") == 1
    assert_serves_after(srv, clock, x, want)


def test_persistent_fault_fails_only_its_cohort(packed, batch):
    """A never-healing flush errors its whole window (after retries and
    bisection drain), but traffic admitted AFTER the fault is clean —
    failure isolation, the server does not die."""
    x, want = batch
    srv, clock, _ = mk_server(packed,
                              FaultPlan.of(FaultSpec("persistent")))
    rids = submit_all(srv, x, range(4))
    clock.advance(1.0)
    done = {r.rid: r for r in srv.step()}
    assert [done[r].status for r in rids] == ["error"] * 4
    assert all(isinstance(done[r].error, PersistentFlushError)
               for r in rids)
    assert_serves_after(srv, clock, x, want)


def test_slow_flush_ages_queue_into_timeout(packed, batch):
    """The slow flush itself completes (its window was already triaged),
    but requests queued behind it age past timeout_grace and complete
    as timeout — not served stale."""
    x, want = batch
    srv, clock, _ = mk_server(
        packed, FaultPlan.of(FaultSpec("slow", delay_s=1.0)),
        timeout_grace=2.0)
    first = submit_all(srv, x, range(4))
    clock.advance(0.006)                 # past deadline, inside grace
    done = {r.rid: r for r in srv.step()}   # 1 s clock jump inside
    assert [done[r].status for r in first] == ["ok"] * 4
    late = submit_all(srv, x, range(4, 8))
    clock.advance(0.100)                 # grace is 10 ms: way past
    done2 = {r.rid: r for r in srv.step()}
    assert [done2[r].status for r in late] == ["timeout"] * 4
    assert all(done2[r].result is None for r in late)
    assert srv.telemetry.metrics.value("serve.timeouts") == 4
    assert_serves_after(srv, clock, x, want)


def test_device_loss_degrades_and_serves_requeued(packed, batch):
    """Injected device loss: the window is requeued (zero lost), the
    supervisor remeshes onto the survivors and the SAME rids complete
    ok and bit-exact on the rebuilt engine."""
    x, want = batch
    srv, clock, _ = mk_server(
        packed, FaultPlan.of(FaultSpec("device_loss", survivors=1)))
    sup = ServingSupervisor(srv, "m", backend="jnp")
    rids = submit_all(srv, x, range(8))
    done = {r.rid: r for r in sup.step()}
    assert [done[r].status for r in rids] == ["ok"] * 8
    np.testing.assert_array_equal(
        np.stack([done[r].result for r in rids]), want)
    assert sup.events == [sup.events[0]]
    assert sup.events[0].requeued == 8
    assert sup.events[0].mesh_shape == (1, 1)
    m = srv.telemetry.metrics
    assert m.value("serve.degraded") == 1
    assert m.value("serve.degraded_state") == 0
    assert_serves_after(srv, clock, x, want)


def test_device_loss_during_bisection_loses_no_requests(packed, batch):
    """Regression: device loss striking INSIDE the bisection recursion
    must requeue the whole original window, not just the half that was
    dispatching — the not-yet-dispatched siblings used to be silently
    lost (no terminal state, take() None forever).

    Script (max_retries=0 keeps the dispatch count deterministic):
    d0 cohort [0,1,2,3] hits poison rid=1 → bisect; d1 [0,1] poison →
    bisect; d2 singleton [0] is clean of the poison, so the armed
    device loss fires there — with [1] and [2,3] never dispatched.
    """
    x, want = batch
    srv, clock, _ = mk_server(
        packed,
        FaultPlan.of(FaultSpec("poison", rid=1),
                     FaultSpec("device_loss", survivors=1, at_dispatch=2)),
        retry=SV.RetryPolicy(max_retries=0))
    sup = ServingSupervisor(srv, "m", backend="jnp")
    rids = submit_all(srv, x, range(4))
    clock.advance(1.0)
    with pytest.raises(SV.DeviceLossError):
        srv.step()
    # the sharp invariant: ALL four requests are back, in FIFO order
    assert srv.pending() == 4
    assert [r.rid for r in srv._queue] == rids
    # recovery: degrade + re-step completes every rid terminally
    sup.degrade(1)
    done = {r.rid: r for r in srv.step()}
    assert sorted(done) == rids
    assert done[1].status == "error"
    assert isinstance(done[1].error, PoisonRequestError)
    ok = [r for r in rids if r != 1]
    assert all(done[r].status == "ok" for r in ok)
    np.testing.assert_array_equal(
        np.stack([done[r].result for r in ok]),
        want[[i for i in range(4) if i != 1]])
    assert srv.telemetry.metrics.value("serve.bisections") > 0
    assert_serves_after(srv, clock, x, want)


def test_device_loss_during_bisection_supervised_end_to_end(packed, batch):
    """The same overlap driven through ServingSupervisor.step — the
    chaos-CI path: one supervised step absorbs the mid-bisection loss,
    degrades, and finishes every rid."""
    x, want = batch
    srv, clock, _ = mk_server(
        packed,
        FaultPlan.of(FaultSpec("poison", rid=1),
                     FaultSpec("device_loss", survivors=1, at_dispatch=2)),
        retry=SV.RetryPolicy(max_retries=0))
    sup = ServingSupervisor(srv, "m", backend="jnp")
    rids = submit_all(srv, x, range(4))
    clock.advance(1.0)
    done = {r.rid: r for r in sup.step()}
    for rid in rids:
        assert rid in done
        assert done[rid].status in SV.TERMINAL_STATES
    assert done[1].status == "error"
    assert all(done[r].status == "ok" for r in rids if r != 1)
    assert sup.events[0].requeued == 4
    assert srv.pending() == 0
    assert_serves_after(srv, clock, x, want)


def test_device_loss_warm_restores_from_checkpoint(packed, batch, tmp_path):
    """With a ckpt_dir and a healthy-path checkpoint, degrade restores
    the packed tree from disk (reshard-on-restore), not the live tree."""
    x, want = batch
    srv, clock, _ = mk_server(
        packed, FaultPlan.of(FaultSpec("device_loss", survivors=1)))
    sup = ServingSupervisor(srv, "m", ckpt_dir=str(tmp_path),
                            backend="jnp")
    assert sup.checkpoint() is not None
    assert latest_step(str(tmp_path)) == 0
    rids = submit_all(srv, x, range(8))
    done = {r.rid: r for r in sup.step()}
    assert all(done[r].status == "ok" for r in rids)
    np.testing.assert_array_equal(
        np.stack([done[r].result for r in rids]), want)
    assert sup.events[0].restored_from == "checkpoint"


def test_every_fault_kind_reaches_exactly_one_terminal_state(packed, batch):
    """The matrix invariant, all five kinds in one scripted run: every
    admitted rid ends in exactly one of TERMINAL_STATES and the
    mailbox agrees with the step() returns."""
    x, want = batch
    submitted, finished = [], {}

    def drive(plan, n, supervised=None, advance=1.0, **kw):
        srv, clock, _ = mk_server(packed, plan, **kw)
        sup = supervised and ServingSupervisor(srv, "m", backend="jnp")
        rids = submit_all(srv, x, range(n))
        clock.advance(advance)
        stepper = sup.step if sup else srv.step
        done = list(stepper())
        while srv.pending():
            clock.advance(advance)
            done += stepper()
        return rids, {r.rid: r for r in done}

    cases = [
        (FaultPlan.of(FaultSpec("transient", times=1)), {}, {}),
        (FaultPlan.of(FaultSpec("persistent")), {}, {}),
        (FaultPlan.of(FaultSpec("poison", rid=2)), {}, {}),
        (FaultPlan.of(FaultSpec("device_loss", survivors=1)),
         {"supervised": True}, {}),
        (FaultPlan.of(FaultSpec("slow", delay_s=1.0)),
         {}, {"timeout_grace": 2.0}),
    ]
    for plan, drive_kw, srv_kw in cases:
        rids, done = drive(plan, 8, **drive_kw, **srv_kw)
        for rid in rids:
            assert rid in done, (plan, rid)
            assert done[rid].status in SV.TERMINAL_STATES, (plan, rid)


# ---------------------------------------------------------------------------
# request-lifecycle semantics under SimClock (satellite)
# ---------------------------------------------------------------------------

def test_deadline_exceeded_completes_as_timeout(packed, batch):
    """A request whose deadline budget is exceeded by more than the
    grace factor is NEVER dispatched — it completes as timeout with no
    result, and the flush serves only the live cohort."""
    x, want = batch
    srv, clock, _ = mk_server(packed, timeout_grace=2.0)
    stale = srv.submit(x[0])             # budget 5 ms, grace cutoff 10 ms
    clock.advance(0.050)
    fresh = srv.submit(x[1])
    clock.advance(0.001)
    done = {r.rid: r for r in srv.step()}
    assert done[stale].status == "timeout"
    assert done[stale].result is None
    assert done[stale].error is None
    assert done[fresh].status == "ok"
    np.testing.assert_array_equal(done[fresh].result, want[1])


def test_no_grace_means_no_timeouts(packed, batch):
    """timeout_grace=None (default): deadlines only schedule flushes —
    an ancient request is still served (the pre-existing contract)."""
    x, want = batch
    srv, clock, _ = mk_server(packed)
    rid = srv.submit(x[0])
    clock.advance(1000.0)
    done = {r.rid: r for r in srv.step()}
    assert done[rid].status == "ok"


def test_zero_deadline_budget_is_not_instant_timeout(packed, batch):
    """submit(x, deadline=0) means "flush me NOW", not "time me out
    now": with a zero budget the grace window falls back to
    default_deadline, so a flush that lands any wall-clock instant
    after submission still serves the request — while a genuinely
    ancient zero-budget request does age out."""
    x, want = batch
    srv, clock, _ = mk_server(packed, timeout_grace=2.0)
    rid = srv.submit(x[0], deadline=0.0)
    clock.advance(0.001)        # later than submit, inside 2x5ms grace
    done = {r.rid: r for r in srv.step()}
    assert done[rid].status == "ok"
    np.testing.assert_array_equal(done[rid].result, want[0])
    stale = srv.submit(x[1], deadline=0.0)
    clock.advance(0.050)        # way past the fallback grace window
    done = {r.rid: r for r in srv.step()}
    assert done[stale].status == "timeout"
    assert done[stale].result is None


def test_full_queue_sheds_with_typed_error(packed, batch):
    x, _ = batch
    srv, clock, _ = mk_server(packed, max_queue=2)
    srv.submit(x[0]); srv.submit(x[1])
    with pytest.raises(SV.BackpressureError):
        srv.submit(x[2])
    # batch API: all-or-nothing, same typed error
    with pytest.raises(SV.BackpressureError):
        srv.serve([x[2], x[3]])
    assert srv.telemetry.metrics.value("serve.shed") == 3
    # submit() and serve() bump the SAME counter pair — a dashboard
    # keyed on serve.rejected must not undercount shed batches
    assert srv.telemetry.metrics.value("serve.rejected") == 3
    assert srv.pending() == 2            # nothing half-admitted


def test_cancel_after_error_is_idempotent_noop(packed, batch):
    """cancel() is an eviction of QUEUED work; once a request reached a
    terminal state it returns False, repeatedly, and does not disturb
    the mailbox entry."""
    x, _ = batch
    srv, clock, _ = mk_server(
        packed, FaultPlan.of(FaultSpec("transient", times=99)),
        retry=SV.RetryPolicy(max_retries=0))
    rid = srv.submit(x[0])
    clock.advance(1.0)
    (req,) = srv.step()
    assert req.status == "error"
    assert srv.cancel(rid) is False
    assert srv.cancel(rid) is False
    assert srv.telemetry.metrics.value("serve.cancelled") == 0
    assert srv.take(rid) is req          # mailbox entry intact


def test_take_of_failed_rid_returns_error_status(packed, batch):
    x, _ = batch
    srv, clock, _ = mk_server(packed,
                              FaultPlan.of(FaultSpec("poison", rid=0)))
    rid = srv.submit(x[0])
    clock.advance(1.0)
    srv.step()
    got = srv.take(rid)
    assert got is not None and got.rid == rid
    assert got.status == "error"
    assert isinstance(got.error, PoisonRequestError)
    assert srv.take(rid) is None         # claimed exactly once


def test_serve_raises_on_non_ok_outcomes(packed, batch):
    """The batch API has no per-request status channel, so a non-ok
    outcome raises instead of returning None rows."""
    x, _ = batch
    srv, clock, _ = mk_server(packed,
                              FaultPlan.of(FaultSpec("poison", rid=1)))
    with pytest.raises(RuntimeError, match="non-ok"):
        srv.serve([x[0], x[1], x[2]])


# ---------------------------------------------------------------------------
# harness plumbing
# ---------------------------------------------------------------------------

def test_fault_spec_validation():
    with pytest.raises(ValueError, match="kind"):
        FaultSpec("meteor")
    with pytest.raises(ValueError, match="rid"):
        FaultSpec("poison")
    with pytest.raises(ValueError, match="survivor"):
        FaultSpec("device_loss")


def test_injector_counts_in_server_registry(packed, batch):
    x, _ = batch
    srv, clock, _ = mk_server(
        packed, FaultPlan.of(FaultSpec("transient", times=2)))
    submit_all(srv, x, range(8))
    srv.step()
    assert srv.telemetry.metrics.value("faults.injected.transient") == 2


def test_timeout_grace_validation(packed):
    with pytest.raises(ValueError, match="timeout_grace"):
        SV.PackedInferenceServer(max_batch=4, timeout_grace=0.5)


# ---------------------------------------------------------------------------
# packed checkpoints
# ---------------------------------------------------------------------------

def test_packed_checkpoint_roundtrip_bcnn(tmp_path):
    """Mixed-tree round trip: array leaves (incl. pool-mask words)
    restored bit-exact, statics (spec dataclass, geometry ints, None
    masks) grafted from the template — and the restored tree serves the
    same rows."""
    spec = cnn.BCNNSpec(input_hw=(8, 8), c_in=3,
                        stages=(cnn.ConvStage(64),
                                cnn.ConvStage(48, pool=True)),
                        dense=(64, 10))
    params = cnn.init_bcnn(jax.random.PRNGKey(0), spec)
    packed = cnn.pack_bcnn(params, spec)
    save_packed_checkpoint(str(tmp_path), 3, packed)
    assert latest_step(str(tmp_path)) == 3
    # template from the SAME config but different (wrong) weights
    params2 = cnn.init_bcnn(jax.random.PRNGKey(9), spec)
    template = cnn.pack_bcnn(params2, spec)
    restored, meta = load_packed_checkpoint(str(tmp_path), 3, template)
    assert meta["extra"]["packed_kind"] == "bcnn"
    x = np.asarray(jax.random.randint(jax.random.PRNGKey(1), (2, 8, 8, 3),
                                      0, 256), np.uint8)
    np.testing.assert_array_equal(
        np.asarray(cnn.bcnn_forward_packed(restored, x, backend="jnp")),
        np.asarray(cnn.bcnn_forward_packed(packed, x, backend="jnp")))


def test_packed_checkpoint_kind_mismatch(tmp_path, packed):
    save_packed_checkpoint(str(tmp_path), 0, packed)
    spec = cnn.BCNNSpec(input_hw=(8, 8), c_in=3,
                        stages=(cnn.ConvStage(64),), dense=(64, 10))
    template = cnn.pack_bcnn(cnn.init_bcnn(jax.random.PRNGKey(0), spec),
                             spec)
    with pytest.raises(ValueError, match="kind"):
        load_packed_checkpoint(str(tmp_path), 0, template)
