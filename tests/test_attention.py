"""Attention unit tests: chunked online-softmax vs naive reference,

masks (causal / sliding window), GQA grouping, softcap, RoPE variants."""
from _hypothesis_compat import hypothesis, st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import attention as A
from repro.models import common as C

settings = hypothesis.settings(max_examples=15, deadline=None)


def naive_attention(q, k, v, *, causal, window=None, softcap=None,
                    q_offset=0):
    b, sq, hq, d = q.shape
    _, skv, hkv, _ = k.shape
    g = hq // hkv
    kr = jnp.repeat(k, g, axis=2)
    vr = jnp.repeat(v, g, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   kr.astype(jnp.float32)) * d ** -0.5
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    qp = q_offset + jnp.arange(sq)[:, None]
    kp = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= qp >= kp
    if window is not None:
        mask &= (qp - kp) < window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", p, vr.astype(jnp.float32))
    return o


def _qkv(key, b, sq, skv, hq, hkv, d, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (b, sq, hq, d), dtype)
    k = jax.random.normal(k2, (b, skv, hkv, d), dtype)
    v = jax.random.normal(k3, (b, skv, hkv, d), dtype)
    return q, k, v


@settings
@hypothesis.given(sq=st.integers(1, 33), hkv=st.sampled_from([1, 2, 4]),
                  g=st.sampled_from([1, 2, 3]),
                  causal=st.booleans(), seed=st.integers(0, 2**31 - 1))
def test_chunked_vs_naive(sq, hkv, g, causal, seed):
    q, k, v = _qkv(jax.random.PRNGKey(seed), 2, sq, sq, hkv * g, hkv, 8)
    want = naive_attention(q, k, v, causal=causal)
    got = A.chunked_attention(q, k, v, causal=causal, q_chunk=8,
                              kv_chunk=8)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@settings
@hypothesis.given(window=st.integers(1, 12), seed=st.integers(0, 2**31 - 1))
def test_sliding_window(window, seed):
    q, k, v = _qkv(jax.random.PRNGKey(seed), 1, 20, 20, 4, 2, 8)
    want = naive_attention(q, k, v, causal=True, window=window)
    got = A.chunked_attention(q, k, v, causal=True, window=window,
                              q_chunk=8, kv_chunk=4)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_softcap():
    q, k, v = _qkv(jax.random.PRNGKey(0), 1, 9, 9, 2, 2, 8)
    want = naive_attention(q, k, v, causal=True, softcap=5.0)
    got = A.chunked_attention(q, k, v, causal=True, attn_softcap=5.0,
                              q_chunk=4, kv_chunk=4)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_q_offset_continuation():
    """Chunked attention with q_offset == suffix of the full result."""
    q, k, v = _qkv(jax.random.PRNGKey(1), 1, 16, 16, 2, 1, 8)
    full = A.chunked_attention(q, k, v, causal=True)
    part = A.chunked_attention(q[:, 12:], k, v, causal=True, q_offset=12)
    np.testing.assert_allclose(np.asarray(part), np.asarray(full[:, 12:]),
                               rtol=2e-5, atol=2e-5)


def test_chunk_size_invariance():
    q, k, v = _qkv(jax.random.PRNGKey(2), 2, 30, 30, 4, 2, 16)
    a = A.chunked_attention(q, k, v, causal=True, q_chunk=5, kv_chunk=7)
    b = A.chunked_attention(q, k, v, causal=True, q_chunk=30, kv_chunk=30)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5,
                               atol=2e-5)


# ------------------------------- RoPE --------------------------------------

def test_mrope_reduces_to_rope_for_text():
    """qwen2-vl M-RoPE with t==h==w positions == standard RoPE."""
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 10, 4, 64))
    pos = jnp.broadcast_to(jnp.arange(10)[None], (2, 10))
    want = C.apply_rope(x, pos)
    pos3 = jnp.broadcast_to(pos[None], (3, 2, 10))
    got = C.apply_mrope(x, pos3, sections=(8, 12, 12))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_partial_rope_passthrough():
    """chatglm partial rotary: the non-rotated half passes through."""
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 5, 2, 16))
    pos = jnp.arange(5)[None]
    y = C.apply_rope(x, pos, fraction=0.5)
    np.testing.assert_allclose(np.asarray(y[..., 8:]),
                               np.asarray(x[..., 8:]), rtol=1e-6, atol=0)
    assert not np.allclose(np.asarray(y[..., :8]), np.asarray(x[..., :8]))


def test_rope_relative_property():
    """RoPE inner products depend only on relative positions."""
    d = 32
    q = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 1, d))
    k = jax.random.normal(jax.random.PRNGKey(3), (1, 1, 1, d))

    def score(pq, pk):
        qr = C.apply_rope(q, jnp.array([[pq]]))
        kr = C.apply_rope(k, jnp.array([[pk]]))
        return float(jnp.sum(qr * kr))

    assert abs(score(3, 1) - score(10, 8)) < 1e-3
    assert abs(score(5, 5) - score(100, 100)) < 1e-3
