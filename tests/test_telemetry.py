"""Telemetry subsystem: metrics registry, span tracer, instrumented
serving lifecycle, the shared jaxpr traversal, and the static cost
probes (taxonomy in docs/observability.md)."""
import json

import numpy as np
import pytest

from repro import telemetry
from repro.kernels import ops as kops
from repro.models import cnn
from repro.telemetry import (LATENCY_BUCKETS_S, MetricsRegistry, Telemetry,
                             Tracer, log_spaced_buckets)
from repro.telemetry.trace import _NOOP
from repro.train import serve as SV
from repro.utils.jaxpr import (count_pallas_calls, max_intermediate_bytes,
                               pallas_grids, pallas_launches)


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------

def test_log_spaced_buckets():
    edges = log_spaced_buckets(1e-6, 100.0, 4)
    assert edges == LATENCY_BUCKETS_S
    assert list(edges) == sorted(set(edges))
    assert edges[0] == 1e-6 and edges[-1] >= 100.0
    with pytest.raises(ValueError):
        log_spaced_buckets(0.0, 1.0)
    with pytest.raises(ValueError):
        log_spaced_buckets(1.0, 0.5)


def test_counter_gauge_basics():
    m = MetricsRegistry()
    c = m.counter("c")
    c.inc()
    c.inc(3)
    assert c.value == 4
    assert m.counter("c") is c                 # get-or-create
    with pytest.raises(ValueError):
        c.inc(-1)
    g = m.gauge("g")
    g.set(2.5)
    g.set(1.0)
    assert g.value == 1.0
    assert m.value("c") == 4 and m.value("g") == 1.0
    assert m.value("never-touched") == 0


def test_kind_collision_raises():
    m = MetricsRegistry()
    m.counter("x")
    with pytest.raises(TypeError):
        m.gauge("x")
    with pytest.raises(TypeError):
        m.histogram("x")


def test_histogram_observe_and_percentile():
    m = MetricsRegistry()
    h = m.histogram("h")
    with pytest.raises(ValueError):
        h.percentile(0.5)                      # empty
    for v in (2e-6, 2e-6, 2e-6, 0.5):
        h.observe(v)
    assert h.count == 4
    assert h.min == 2e-6 and h.max == 0.5
    # nearest-rank: p50 falls in the bucket covering 2e-6; the returned
    # value is that bucket's upper edge (>= the true value, < next decade)
    p50 = h.percentile(0.5)
    assert 2e-6 <= p50 < 1e-5
    assert h.percentile(1.0) >= 0.5
    with pytest.raises(ValueError):
        h.percentile(1.5)
    with pytest.raises(ValueError):
        h.percentile(-0.1)


def test_histogram_overflow_reports_exact_max():
    m = MetricsRegistry()
    h = m.histogram("h")
    h.observe(12345.0)                         # above the 100 s ladder
    assert h.percentile(0.99) == 12345.0


def test_snapshot_reset_merge():
    a, b = MetricsRegistry(), MetricsRegistry()
    a.counter("n").inc(2)
    a.gauge("g").set(7.0)
    a.histogram("h").observe(0.001)
    snap = a.snapshot()
    assert json.loads(json.dumps(snap)) == snap        # JSON-able
    b.counter("n").inc(1)
    b.merge(snap)
    assert b.value("n") == 3
    assert b.value("g") == 7.0
    assert b.histogram("h").count == 1
    a.reset()
    assert a.value("n") == 0 and a.histogram("h").count == 0
    # merging histograms with different edges must refuse, not corrupt
    c = MetricsRegistry()
    c.histogram("h", buckets=(1.0, 2.0)).observe(1.5)
    with pytest.raises(ValueError):
        c.merge(snap)


def test_single_sample_histogram_percentiles():
    h = MetricsRegistry().histogram("h")
    h.observe(0.004)
    for q in (0.0, 0.5, 0.99, 1.0):
        assert h.percentile(q) >= 0.004


# ---------------------------------------------------------------------------
# tracer
# ---------------------------------------------------------------------------

def test_disabled_tracer_is_noop_singleton():
    tr = Tracer()
    assert not tr.enabled
    assert tr.span("a") is tr.span("b") is _NOOP
    with tr.span("a", k=1):
        pass
    tr.instant("x")
    tr.add_complete("y", 0, 10)
    assert tr.events == []


def test_spans_record_chrome_events():
    clock = iter(range(0, 100_000, 1_000))
    tr = Tracer(enabled=True, clock_ns=lambda: next(clock))
    with tr.span("outer", batch=4):
        with tr.span("inner"):
            pass
    tr.instant("mark", rid=7)
    tr.add_complete("explicit", 5_000, 9_000, rid=1)
    evs = tr.events
    assert [e["name"] for e in evs] == ["inner", "outer", "mark", "explicit"]
    outer = evs[1]
    assert outer["ph"] == "X" and outer["args"] == {"batch": 4}
    assert outer["dur"] > evs[0]["dur"]        # outer contains inner
    assert evs[2]["ph"] == "i"
    assert evs[3]["ts"] == 5.0 and evs[3]["dur"] == 4.0   # ns -> us
    doc = tr.chrome_trace()
    assert doc["traceEvents"] == evs
    assert json.loads(json.dumps(doc)) == doc


def test_tracer_bounded_buffer_counts_drops():
    tr = Tracer(enabled=True, max_events=2)
    for i in range(5):
        tr.instant(f"e{i}")
    assert len(tr.events) == 2
    assert tr.dropped == 3
    tr.clear()
    assert tr.events == [] and tr.dropped == 0


def test_tracer_export(tmp_path):
    tr = Tracer(enabled=True)
    with tr.span("s"):
        pass
    path = tmp_path / "trace.json"
    tr.export(str(path))
    doc = json.load(open(path))
    assert doc["traceEvents"][0]["name"] == "s"
    assert doc["displayTimeUnit"] == "ms"


def test_telemetry_bundle():
    tel = Telemetry()
    assert not tel.tracer.enabled
    assert tel.enable_tracing() is tel
    assert tel.tracer.enabled
    prev = telemetry.set_default(tel)
    try:
        assert telemetry.default() is tel
    finally:
        telemetry.set_default(prev)


# ---------------------------------------------------------------------------
# latency_percentile edge cases (the CLI/bench shared definition)
# ---------------------------------------------------------------------------

def test_latency_percentile_empty_raises():
    with pytest.raises(ValueError):
        SV.latency_percentile([], 0.5)


def test_latency_percentile_bad_q_raises():
    with pytest.raises(ValueError):
        SV.latency_percentile([1.0], 2.0)      # p200 typo != p100
    with pytest.raises(ValueError):
        SV.latency_percentile([1.0], -0.5)


def test_latency_percentile_single_and_ranks():
    assert SV.latency_percentile([3.0], 0.0) == 3.0
    assert SV.latency_percentile([3.0], 0.99) == 3.0
    assert SV.latency_percentile([3.0], 1.0) == 3.0
    vals = [1.0, 2.0, 3.0, 4.0]
    assert SV.latency_percentile(vals, 0.0) == 1.0
    assert SV.latency_percentile(vals, 0.5) == 3.0
    assert SV.latency_percentile(vals, 1.0) == 4.0


# ---------------------------------------------------------------------------
# instrumented serving lifecycle
# ---------------------------------------------------------------------------

def _smoke_server(**kw):
    params, spec, kind = cnn.demo_model("bmlp", smoke=True)
    srv = SV.PackedInferenceServer(**kw)
    srv.register("m", params, spec, kind=kind, backend="jnp")
    return srv


def test_serve_metrics_lifecycle():
    clock = SV.SimClock()
    srv = _smoke_server(max_batch=4, clock=clock)
    m = srv.telemetry.metrics
    eng = srv.engine()
    xs = np.zeros((5, *eng.example_shape), np.uint8)
    for x in xs[:3]:
        srv.submit(x)
    assert m.value("serve.submitted") == 3
    assert m.value("serve.queue_depth") == 3
    rid = srv.submit(xs[3])
    assert srv.cancel(rid)
    assert m.value("serve.cancelled") == 1
    clock.advance(1.0)                         # expire deadlines
    done = srv.step()
    assert len(done) == 3
    assert m.value("serve.completed") == 3
    assert m.value("serve.flushes") == 1
    assert m.value("serve.padded_rows") == 1   # 3 requests in bucket 4
    assert m.value("serve.route.gemv") == 1
    assert m.value("serve.queue_depth") == 0
    assert m.histogram("serve.request_latency_s").count == 3
    assert m.histogram("serve.queue_wait_s").count == 3
    assert m.histogram("serve.flush_wall_s").count == 1


def test_serve_backpressure_counts_rejections():
    srv = _smoke_server(max_batch=4, max_queue=1)
    x = np.zeros(srv.engine().example_shape, np.uint8)
    srv.submit(x)
    with pytest.raises(RuntimeError):
        srv.submit(x)
    assert srv.telemetry.metrics.value("serve.rejected") == 1


def test_serve_trace_spans_per_flush():
    srv = _smoke_server(max_batch=4)
    srv.telemetry.enable_tracing()
    x = np.zeros(srv.engine().example_shape, np.uint8)
    srv.serve([x, x])
    names = srv.telemetry.tracer.span_names()
    for want in ("serve.submit", "serve.queue_wait", "serve.flush",
                 "serve.bucket_pad", "serve.pack", "serve.dispatch",
                 "serve.compute", "serve.complete"):
        assert want in names, names
    flushes = [e for e in srv.telemetry.tracer.events
               if e["name"] == "serve.flush"]
    assert len(flushes) == 1
    assert flushes[0]["args"] == {"batch": 2, "bucket": 2, "route": "gemv"}
    # children nest inside the flush window
    f = flushes[0]
    for e in srv.telemetry.tracer.events:
        if e["name"] in ("serve.pack", "serve.dispatch", "serve.compute"):
            assert f["ts"] <= e["ts"]
            assert e["ts"] + e["dur"] <= f["ts"] + f["dur"] + 1e-6


def test_serve_tracing_disabled_records_nothing():
    srv = _smoke_server(max_batch=4)
    x = np.zeros(srv.engine().example_shape, np.uint8)
    srv.serve([x])
    assert srv.telemetry.tracer.events == []
    # metrics still live
    assert srv.telemetry.metrics.value("serve.flushes") == 1


# ---------------------------------------------------------------------------
# cache / pool accounting across register -> swap -> swap-back
# ---------------------------------------------------------------------------

def test_cache_counters_across_model_swaps():
    params, spec, kind = cnn.demo_model("bmlp", smoke=True)
    params2, spec2, kind2 = cnn.demo_model("bmlp", smoke=True, seed=1)
    srv = SV.PackedInferenceServer(max_batch=4)
    m = srv.telemetry.metrics
    srv.register("a", params, spec, kind=kind, backend="jnp")
    srv.register("b", params2, spec2, kind=kind2, backend="jnp")
    assert m.value("serve.cache.misses") == 2          # packed once each
    assert m.value("serve.cache.hits") == 0
    x = np.zeros(srv.engine("a").example_shape, np.uint8)
    srv.use("a")
    srv.serve([x])
    srv.use("b")
    srv.serve([x])
    srv.use("a")                                        # swap back
    srv.register("a", params, spec, kind=kind, backend="jnp")
    srv.serve([x])
    assert m.value("serve.cache.misses") == 2           # never re-packed
    assert m.value("serve.cache.hits") == 1             # the re-register
    srv.invalidate("a")
    assert m.value("serve.cache.invalidations") == 1
    srv.register("a", params, spec, kind=kind, backend="jnp")
    assert m.value("serve.cache.misses") == 3           # re-pack after inval


def test_pool_counters_buffer_reuse():
    srv = _smoke_server(max_batch=4)
    m = srv.telemetry.metrics
    eng = srv.engine()
    x = np.zeros(eng.example_shape, np.uint8)
    srv.serve([x])                                      # warm bucket 1
    assert m.value("serve.pool.allocations") == 1
    assert m.value("serve.pool.reuses") == 0
    for _ in range(3):
        srv.serve([x])                                  # steady state
    assert m.value("serve.pool.allocations") == 1       # zero new allocs
    assert m.value("serve.pool.reuses") == 3
    srv.serve([x, x, x])                                # new bucket (4? no: 4)
    assert m.value("serve.pool.allocations") == 2
    buf1 = srv.pool.batch_buffer(1, eng.example_shape)
    buf2 = srv.pool.batch_buffer(1, eng.example_shape)
    assert buf1 is buf2                                  # same object reused


def test_dispatch_batch_counts_routes():
    g = telemetry.default().metrics
    before_v = g.value("ops.dispatch.gemv")
    before_m = g.value("ops.dispatch.gemm")
    assert kops.dispatch_batch(1, 16) == "gemv"
    assert kops.dispatch_batch(64, 16) == "gemm"
    assert g.value("ops.dispatch.gemv") == before_v + 1
    assert g.value("ops.dispatch.gemm") == before_m + 1


# ---------------------------------------------------------------------------
# shared jaxpr traversal (utils/jaxpr.py)
# ---------------------------------------------------------------------------

def test_pallas_launches_names_and_grids():
    params, spec, kind = cnn.demo_model("bmlp", smoke=True)
    packed = cnn.pack_bmlp(params, spec)
    fwd = cnn.make_packed_forward(packed, backend="pallas")
    x = np.zeros((1, *cnn.packed_input_shape(packed)), np.uint8)
    launches = pallas_launches(lambda a: fwd(a), x)
    assert launches, "no pallas launches traced"
    for ln in launches:
        assert isinstance(ln.kernel, str) and ln.kernel
        assert isinstance(ln.grid, tuple)
        assert all(isinstance(d, int) and d >= 1 for d in ln.grid)
    # the three views are one traversal: they cannot disagree
    assert count_pallas_calls(lambda a: fwd(a), x) == len(launches)
    assert pallas_grids(lambda a: fwd(a), x) == [l.grid for l in launches]
    nbytes, shape = max_intermediate_bytes(lambda a: fwd(a), x)
    assert nbytes > 0 and len(shape) >= 1


def test_max_intermediate_ignores_kernel_internals():
    # jnp backend traces no pallas_call; the fused pallas epilogue must
    # not surface larger HBM intermediates than the unfused jnp path.
    params, spec, kind = cnn.demo_model("bmlp", smoke=True)
    packed = cnn.pack_bmlp(params, spec)
    x = np.zeros((8, *cnn.packed_input_shape(packed)), np.uint8)
    fused = cnn.make_packed_forward(packed, backend="pallas")
    unfused = cnn.make_packed_forward(packed, backend="jnp")
    assert count_pallas_calls(lambda a: unfused(a), x) == 0
    nb_fused, _ = max_intermediate_bytes(lambda a: fused(a), x)
    nb_unfused, _ = max_intermediate_bytes(lambda a: unfused(a), x)
    assert nb_fused <= nb_unfused


# ---------------------------------------------------------------------------
# static cost probes
# ---------------------------------------------------------------------------

def test_probe_forward_report_shape():
    from repro.telemetry import probes
    packed = probes._demo_packed("bmlp")
    cell = probes.probe_forward(packed, 1)
    assert cell["kind"] == "bmlp" and cell["batch"] == 1
    assert cell["launch_count"] == len(cell["launches"]) > 0
    assert cell["route"] == "gemv"
    assert cell["max_intermediate_bytes"] > 0
    big = probes.probe_forward(packed, 32)
    assert big["route"] == "gemm"
    assert json.loads(json.dumps(cell)) == cell


def test_probe_diff_reports_drift():
    from repro.telemetry import probes
    base = {"schema": 1, "cells": {"a": {"launch_count": 3,
                                         "launches": [1, 2, 3]}}}
    same = json.loads(json.dumps(base))
    assert probes.diff_reports(base, same) == []
    drifted = json.loads(json.dumps(base))
    drifted["cells"]["a"]["launch_count"] = 4
    drifted["cells"]["b"] = {}
    lines = probes.diff_reports(base, drifted)
    assert any("launch_count" in l for l in lines)
    assert any("NEW" in l for l in lines)


def test_probes_match_committed_baseline_unsharded():
    """The forward cells of the committed baseline must match a fresh
    trace (the sharded cells need 8 devices and are CI's job)."""
    from repro.telemetry import probes
    baseline = json.load(open(
        f"{probes.repo_root()}/{probes.BASELINE_PATH}"))
    report = probes.standard_report(sharded=False)
    keep = {k: v for k, v in baseline["cells"].items()
            if k in report["cells"]}
    drift = probes.diff_reports(
        {"schema": baseline["schema"], "cells": keep}, report)
    assert not drift, "\n".join(drift)
