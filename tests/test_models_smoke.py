"""Per-architecture smoke tests (deliverable f): REDUCED config of the

same family — forward + one train step on CPU, asserting output shapes
and no NaNs.  Full configs are exercised only via the dry-run."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_configs
from repro.models import model as M
from repro.train import trainer as TR

ARCHS = list(list_configs())


def _batch(cfg, key, b=2, s=16):
    batch = {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size),
             "labels": jax.random.randint(key, (b, s), 0, cfg.vocab_size)}
    if cfg.frontend == "vision_stub":
        batch["embeds"] = jax.random.normal(key, (b, s, cfg.d_model),
                                            jnp.bfloat16)
    if cfg.encoder_layers:
        batch["enc_embeds"] = jax.random.normal(key, (b, s, cfg.d_model),
                                                jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_no_nan(arch):
    cfg = get_config(arch, reduced=True)
    key = jax.random.PRNGKey(0)
    params = M.init_model(key, cfg)
    batch = _batch(cfg, key)
    logits = M.logits_fn(params, cfg, batch)
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step(arch):
    cfg = get_config(arch, reduced=True)
    tc = TR.TrainConfig(lr=1e-3, warmup=1, total_steps=10)
    key = jax.random.PRNGKey(1)
    state = TR.init_train_state(key, cfg, tc)
    step = jax.jit(TR.make_train_step(cfg, tc))
    batch = _batch(cfg, key)
    state, metrics = step(state, batch)
    assert jnp.isfinite(metrics["loss"])
    assert jnp.isfinite(metrics["grad_norm"])
    # params actually changed
    before = jax.tree.leaves(TR.init_train_state(key, cfg, tc)["params"])
    after = jax.tree.leaves(state["params"])
    changed = any(not np.allclose(np.asarray(a), np.asarray(b))
                  for a, b in zip(after, before))
    assert changed


@pytest.mark.parametrize("arch", ["starcoder2-3b", "qwen3-moe-30b-a3b"])
def test_binary_quant_train_step(arch):
    """The paper's technique as an LM feature: binary train step runs and
    clips latents to [-1, 1] (paper §4.4)."""
    cfg = get_config(arch, quant="binary", reduced=True)
    tc = TR.TrainConfig(lr=1e-2, warmup=1, total_steps=10)
    key = jax.random.PRNGKey(2)
    state = TR.init_train_state(key, cfg, tc)
    step = jax.jit(TR.make_train_step(cfg, tc))
    state, metrics = step(state, _batch(cfg, key))
    assert jnp.isfinite(metrics["loss"])
    for leaf in jax.tree.leaves(state["params"]):
        assert float(jnp.max(jnp.abs(leaf))) <= 1.0 + 1e-6


def test_full_configs_match_assignment():
    """Exact assigned hyperparameters (spot checks)."""
    c = get_config("nemotron-4-15b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads,
            c.d_ff, c.vocab_size) == (32, 6144, 48, 8, 24576, 256000)
    assert c.ffn_type == "relu2"
    c = get_config("gemma2-9b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads) == \
        (42, 3584, 16, 8)
    assert c.attention_pattern == ("local", "global")
    assert c.logit_softcap == 30.0
    c = get_config("qwen3-moe-30b-a3b")
    assert c.moe.num_experts == 128 and c.moe.top_k == 8
    c = get_config("mamba2-1.3b")
    assert c.ssm.d_state == 128 and c.num_layers == 48
    c = get_config("llama4-maverick-400b-a17b")
    assert c.moe.top_k == 1 and c.vocab_size == 202048
    c = get_config("recurrentgemma-9b")
    assert c.attention_pattern == ("rec", "rec", "local")
    assert c.num_kv_heads == 1
    c = get_config("whisper-base")
    assert c.encoder_layers == 6 and c.d_model == 512
    c = get_config("qwen2-vl-72b")
    assert c.num_layers == 80 and c.rope_style == "mrope"
