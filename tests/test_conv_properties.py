"""Property suite locking down the M-tiled conv grid + fused bit-plane
conv (kernels/binary_conv.py).

Invariants, sampled over the awkward-shape grid in ``strategies.py``:

* packed conv == float-sign conv reference, every backend (pallas
  interpret / jnp / ref),
* the (batch, M tiles, C_out blocks) grid is invariant to the tiling:
  any block_oh/block_n == the untiled single-tile grid, for both the
  int32 kernel and the fused BN-sign-repack kernel,
* fused single-launch bit-plane conv == the 8-plane sequential
  reference == the float path on raw fixed-precision input, including
  the uint8 edge values 0 and 255,
* ``_bitplane_conv_packed`` issues exactly ONE pallas_call,
* invalid block sizes raise instead of being silently clamped.
"""
from _hypothesis_compat import hypothesis, st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
import strategies as S

from repro.core import binarize as B
from repro.kernels import binary_conv as BC
from repro.kernels import ops, ref
from repro.models import cnn
from repro.utils.jaxpr import count_pallas_calls

settings = hypothesis.settings(max_examples=8, deadline=None)


def _conv_float_int(x, w, stride, padding):
    """Integer dots of conv(sign(x), sign(w)) with true zero padding."""
    out = jax.lax.conv_general_dilated(
        B.sign_pm1(x), jnp.transpose(B.sign_pm1(w), (1, 2, 3, 0)),
        (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return np.asarray(out).astype(np.int32)


def _bitplane_float_int(x_uint8, w, stride, padding):
    """Integer conv of the RAW fixed-precision input against sign(w)."""
    out = jax.lax.conv_general_dilated(
        x_uint8.astype(jnp.float32),
        jnp.transpose(B.sign_pm1(w), (1, 2, 3, 0)), (stride, stride),
        padding, dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return np.asarray(out).astype(np.int32)


def _draw_uint8(key, shape, fill):
    if fill == "zeros":
        return jnp.zeros(shape, jnp.uint8)
    if fill == "max255":
        return jnp.full(shape, 255, jnp.uint8)
    return jax.random.randint(key, shape, 0, 256).astype(jnp.uint8)


# ---------------------------------------------------------------------------
# Packed conv == float reference, every backend
# ---------------------------------------------------------------------------

@settings
@hypothesis.given(case=S.conv_cases(), seed=S.seeds())
def test_packed_conv_matches_float_all_backends(case, seed):
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (case.batch, case.h, case.w, case.c_in))
    wt = jax.random.normal(jax.random.fold_in(key, 1),
                           (case.c_out, case.k, case.k, case.c_in))
    want = _conv_float_int(x, wt, case.stride, case.padding)
    for backend in ("pallas", "jnp", "ref"):
        got = ops.binary_conv2d(x, wt, stride=case.stride,
                                padding=case.padding, backend=backend)
        np.testing.assert_array_equal(
            np.asarray(got), want,
            err_msg=f"{backend} backend diverged on {case}")


# ---------------------------------------------------------------------------
# The M-tiled grid is invariant to the tiling
# ---------------------------------------------------------------------------

@settings
@hypothesis.given(case=S.conv_cases(), block_oh=S.m_tilings(),
                  block_n=st.sampled_from((None, 128, 256)),
                  seed=S.seeds())
def test_m_tiled_grid_equals_untiled(case, block_oh, block_n, seed):
    """Any (block_oh, block_n) == the single-M-tile (pre-refactor) grid."""
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (case.batch, case.h, case.w, case.c_in))
    wt = jax.random.normal(jax.random.fold_in(key, 1),
                           (case.c_out, case.k, case.k, case.c_in))
    plan = BC.make_conv_plan(wt, input_hw=(case.h, case.w),
                             stride=case.stride, padding=case.padding)
    x_p = B.pack_bits(x).reshape(case.batch, case.h, case.w, -1)
    kw = dict(kh=case.k, kw=case.k, stride=case.stride, pads=plan["pads"],
              out_hw=plan["out_hw"], c_out=case.c_out,
              k_true=plan["k_true"], interpret=True)
    untiled = BC.binary_conv2d_packed(
        x_p, plan["w_packed"], plan["correction"],
        block_oh=plan["out_hw"][0], **kw)
    tiled = BC.binary_conv2d_packed(
        x_p, plan["w_packed"], plan["correction"], block_oh=block_oh,
        block_n=block_n, **kw)
    np.testing.assert_array_equal(np.asarray(tiled), np.asarray(untiled))


@settings
@hypothesis.given(case=S.conv_cases(max_hw=8), block_oh=S.m_tilings(),
                  seed=S.seeds())
def test_m_tiled_fused_epilogue_equals_untiled(case, block_oh, seed):
    """Tiling invariance holds through the fused BN-sign-repack epilogue
    (per-tile correction blocks + per-tile re-bitpack)."""
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (case.batch, case.h, case.w, case.c_in))
    wt = jax.random.normal(jax.random.fold_in(key, 1),
                           (case.c_out, case.k, case.k, case.c_in))
    tau = jax.random.normal(jax.random.fold_in(key, 2), (case.c_out,)) * 3
    flip = jnp.where(jax.random.bernoulli(jax.random.fold_in(key, 3), 0.4,
                                          (case.c_out,)), -1.0, 1.0)
    plan = BC.make_conv_plan(wt, input_hw=(case.h, case.w),
                             stride=case.stride, padding=case.padding)
    x_p = B.pack_bits(x).reshape(case.batch, case.h, case.w, -1)
    conv = ref.binary_conv2d_packed_ref(
        x_p, plan["w_packed"], plan["correction"], kh=case.k, kw=case.k,
        stride=case.stride, pads=plan["pads"], c_out=case.c_out,
        k_true=plan["k_true"])
    want = ref.bn_sign_pack_ref(conv, tau, flip)
    got = BC.binary_conv2d_bn_sign_packed(
        x_p, plan["w_packed"], plan["correction"], tau, flip, kh=case.k,
        kw=case.k, stride=case.stride, pads=plan["pads"],
        out_hw=plan["out_hw"], c_out=case.c_out, k_true=plan["k_true"],
        block_oh=block_oh, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# Bit-plane first layer: fused single launch == sequential == float
# ---------------------------------------------------------------------------

@settings
@hypothesis.given(case=S.bitplane_conv_cases(), fill=S.uint8_fill(),
                  block_oh=S.m_tilings(), seed=S.seeds())
def test_bitplane_fused_equals_sequential_equals_float(case, fill, block_oh,
                                                       seed):
    key = jax.random.PRNGKey(seed)
    xu = _draw_uint8(key, (case.batch, case.h, case.w, case.c_in), fill)
    wt = jax.random.normal(jax.random.fold_in(key, 1),
                           (case.c_out, case.k, case.k, case.c_in))
    plan = BC.make_bitplane_conv_plan(wt, input_hw=(case.h, case.w),
                                      stride=case.stride,
                                      padding=case.padding)
    want = _bitplane_float_int(xu, wt, case.stride, case.padding)
    # 8-plane sequential reference (the pre-fusion model path == the
    # 'jnp'/'ref' backend of the dispatch).
    seq = ops.bitplane_conv2d_packed(plan, xu, backend="jnp")
    np.testing.assert_array_equal(np.asarray(seq), want,
                                  err_msg=f"sequential ref != float {case}")
    # Fused single-launch kernel, any M tiling.
    fused = ops.bitplane_conv2d_packed(plan, xu, backend="pallas",
                                       block_oh=block_oh)
    np.testing.assert_array_equal(np.asarray(fused), want,
                                  err_msg=f"fused kernel != float {case}")


def test_bitplane_uint8_edges_exact():
    """Constant 0 and 255 images: every plane all-(−1) / all-(+1)."""
    wt = jax.random.normal(jax.random.PRNGKey(0), (16, 3, 3, 5))
    plan = BC.make_bitplane_conv_plan(wt, input_hw=(6, 6))
    for fill in ("zeros", "max255"):
        xu = _draw_uint8(None, (1, 6, 6, 5), fill)
        want = _bitplane_float_int(xu, wt, 1, "SAME")
        for backend in ("jnp", "pallas"):
            got = ops.bitplane_conv2d_packed(plan, xu, backend=backend)
            np.testing.assert_array_equal(np.asarray(got), want)


@settings
@hypothesis.given(seed=S.seeds(), nbits=st.sampled_from((1, 4, 8)))
def test_pack_bitplanes_matches_per_plane_pack_bits(seed, nbits):
    """Plane packing == pack_bits of the ±1-shifted plane, every plane."""
    key = jax.random.PRNGKey(seed)
    x = jax.random.randint(key, (3, 4, 37), 0, 1 << nbits
                           ).astype(jnp.uint8)
    got = B.pack_bitplanes_uint8(x, nbits)
    planes = B.bitplanes_uint8(x, nbits)
    for i in range(nbits):
        want = B.pack_bits(2.0 * planes[i].astype(jnp.float32) - 1.0)
        np.testing.assert_array_equal(np.asarray(got[i]), np.asarray(want))


def test_bitplane_conv_is_single_kernel_launch():
    """The model's stage-0 conv traces to exactly ONE pallas_call (the
    acceptance criterion: plane loop fused into the kernel, plane
    extraction/packing pure jnp)."""
    key = jax.random.PRNGKey(3)
    wt = jax.random.normal(key, (16, 3, 3, 3))
    plan = BC.make_bitplane_conv_plan(wt, input_hw=(8, 8))
    xu = jax.random.randint(jax.random.fold_in(key, 1), (2, 8, 8, 3), 0,
                            256).astype(jnp.uint8)
    n = count_pallas_calls(
        lambda v: cnn._bitplane_conv_packed(plan, v, 8, backend="pallas"),
        xu)
    assert n == 1, f"expected 1 kernel launch, traced {n}"
    # And it still computes the right thing through the model entry point.
    want = _bitplane_float_int(xu, wt, 1, "SAME")
    got = cnn._bitplane_conv_packed(plan, xu, 8, backend="pallas")
    np.testing.assert_array_equal(np.asarray(got), want)


def test_tiled_conv_launch_count_is_one():
    """M tiling multiplies grid steps, not kernel launches."""
    key = jax.random.PRNGKey(4)
    wt = jax.random.normal(key, (8, 3, 3, 4))
    plan = BC.make_conv_plan(wt, input_hw=(8, 8))
    x = jax.random.normal(jax.random.fold_in(key, 1), (1, 8, 8, 4))
    x_p = B.pack_bits(x).reshape(1, 8, 8, -1)
    n = count_pallas_calls(
        lambda v: BC.binary_conv2d_packed(
            v, plan["w_packed"], plan["correction"], kh=3, kw=3, stride=1,
            pads=plan["pads"], out_hw=plan["out_hw"], c_out=8,
            k_true=plan["k_true"], block_oh=2, interpret=True), x_p)
    assert n == 1


# ---------------------------------------------------------------------------
# Block-size knob validation (regression: silent clamp-up)
# ---------------------------------------------------------------------------

def _tiny_conv_setup():
    key = jax.random.PRNGKey(5)
    wt = jax.random.normal(key, (8, 3, 3, 4))
    plan = BC.make_conv_plan(wt, input_hw=(6, 6))
    x = jax.random.normal(jax.random.fold_in(key, 1), (1, 6, 6, 4))
    x_p = B.pack_bits(x).reshape(1, 6, 6, -1)
    return plan, x_p


@pytest.mark.parametrize("bad_block_n", [1, 64, 130, 127])
def test_block_n_below_lane_raises(bad_block_n):
    """block_n < 128 (or non-multiple) used to be silently clamped up to
    128, making the knob a no-op — it must raise."""
    plan, x_p = _tiny_conv_setup()
    with pytest.raises(ValueError, match="block_n"):
        ops.binary_conv2d_packed(plan, x_p, backend="pallas",
                                 block_n=bad_block_n)


def test_block_n_valid_values_still_work():
    plan, x_p = _tiny_conv_setup()
    want = ops.binary_conv2d_packed(plan, x_p, backend="jnp")
    for block_n in (None, 128, 256):
        got = ops.binary_conv2d_packed(plan, x_p, backend="pallas",
                                       block_n=block_n)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_block_oh_invalid_raises():
    plan, x_p = _tiny_conv_setup()
    with pytest.raises(ValueError, match="block_oh"):
        ops.binary_conv2d_packed(plan, x_p, backend="pallas", block_oh=0)


def test_bn_sign_pack_block_cw_raises():
    """The standalone epilogue kernel validates its lane-axis block the
    same way (shared check_block_lanes)."""
    from repro.kernels import fused_epilogue as FE
    x = jnp.ones((4, 64), jnp.int32)
    tau = jnp.zeros((64,))
    flip = jnp.ones((64,))
    with pytest.raises(ValueError, match="block_cw"):
        FE.bn_sign_pack(x, tau, flip, block_cw=64, interpret=True)
    with pytest.raises(ValueError, match="block_m"):
        FE.bn_sign_pack(x, tau, flip, block_m=4, interpret=True)


def test_bitpack_block_knobs_raise():
    """bitpack validates both block axes (no silent clamp-up)."""
    from repro.kernels import bitpack as BP
    x = jnp.ones((4, 64))
    with pytest.raises(ValueError, match="block_m"):
        BP.bitpack(x, block_m=3, interpret=True)
    with pytest.raises(ValueError, match="block_kw"):
        BP.bitpack(x, block_kw=64, interpret=True)


def test_bitplane_plan_carries_no_correction():
    """The bitplane plan's pad handling lives entirely in the rowsum —
    a dead zero correction array must not ride along in packed params."""
    wt = jax.random.normal(jax.random.PRNGKey(0), (8, 3, 3, 3))
    plan = BC.make_bitplane_conv_plan(wt, input_hw=(6, 6))
    assert "correction" not in plan
    assert plan["rowsum"].shape == (8,)
