"""Pallas kernel sweeps vs the pure-jnp oracles (interpret mode on CPU).

Every kernel: shapes x dtypes, bit-exact against ref.py.
"""
from _hypothesis_compat import hypothesis, st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import binarize as B
from repro.kernels import binary_matmul as BMM
from repro.kernels import bitpack as BP
from repro.kernels import ops, ref

settings = hypothesis.settings(max_examples=20, deadline=None)


@pytest.mark.parametrize("m,k,n", [
    (1, 64, 128),        # GEMV specialization (paper §6.2 batch-1 swap)
    (8, 256, 256),
    (16, 1000, 100),     # non-aligned K and N -> padding path
    (33, 4096, 65),
    (128, 8192, 128),    # one full tile in every dim
    (130, 131, 257),     # everything ragged
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_binary_matmul_shapes(m, k, n, dtype):
    key = jax.random.PRNGKey(m * 7 + k * 3 + n)
    a = jax.random.normal(key, (m, k)).astype(dtype)
    b = jax.random.normal(jax.random.fold_in(key, 1), (n, k)).astype(dtype)
    want = ref.binary_matmul_ref(a, b)
    got = ops.binary_matmul(a, b, backend="pallas")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings
@hypothesis.given(m=st.integers(1, 40), kw_mult=st.integers(1, 6),
                  n=st.integers(1, 40), seed=st.integers(0, 2**31 - 1))
def test_binary_matmul_property(m, kw_mult, n, seed):
    k = kw_mult * 32 + (seed % 31)
    key = jax.random.PRNGKey(seed)
    a = jax.random.normal(key, (m, k))
    b = jax.random.normal(jax.random.fold_in(key, 1), (n, k))
    got = BMM.binary_matmul_packed(B.pack_bits(a), B.pack_bits(b),
                                   k_true=k, interpret=True)
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(ref.binary_matmul_ref(a, b)))


@pytest.mark.parametrize("blocks", [(8, 128, 128), (16, 256, 128),
                                    (128, 128, 256)])
def test_binary_matmul_block_shape_invariance(blocks):
    """Output must not depend on the BlockSpec tiling."""
    bm, bn, bkw = blocks
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (50, 5000))
    b = jax.random.normal(jax.random.fold_in(key, 1), (70, 5000))
    want = ref.binary_matmul_ref(a, b)
    got = BMM.binary_matmul_packed(B.pack_bits(a), B.pack_bits(b),
                                   k_true=5000, block_m=bm, block_n=bn,
                                   block_kw=bkw, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("m,k", [(1, 32), (8, 4096), (20, 100), (256, 8192),
                                 (3, 33)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_bitpack_shapes(m, k, dtype):
    x = jax.random.normal(jax.random.PRNGKey(k + m), (m, k)).astype(dtype)
    got = BP.bitpack(x, interpret=True)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(ref.bitpack_ref(x)))


@settings
@hypothesis.given(m=st.integers(1, 30), k=st.integers(1, 500),
                  seed=st.integers(0, 2**31 - 1))
def test_bitpack_property(m, k, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (m, k))
    got = BP.bitpack(x, interpret=True)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(ref.bitpack_ref(x)))


def test_every_public_op_rejects_unknown_backend():
    """Regression: dispatchers used to re-implement 'auto' resolution
    inline, so a typo like backend='pallsa' silently ran the jnp path.
    Every public op must now raise through _resolve."""
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (4, 64))
    b = jax.random.normal(jax.random.fold_in(key, 1), (4, 64))
    x = jax.random.normal(jax.random.fold_in(key, 2), (1, 6, 6, 8))
    w = jax.random.normal(jax.random.fold_in(key, 3), (16, 3, 3, 8))
    xu = jax.random.randint(jax.random.fold_in(key, 4), (1, 6, 6, 3), 0,
                            256).astype(jnp.uint8)
    wu = jax.random.normal(jax.random.fold_in(key, 5), (16, 3, 3, 3))
    from repro.kernels import binary_conv as BC
    plan = BC.make_conv_plan(w, input_hw=(6, 6))
    bplan = BC.make_bitplane_conv_plan(wu, input_hw=(6, 6))
    x_p = B.pack_bits(x)
    folded = {"tau": jnp.zeros((16,)), "flip": jnp.ones((16,))}
    dense_stages = [{"w_packed": B.pack_bits(b), "k_true": 64,
                     "tau": jnp.zeros((4,)), "flip": jnp.ones((4,))}]
    calls = [
        lambda be: ops.binary_matmul(a, b, backend=be),
        lambda be: ops.binary_matmul_packed(B.pack_bits(a), B.pack_bits(b),
                                            k_true=64, backend=be),
        lambda be: ops.binary_matmul_bn_sign_packed(
            B.pack_bits(a), B.pack_bits(b), jnp.zeros((4,)),
            jnp.ones((4,)), k_true=64, backend=be),
        lambda be: ops.binary_dense_stack_packed(dense_stages,
                                                 B.pack_bits(a), backend=be),
        lambda be: ops.bitpack(a, backend=be),
        lambda be: ops.binary_conv2d_packed(plan, x_p, backend=be),
        lambda be: ops.binary_conv2d_bn_sign_packed(plan, folded, x_p,
                                                    backend=be),
        lambda be: ops.bitplane_conv2d_packed(bplan, xu, backend=be),
        lambda be: ops.bn_sign_pack(jnp.zeros((2, 16), jnp.int32),
                                    folded["tau"], folded["flip"],
                                    backend=be),
        lambda be: ops.binary_conv2d(x, w, backend=be),
    ]
    for call in calls:
        with pytest.raises(ValueError, match="unknown backend"):
            call("pallsa")


def test_binary_matmul_pallas_packs_in_kernel():
    """Regression: ops.binary_matmul used to pack both operands with the
    host-side pack_bits even on backend='pallas'.  Routed through the
    bitpack dispatcher, the traced fn now launches 3 kernels (two packs
    + the GEMM) instead of one."""
    from repro.utils.jaxpr import count_pallas_calls
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (16, 64))
    b = jax.random.normal(jax.random.fold_in(key, 1), (32, 64))
    n = count_pallas_calls(
        lambda u, v: ops.binary_matmul(u, v, backend="pallas"), a, b)
    assert n == 3, f"expected pack+pack+GEMM kernel launches, traced {n}"
    n_jnp = count_pallas_calls(
        lambda u, v: ops.binary_matmul(u, v, backend="jnp"), a, b)
    assert n_jnp == 0, n_jnp


def test_binary_conv2d_wrapper_forwards_block_knobs():
    """The convenience wrapper must reach the same tiling validation as
    the packed entry points: an off-lane block_n raises, a valid pair
    changes nothing."""
    key = jax.random.PRNGKey(7)
    x = jax.random.normal(key, (1, 6, 6, 8))
    w = jax.random.normal(jax.random.fold_in(key, 1), (16, 3, 3, 8))
    with pytest.raises(ValueError):
        ops.binary_conv2d(x, w, backend="pallas", block_n=64)
    want = ops.binary_conv2d(x, w, backend="pallas")
    got = ops.binary_conv2d(x, w, backend="pallas", block_oh=2, block_n=128)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_ops_auto_backend_cpu_is_jnp():
    a = jax.random.normal(jax.random.PRNGKey(0), (4, 64))
    b = jax.random.normal(jax.random.PRNGKey(1), (4, 64))
    np.testing.assert_array_equal(
        np.asarray(ops.binary_matmul(a, b, backend="auto")),
        np.asarray(ref.binary_matmul_ref(a, b)))


# ---------------------------------------------------------------------------
# Binary conv2d kernel (kernels/binary_conv.py) + fused epilogue
# ---------------------------------------------------------------------------

# Awkward geometries the packed path must get bit-exact: C_in not a
# multiple of 32 (sub-word and multi-word), stride 2, VALID, 1x1 kernels,
# even kernels, batch 1.
CONV_CASES = [
    (1, 7, 7, 3, 8, 3, 1, "SAME"),       # batch 1, tiny C_in
    (2, 8, 8, 20, 33, 3, 2, "SAME"),     # stride 2, ragged C_out
    (2, 9, 9, 40, 16, 3, 2, "VALID"),    # C_in > 32, not a multiple
    (1, 5, 5, 32, 10, 1, 1, "SAME"),     # 1x1 kernel
    (2, 6, 6, 64, 24, 2, 2, "VALID"),    # even kernel, stride 2
]


def _conv_float_int(x, w, stride, padding):
    """Integer dots of conv(sign(x), sign(w)) with true zero padding."""
    xb = B.sign_pm1(x)
    wb = B.sign_pm1(w)
    out = jax.lax.conv_general_dilated(
        xb, jnp.transpose(wb, (1, 2, 3, 0)), (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return np.asarray(out).astype(np.int32)


@pytest.mark.parametrize("b,h,w,c_in,c_out,k,stride,padding", CONV_CASES)
@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_binary_conv2d_matches_float(b, h, w, c_in, c_out, k, stride,
                                     padding, backend):
    key = jax.random.PRNGKey(b * 131 + h * 17 + c_in)
    x = jax.random.normal(key, (b, h, w, c_in))
    wt = jax.random.normal(jax.random.fold_in(key, 1), (c_out, k, k, c_in))
    want = _conv_float_int(x, wt, stride, padding)
    got = ops.binary_conv2d(x, wt, stride=stride, padding=padding,
                            backend=backend)
    np.testing.assert_array_equal(np.asarray(got), want)


def _rand_folded(key, c):
    tau = jax.random.normal(key, (c,)) * 3
    flip = jnp.where(jax.random.bernoulli(jax.random.fold_in(key, 1),
                                          0.4, (c,)), -1.0, 1.0)
    return {"tau": tau, "flip": flip}


@pytest.mark.parametrize("b,h,w,c_in,c_out,k,stride,padding", CONV_CASES)
def test_binary_conv2d_fused_epilogue_matches_ref(b, h, w, c_in, c_out, k,
                                                  stride, padding):
    """Fused conv+BN-sign+repack == conv, then reference threshold+pack."""
    from repro.kernels import binary_conv as BC
    key = jax.random.PRNGKey(b * 7 + c_out)
    x = jax.random.normal(key, (b, h, w, c_in))
    wt = jax.random.normal(jax.random.fold_in(key, 1), (c_out, k, k, c_in))
    plan = BC.make_conv_plan(wt, input_hw=(h, w), stride=stride,
                             padding=padding)
    x_p = ops.bitpack(x.reshape(-1, c_in), backend="jnp"
                      ).reshape(b, h, w, -1)
    folded = _rand_folded(jax.random.fold_in(key, 2), c_out)
    conv = ops.binary_conv2d_packed(plan, x_p, backend="jnp")
    want = ref.bn_sign_pack_ref(conv, folded["tau"], folded["flip"])
    for backend in ("jnp", "pallas"):
        got = ops.binary_conv2d_bn_sign_packed(plan, folded, x_p,
                                               backend=backend)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("m,c", [(1, 16), (13, 33), (40, 128), (5, 100)])
@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_bn_sign_pack_shapes(m, c, backend):
    key = jax.random.PRNGKey(m * 3 + c)
    x = jax.random.randint(key, (m, c), -200, 200)
    folded = _rand_folded(jax.random.fold_in(key, 1), c)
    got = ops.bn_sign_pack(x, folded["tau"], folded["flip"],
                           backend=backend)
    want = ref.bn_sign_pack_ref(x, folded["tau"], folded["flip"])
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_bn_sign_pack_threshold_boundary():
    """x == tau must take the >= branch, matching apply_bn_sign_folded."""
    x = jnp.array([[5, -5, 0]], dtype=jnp.int32)
    tau = jnp.array([5.0, -5.0, 0.0])
    flip = jnp.array([1.0, -1.0, 1.0])
    for backend in ("jnp", "pallas"):
        got = ops.bn_sign_pack(x, tau, flip, backend=backend)
        # ge = [T, T, T]; flip>0 = [T, F, T] -> bits [1, 0, 1] -> 0b101
        np.testing.assert_array_equal(np.asarray(got),
                                      np.array([[0b101]], dtype=np.uint32))


def test_maxpool_packed_equals_pool_then_threshold():
    """Bit-domain pooling == maxpool(int32) then threshold, both flips."""
    from repro.core import binary_layers as L
    key = jax.random.PRNGKey(3)
    z = jax.random.randint(key, (2, 6, 6, 40), -100, 100)
    folded = _rand_folded(jax.random.fold_in(key, 1), 40)
    want = ref.bn_sign_pack_ref(L.maxpool2d(z), folded["tau"],
                                folded["flip"])
    pooled = L.maxpool2d_packed(
        ops.bn_sign_pack(z, folded["tau"], folded["flip"], backend="jnp"),
        L.pool_flip_mask(folded))
    np.testing.assert_array_equal(np.asarray(pooled), np.asarray(want))
