"""Pallas kernel sweeps vs the pure-jnp oracles (interpret mode on CPU).

Every kernel: shapes x dtypes, bit-exact against ref.py.
"""
import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import binarize as B
from repro.kernels import binary_matmul as BMM
from repro.kernels import bitpack as BP
from repro.kernels import ops, ref

settings = hypothesis.settings(max_examples=20, deadline=None)


@pytest.mark.parametrize("m,k,n", [
    (1, 64, 128),        # GEMV specialization (paper §6.2 batch-1 swap)
    (8, 256, 256),
    (16, 1000, 100),     # non-aligned K and N -> padding path
    (33, 4096, 65),
    (128, 8192, 128),    # one full tile in every dim
    (130, 131, 257),     # everything ragged
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_binary_matmul_shapes(m, k, n, dtype):
    key = jax.random.PRNGKey(m * 7 + k * 3 + n)
    a = jax.random.normal(key, (m, k)).astype(dtype)
    b = jax.random.normal(jax.random.fold_in(key, 1), (n, k)).astype(dtype)
    want = ref.binary_matmul_ref(a, b)
    got = ops.binary_matmul(a, b, backend="pallas")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings
@hypothesis.given(m=st.integers(1, 40), kw_mult=st.integers(1, 6),
                  n=st.integers(1, 40), seed=st.integers(0, 2**31 - 1))
def test_binary_matmul_property(m, kw_mult, n, seed):
    k = kw_mult * 32 + (seed % 31)
    key = jax.random.PRNGKey(seed)
    a = jax.random.normal(key, (m, k))
    b = jax.random.normal(jax.random.fold_in(key, 1), (n, k))
    got = BMM.binary_matmul_packed(B.pack_bits(a), B.pack_bits(b),
                                   k_true=k, interpret=True)
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(ref.binary_matmul_ref(a, b)))


@pytest.mark.parametrize("blocks", [(8, 128, 128), (16, 256, 128),
                                    (128, 128, 256)])
def test_binary_matmul_block_shape_invariance(blocks):
    """Output must not depend on the BlockSpec tiling."""
    bm, bn, bkw = blocks
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (50, 5000))
    b = jax.random.normal(jax.random.fold_in(key, 1), (70, 5000))
    want = ref.binary_matmul_ref(a, b)
    got = BMM.binary_matmul_packed(B.pack_bits(a), B.pack_bits(b),
                                   k_true=5000, block_m=bm, block_n=bn,
                                   block_kw=bkw, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("m,k", [(1, 32), (8, 4096), (20, 100), (256, 8192),
                                 (3, 33)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_bitpack_shapes(m, k, dtype):
    x = jax.random.normal(jax.random.PRNGKey(k + m), (m, k)).astype(dtype)
    got = BP.bitpack(x, interpret=True)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(ref.bitpack_ref(x)))


@settings
@hypothesis.given(m=st.integers(1, 30), k=st.integers(1, 500),
                  seed=st.integers(0, 2**31 - 1))
def test_bitpack_property(m, k, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (m, k))
    got = BP.bitpack(x, interpret=True)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(ref.bitpack_ref(x)))


def test_ops_auto_backend_cpu_is_jnp():
    a = jax.random.normal(jax.random.PRNGKey(0), (4, 64))
    b = jax.random.normal(jax.random.PRNGKey(1), (4, 64))
    np.testing.assert_array_equal(
        np.asarray(ops.binary_matmul(a, b, backend="auto")),
        np.asarray(ref.binary_matmul_ref(a, b)))
