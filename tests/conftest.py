# Tests run on the single host CPU device (the dry-run, and ONLY the
# dry-run, forces 512 placeholder devices via XLA_FLAGS in its own
# process).  Keep jax state untouched here.
import jax

jax.config.update("jax_platform_name", "cpu")
