"""Unit + property tests for the core binarization primitives (paper §4)."""
from _hypothesis_compat import hypothesis, st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import binarize as B

settings = hypothesis.settings(max_examples=25, deadline=None)


@settings
@hypothesis.given(m=st.integers(1, 7), k=st.integers(1, 300),
                  seed=st.integers(0, 2**31 - 1))
def test_pack_unpack_roundtrip(m, k, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (m, k))
    packed = B.pack_bits(x)
    assert packed.shape == (m, B.packed_width(k))
    assert packed.dtype == jnp.uint32
    np.testing.assert_array_equal(np.asarray(B.unpack_bits(packed, k)),
                                  np.asarray(B.sign_pm1(x)))


@settings
@hypothesis.given(m=st.integers(1, 9), k=st.integers(1, 200),
                  n=st.integers(1, 9), seed=st.integers(0, 2**31 - 1))
def test_packed_matmul_identity(m, k, n, seed):
    """Paper eq. 2:  a.b == K - 2*popcount(xor) on packed words."""
    ka, kb = jax.random.split(jax.random.PRNGKey(seed))
    a = jax.random.normal(ka, (m, k))
    b = jax.random.normal(kb, (n, k))
    want = jnp.dot(B.sign_pm1(a), B.sign_pm1(b).T).astype(jnp.int32)
    got = B.packed_matmul(B.pack_bits(a), B.pack_bits(b), k)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings
@hypothesis.given(m=st.integers(1, 5), k=st.integers(64, 400),
                  n=st.integers(1, 5), blk=st.integers(1, 4),
                  seed=st.integers(0, 2**31 - 1))
def test_packed_matmul_chunked_contraction(m, k, n, blk, seed):
    ka, kb = jax.random.split(jax.random.PRNGKey(seed))
    a = jax.random.normal(ka, (m, k))
    b = jax.random.normal(kb, (n, k))
    full = B.packed_matmul(B.pack_bits(a), B.pack_bits(b), k)
    chunked = B.packed_matmul(B.pack_bits(a), B.pack_bits(b), k,
                              block_kw=blk)
    np.testing.assert_array_equal(np.asarray(full), np.asarray(chunked))


def test_packed_matmul_batched_lead_dims():
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (3, 4, 100))
    b = jax.random.normal(jax.random.fold_in(key, 1), (7, 100))
    got = B.packed_matmul(B.pack_bits(a), B.pack_bits(b), 100)
    want = jnp.einsum("bmk,nk->bmn", B.sign_pm1(a),
                      B.sign_pm1(b)).astype(jnp.int32)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_ste_gradient_window():
    """STE (paper §4.4): grad passes iff |x| <= 1."""
    x = jnp.array([-2.0, -1.0, -0.3, 0.0, 0.7, 1.0, 1.5])
    g = jax.grad(lambda v: B.binarize_ste(v).sum())(x)
    np.testing.assert_array_equal(np.asarray(g),
                                  np.array([0, 1, 1, 1, 1, 1, 0],
                                           np.float32))


def test_sign_zero_is_positive():
    assert float(B.sign_pm1(jnp.array(0.0))) == 1.0


@settings
@hypothesis.given(m=st.integers(1, 6), k=st.integers(1, 120),
                  n=st.integers(1, 6), seed=st.integers(0, 2**31 - 1))
def test_bitplane_dot_exact(m, k, n, seed):
    """Paper §4.3 (exact form): bit-plane decomposition reproduces the
    integer GEMM of uint8 inputs against ±1 weights exactly."""
    kx, kw = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.randint(kx, (m, k), 0, 256).astype(jnp.uint8)
    w = B.sign_pm1(jax.random.normal(kw, (n, k)))
    want = jnp.dot(x.astype(jnp.int32), w.astype(jnp.int32).T)
    got = B.bitplane_dot(x, w)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_mxu_unpack_equals_xnor_path():
    """DESIGN.md §2: the two GEMM strategies are numerically identical."""
    key = jax.random.PRNGKey(3)
    a = B.sign_pm1(jax.random.normal(key, (5, 96)))
    b = jax.random.normal(jax.random.fold_in(key, 1), (9, 96))
    bp = B.pack_bits(b)
    vpu = B.packed_matmul(B.pack_bits(a), bp, 96)
    mxu = B.binary_dot_unpacked_mxu(a, bp, 96, dtype=jnp.float32)
    np.testing.assert_array_equal(np.asarray(vpu),
                                  np.asarray(mxu).astype(np.int32))


def test_clip_latent():
    w = jnp.array([-3.0, -0.5, 0.5, 3.0])
    np.testing.assert_array_equal(np.asarray(B.clip_latent(w)),
                                  np.array([-1, -0.5, 0.5, 1], np.float32))
