"""The paper's technique as an LM feature (DESIGN.md §3): packed-weight

inference path, strategy equivalence, pack-once semantics, memory win."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.quantize import (GemmStrategy, QuantConfig, QuantMode)
from repro.models import linear as LN
from repro.models import model as M
from repro.utils.tree import tree_bytes


def test_packed_linear_matches_latent_binary():
    """Packed inference == latent sign-binarized training forward."""
    key = jax.random.PRNGKey(0)
    lp = LN.init_linear(key, 96, 64)
    x = jax.random.normal(jax.random.fold_in(key, 1), (5, 96))
    q = QuantConfig(mode=QuantMode.BINARY)
    want = LN.apply_linear(lp, x, q, dtype=jnp.float32)
    packed = LN.pack_linear(lp)
    got_vpu = LN.apply_linear(
        packed, x, dataclasses.replace(q, strategy=GemmStrategy.VPU_XNOR),
        dtype=jnp.float32)
    got_mxu = LN.apply_linear(
        packed, x, dataclasses.replace(q,
                                       strategy=GemmStrategy.MXU_UNPACK),
        dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(got_vpu), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(got_mxu), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_binary_weight_mode_keeps_activations_real():
    key = jax.random.PRNGKey(1)
    lp = LN.init_linear(key, 64, 32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (3, 64))
    q = QuantConfig(mode=QuantMode.BINARY_WEIGHT)
    w = lp["w"]
    alpha = jnp.mean(jnp.abs(w.T), axis=1)
    want = x @ jnp.where(w >= 0, 1.0, -1.0) * alpha
    got = LN.apply_linear(LN.pack_linear(lp), x, q, dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_pack_tree_memory_reduction():
    """Pack-once (paper C2): stacked LM weights shrink ~16x vs fp32
    (uint32 words hold 32 weights; alpha adds d_out floats)."""
    cfg = get_config("starcoder2-3b", reduced=True)
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    stack_fp = tree_bytes(params["stack"])
    packed = LN.maybe_pack_tree(params, QuantConfig(
        mode=QuantMode.BINARY_WEIGHT))
    stack_bin = tree_bytes(packed["stack"])
    assert stack_fp / stack_bin > 10    # norms/alphas keep it under 32x


def test_packed_lm_decode_runs():
    """End-to-end packed binary-weight decode (the serve path)."""
    cfg = get_config("starcoder2-3b", quant="binary_weight", reduced=True)
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    params = LN.maybe_pack_tree(params, cfg.quant)
    cache = M.init_cache(params, cfg, 2, 8)
    tok = jnp.zeros((2, 1), jnp.int32)
    logits, cache = M.decode_step(params, cfg, tok, cache, jnp.int32(0))
    assert logits.shape == (2, 1, cfg.vocab_size)
    assert jnp.isfinite(logits.astype(jnp.float32)).all()


def test_fully_binary_lm_forward_runs():
    cfg = get_config("starcoder2-3b", quant="binary", reduced=True)
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    batch = {"tokens": jnp.zeros((2, 8), jnp.int32),
             "labels": jnp.zeros((2, 8), jnp.int32)}
    loss = M.loss_fn(params, cfg, batch)
    assert jnp.isfinite(loss)


def test_auto_strategy_crossover():
    q = QuantConfig(mode=QuantMode.BINARY)
    assert q.resolve_strategy(1, 1024, 4096) == GemmStrategy.VPU_XNOR
    assert q.resolve_strategy(128, 1024, 4096) == GemmStrategy.VPU_XNOR
    assert q.resolve_strategy(8192, 1024, 4096) == GemmStrategy.MXU_UNPACK
