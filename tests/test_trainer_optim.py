"""Trainer + optimizer tests: grad-accum equivalence, compression EF

property, AdamW behavior, loss actually decreases on learnable data."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.synthetic import TokenStreamConfig, token_batch
from repro.optim import adamw as OPT
from repro.optim import compress as CMP
from repro.train import trainer as TR


def test_microbatch_grad_accum_matches_full_batch():
    """scan-accumulated grads over 4 microbatches == single-shot grads."""
    cfg = get_config("starcoder2-3b", reduced=True)
    key = jax.random.PRNGKey(0)
    tc1 = TR.TrainConfig(microbatches=1, lr=1e-3)
    tc4 = TR.TrainConfig(microbatches=4, lr=1e-3)
    state1 = TR.init_train_state(key, cfg, tc1)
    state4 = jax.tree.map(lambda x: x, state1)
    batch = {"tokens": jax.random.randint(key, (8, 16), 0, cfg.vocab_size),
             "labels": jax.random.randint(key, (8, 16), 0, cfg.vocab_size)}
    s1, m1 = jax.jit(TR.make_train_step(cfg, tc1))(state1, batch)
    s4, m4 = jax.jit(TR.make_train_step(cfg, tc4))(state4, batch)
    # loss is mean over valid tokens in both cases
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]),
                               rtol=1e-3)
    for a, b in zip(jax.tree.leaves(s1["params"]),
                    jax.tree.leaves(s4["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-2,
                                   atol=2e-4)


def test_loss_decreases_on_learnable_stream():
    cfg = get_config("starcoder2-3b", reduced=True)
    tc = TR.TrainConfig(lr=3e-3, warmup=2, total_steps=30)
    state = TR.init_train_state(jax.random.PRNGKey(0), cfg, tc)
    step = jax.jit(TR.make_train_step(cfg, tc))
    dcfg = TokenStreamConfig(vocab_size=cfg.vocab_size, seq_len=32,
                             global_batch=8)
    losses = []
    for i in range(25):
        state, metrics = step(state, token_batch(dcfg, i))
        losses.append(float(metrics["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.5, losses


def test_signsgd_ef_error_feedback_property():
    """EF invariant: comp_t + e_t == g_t + e_{t-1}; over steps, the sum of
    transmitted values tracks the sum of true gradients (error does not
    accumulate unboundedly)."""
    key = jax.random.PRNGKey(0)
    grads = {"w": jax.random.normal(key, (64,))}
    err = CMP.signsgd_ef_init(grads)
    total_true = jnp.zeros((64,))
    total_sent = jnp.zeros((64,))
    for i in range(50):
        g = {"w": jax.random.normal(jax.random.fold_in(key, i), (64,))}
        comp, err = CMP.signsgd_ef_compress(g, err)
        total_true += g["w"]
        total_sent += comp["w"]
    # residual bounded by the last error, not growing with T
    resid = np.abs(np.asarray(total_true - total_sent - err["w"])).max()
    assert resid < 1e-4
    # compressed really is 1-bit-per-element (sign * per-tensor scale)
    vals = np.unique(np.round(np.asarray(comp["w"]), 6))
    assert len(vals) <= 2


def test_adamw_latent_clip():
    cfg = OPT.AdamWConfig(lr=1.0, weight_decay=0.0, clip_latent=True)
    params = {"w": jnp.array([0.95, -0.95])}
    state = OPT.adamw_init(params)
    grads = {"w": jnp.array([-1.0, 1.0])}
    new_p, state, _ = OPT.adamw_update(cfg, params, grads, state)
    assert float(jnp.max(jnp.abs(new_p["w"]))) <= 1.0


def test_adamw_descends_quadratic():
    cfg = OPT.AdamWConfig(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.array([5.0, -3.0])}
    state = OPT.adamw_init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state, _ = OPT.adamw_update(cfg, params, grads, state)
    assert float(jnp.abs(params["w"]).max()) < 0.2


def test_compressed_training_still_learns():
    cfg = get_config("starcoder2-3b", reduced=True)
    tc = TR.TrainConfig(lr=3e-3, warmup=2, total_steps=30,
                        compress_grads=True)
    state = TR.init_train_state(jax.random.PRNGKey(0), cfg, tc)
    step = jax.jit(TR.make_train_step(cfg, tc))
    dcfg = TokenStreamConfig(vocab_size=cfg.vocab_size, seq_len=32,
                             global_batch=8)
    losses = []
    for i in range(25):
        state, metrics = step(state, token_batch(dcfg, i))
        losses.append(float(metrics["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3, losses
