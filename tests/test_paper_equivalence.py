"""The paper's central correctness claim (§6): the optimized binary path

is numerically equivalent to the non-optimized binary reference — for
both the MLP (Table 2) and the CNN (Table 3) networks.
"""
from _hypothesis_compat import hypothesis, st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import cnn

settings = hypothesis.settings(max_examples=8, deadline=None)


def _randomize_bn(params, key):
    bns = params.get("bns", []) + params.get("conv_bns", []) \
        + params.get("dense_bns", [])
    for i, bn in enumerate(bns):
        c = bn["gamma"].shape[0]
        k = jax.random.fold_in(key, i)
        ks = jax.random.split(k, 5)
        bn["gamma"] = jax.random.uniform(ks[0], (c,), minval=0.3,
                                         maxval=1.5) * jnp.where(
            jax.random.bernoulli(ks[4], 0.3, (c,)), -1.0, 1.0)
        bn["beta"] = jax.random.normal(ks[1], (c,))
        bn["mean"] = jax.random.normal(ks[2], (c,)) * 3
        bn["var"] = jax.random.uniform(ks[3], (c,), minval=0.5, maxval=2.0)
    return params


@settings
@hypothesis.given(seed=st.integers(0, 2**31 - 1), b=st.integers(1, 5),
                  d_in=st.integers(8, 64), width=st.integers(16, 96))
def test_bmlp_packed_equals_reference(seed, b, d_in, width):
    key = jax.random.PRNGKey(seed)
    spec = cnn.BMLPSpec(sizes=(d_in, width, width // 2, 10))
    params = _randomize_bn(cnn.init_bmlp(key, spec), key)
    x = jax.random.randint(jax.random.fold_in(key, 1), (b, d_in), 0,
                           256).astype(jnp.uint8)
    want = cnn.bmlp_forward_float(params, x)
    got = cnn.bmlp_forward_packed(cnn.pack_bmlp(params, spec), x,
                                  backend="jnp")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


@settings
@hypothesis.given(seed=st.integers(0, 2**31 - 1))
def test_bcnn_packed_equals_reference(seed):
    key = jax.random.PRNGKey(seed)
    spec = cnn.BCNNSpec(
        input_hw=(8, 8), c_in=3,
        stages=(cnn.ConvStage(16), cnn.ConvStage(16, pool=True),
                cnn.ConvStage(32, pool=True)),
        dense=(48, 10))
    params = _randomize_bn(cnn.init_bcnn(key, spec), key)
    x = jax.random.randint(jax.random.fold_in(key, 1), (2, 8, 8, 3), 0,
                           256).astype(jnp.uint8)
    want = cnn.bcnn_forward_float(params, x, spec)
    got = cnn.bcnn_forward_packed(cnn.pack_bcnn(params, spec), x,
                                  backend="jnp")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-3)


def test_bcnn_pallas_backend_matches_jnp():
    """The pallas (interpret) and jnp backends agree bit-for-bit."""
    key = jax.random.PRNGKey(7)
    spec = cnn.BCNNSpec(input_hw=(8, 8), c_in=3,
                        stages=(cnn.ConvStage(16, pool=True),),
                        dense=(32, 10))
    params = _randomize_bn(cnn.init_bcnn(key, spec), key)
    x = jax.random.randint(jax.random.fold_in(key, 1), (2, 8, 8, 3), 0,
                           256).astype(jnp.uint8)
    packed = cnn.pack_bcnn(params, spec)
    a = cnn.bcnn_forward_packed(packed, x, backend="jnp")
    b = cnn.bcnn_forward_packed(packed, x, backend="pallas")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6,
                               atol=1e-6)


@pytest.mark.parametrize("h,c_in,c_out,k,stride,padding", [
    (8, 20, 8, 3, 1, "SAME"),     # C_in not a multiple of 32
    (9, 3, 12, 3, 2, "SAME"),     # stride 2, odd spatial
    (8, 40, 8, 3, 1, "VALID"),    # VALID, multi-word ragged C_in
    (6, 33, 8, 1, 1, "SAME"),     # 1x1 kernel
    (7, 16, 8, 3, 2, "VALID"),    # stride 2 + VALID
])
@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_conv_packed_equals_float_awkward_shapes(h, c_in, c_out, k, stride,
                                                 padding, backend):
    """Layer-level claim on awkward shapes (batch 1 included): the packed

    conv path matches apply_binary_conv2d_float exactly on integer dots."""
    from repro.core import binarize as B
    from repro.core import binary_layers as L
    from repro.kernels import ops as kops
    key = jax.random.PRNGKey(h * 31 + c_in * 7 + c_out)
    x = jax.random.normal(key, (1, h, h, c_in))
    params = L.init_binary_conv2d(jax.random.fold_in(key, 1), k, k, c_in,
                                  c_out)
    want = L.apply_binary_conv2d_float(params, x, stride=stride,
                                       padding=padding)
    packed = L.pack_binary_conv2d(params, input_hw=(h, h), stride=stride,
                                  padding=padding)
    x_p = kops.bitpack(B.sign_pm1(x).reshape(-1, c_in), backend="jnp"
                       ).reshape(1, h, h, -1)
    got = L.apply_binary_conv2d_packed(packed, x_p, backend=backend)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(want).astype(np.int32))


def test_bcnn_fused_path_ragged_channels():
    """Full fused pipeline with channel counts that are NOT multiples of

    32: the fused epilogue's zero-bit tails, the bit-domain pooling, and
    the grouped conv->dense boundary packing must all stay exact."""
    key = jax.random.PRNGKey(11)
    spec = cnn.BCNNSpec(
        input_hw=(8, 8), c_in=3,
        stages=(cnn.ConvStage(20), cnn.ConvStage(24, pool=True),
                cnn.ConvStage(40, pool=True)),
        dense=(33, 10))
    params = _randomize_bn(cnn.init_bcnn(key, spec), key)
    x = jax.random.randint(jax.random.fold_in(key, 1), (3, 8, 8, 3), 0,
                           256).astype(jnp.uint8)
    want = cnn.bcnn_forward_float(params, x, spec)
    packed = cnn.pack_bcnn(params, spec)
    for backend in ("jnp", "pallas"):
        got = cnn.bcnn_forward_packed(packed, x, backend=backend)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-3)


def test_paper_architectures_instantiate():
    """The full paper architectures (Table 2/3) build and pack."""
    mlp_spec = cnn.BMLPSpec()            # 784-4096^3-10
    assert mlp_spec.sizes == (784, 4096, 4096, 4096, 10)
    cnn_spec = cnn.BCNNSpec()            # 2x128C3-MP2-...-1024FC-10
    assert cnn_spec.stages[-1].c_out == 512
    # memory: packed vs float parameter bytes (paper reports ~31x)
    key = jax.random.PRNGKey(0)
    spec = cnn.BMLPSpec(sizes=(784, 512, 10))
    params = cnn.init_bmlp(key, spec)
    packed = cnn.pack_bmlp(params, spec)
    fp_bytes = sum(p["w"].size * 4 for p in params["layers"])
    bin_bytes = sum(p["w_packed"].size * 4 for p in packed["layers"])
    assert fp_bytes / bin_bytes > 28     # ~32x less (padding overhead)
