"""End-to-end behaviour tests for the whole system.

1. The paper's pipeline: train-style latent BMLP -> pack -> binary
   inference agrees; speed/memory claims structurally verified elsewhere
   (benchmarks/).
2. LM pipeline: train a reduced arch on the synthetic stream, checkpoint,
   kill, restore, continue — loss continues to drop and the data cursor
   resumes deterministically.
3. Serving: prefill + batched greedy decode produces deterministic
   tokens.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, load_checkpoint, save_checkpoint
from repro.configs import get_config
from repro.data.synthetic import TokenStreamConfig, token_batch
from repro.models import cnn
from repro.models import model as M
from repro.train import trainer as TR


def test_paper_pipeline_end_to_end():
    """BinaryNet-style training signature -> Espresso-style deployment."""
    key = jax.random.PRNGKey(0)
    spec = cnn.BMLPSpec(sizes=(16, 32, 10))
    params = cnn.init_bmlp(key, spec)
    x = jax.random.randint(key, (4, 16), 0, 256).astype(jnp.uint8)
    y = jax.random.randint(jax.random.fold_in(key, 1), (4,), 0, 10)

    def loss_fn(p):
        logits = cnn.bmlp_forward_float(p, x, ste=True)
        return -jnp.mean(jax.nn.log_softmax(logits)[jnp.arange(4), y])

    g = jax.grad(loss_fn)(params)
    # STE gives nonzero weight grads (trainable)
    assert any(float(jnp.abs(l).max()) > 0
               for l in jax.tree.leaves(g["layers"]))
    # deploy: pack once, run packed
    packed = cnn.pack_bmlp(params, spec)
    out = cnn.bmlp_forward_packed(packed, x, backend="jnp")
    ref = cnn.bmlp_forward_float(params, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5,
                               atol=1e-4)


def test_train_kill_restore_continue(tmp_path):
    cfg = get_config("starcoder2-3b", reduced=True)
    tc = TR.TrainConfig(lr=3e-3, warmup=2, total_steps=40)
    dcfg = TokenStreamConfig(vocab_size=cfg.vocab_size, seq_len=32,
                             global_batch=8)
    step = jax.jit(TR.make_train_step(cfg, tc))

    # run A: 10 steps, checkpoint at 9
    state = TR.init_train_state(jax.random.PRNGKey(0), cfg, tc)
    for i in range(10):
        state, m = step(state, token_batch(dcfg, i))
    save_checkpoint(str(tmp_path), 9, state, extra={"data_step": 10})
    for i in range(10, 15):
        state, m = step(state, token_batch(dcfg, i))
    loss_a = float(m["loss"])
    ref_leaf = np.asarray(jax.tree.leaves(state["params"])[0])

    # run B: restore at 9, replay the same data steps
    state_b = TR.init_train_state(jax.random.PRNGKey(0), cfg, tc)
    state_b, meta = load_checkpoint(str(tmp_path),
                                    latest_step(str(tmp_path)), state_b)
    assert meta["extra"]["data_step"] == 10
    for i in range(10, 15):
        state_b, m_b = step(state_b, token_batch(dcfg, i))
    loss_b = float(m_b["loss"])
    np.testing.assert_allclose(loss_a, loss_b, rtol=1e-4)
    np.testing.assert_allclose(
        ref_leaf, np.asarray(jax.tree.leaves(state_b["params"])[0]),
        rtol=1e-4, atol=1e-5)


def test_greedy_decode_deterministic():
    cfg = get_config("starcoder2-3b", reduced=True)
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                              cfg.vocab_size)

    def gen():
        logits, cache = M.prefill(params, cfg, {"tokens": toks}, 16)
        tok = jnp.argmax(logits[:, 0], -1)[:, None].astype(jnp.int32)
        out = [tok]
        for t in range(7):
            logits, cache = M.decode_step(params, cfg, tok, cache,
                                          jnp.int32(8 + t))
            tok = jnp.argmax(logits[:, 0], -1)[:, None].astype(jnp.int32)
            out.append(tok)
        return np.asarray(jnp.concatenate(out, 1))

    a, b = gen(), gen()
    np.testing.assert_array_equal(a, b)


def test_data_stream_deterministic_and_learnable():
    dcfg = TokenStreamConfig(vocab_size=101, seq_len=16, global_batch=4)
    b1 = token_batch(dcfg, 5)
    b2 = token_batch(dcfg, 5)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    # labels are the next token (shifted)
    np.testing.assert_array_equal(np.asarray(b1["tokens"][:, 1:]),
                                  np.asarray(b1["labels"][:, :-1]))
