"""Integration: prefill + token-by-token decode == full teacher-forced

forward, for every family's cache type (global KV, local ring buffer,
RG-LRU state, SSD state, MoE dispatch, whisper self+cross)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model as M

DECODER_ARCHS = ["starcoder2-3b", "gemma2-9b", "chatglm3-6b",
                 "nemotron-4-15b", "qwen2-vl-72b", "mamba2-1.3b",
                 "recurrentgemma-9b", "qwen3-moe-30b-a3b",
                 "llama4-maverick-400b-a17b"]


def _ample_moe(cfg):
    if cfg.moe is not None:
        return dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, capacity_factor=8.0))
    return cfg


@pytest.mark.parametrize("arch", DECODER_ARCHS)
def test_prefill_decode_matches_forward(arch):
    cfg = _ample_moe(get_config(arch, reduced=True))
    key = jax.random.PRNGKey(0)
    params = M.init_model(key, cfg)
    B, S, T = 2, 12, 4
    toks = jax.random.randint(jax.random.fold_in(key, 1), (B, S + T), 0,
                              cfg.vocab_size)
    full = M.logits_fn(params, cfg, {"tokens": toks}).astype(jnp.float32)
    lg, cache = M.prefill(params, cfg, {"tokens": toks[:, :S]}, S + T)
    np.testing.assert_allclose(np.asarray(lg[:, 0].astype(jnp.float32)),
                               np.asarray(full[:, S - 1]), rtol=3e-2,
                               atol=3e-2)
    for t in range(T):
        lg, cache = M.decode_step(params, cfg, toks[:, S + t:S + t + 1],
                                  cache, jnp.int32(S + t))
        np.testing.assert_allclose(
            np.asarray(lg[:, 0].astype(jnp.float32)),
            np.asarray(full[:, S + t]), rtol=3e-2, atol=3e-2)


def test_local_ring_buffer_wraps():
    """Decode past the window: ring slots are overwritten and masked
    correctly (window smaller than the generated length)."""
    cfg = get_config("recurrentgemma-9b", reduced=True)   # window 8
    key = jax.random.PRNGKey(3)
    params = M.init_model(key, cfg)
    B, total = 1, 24
    toks = jax.random.randint(jax.random.fold_in(key, 1), (B, total), 0,
                              cfg.vocab_size)
    full = M.logits_fn(params, cfg, {"tokens": toks}).astype(jnp.float32)
    cache = M.init_cache(params, cfg, B, total)
    for t in range(total):
        lg, cache = M.decode_step(params, cfg, toks[:, t:t + 1], cache,
                                  jnp.int32(t))
        np.testing.assert_allclose(
            np.asarray(lg[:, 0].astype(jnp.float32)),
            np.asarray(full[:, t]), rtol=4e-2, atol=4e-2)


@pytest.mark.parametrize("arch", ["starcoder2-3b", "gemma2-9b"])
def test_int8_kv_cache_decode(arch):
    """Beyond-paper int8 KV cache: prefill+decode stays within loose
    tolerance of the bf16-cache full forward (quantization noise only);
    cache buffers really are int8."""
    cfg = dataclasses.replace(get_config(arch, reduced=True),
                              kv_cache_dtype="int8")
    key = jax.random.PRNGKey(0)
    params = M.init_model(key, cfg)
    B, S, T = 2, 12, 4
    toks = jax.random.randint(jax.random.fold_in(key, 1), (B, S + T), 0,
                              cfg.vocab_size)
    full = M.logits_fn(params, cfg, {"tokens": toks}).astype(jnp.float32)
    lg, cache = M.prefill(params, cfg, {"tokens": toks[:, :S]}, S + T)
    leaves = jax.tree.leaves(cache)
    assert any(l.dtype == jnp.int8 for l in leaves)
    np.testing.assert_allclose(np.asarray(lg[:, 0].astype(jnp.float32)),
                               np.asarray(full[:, S - 1]), rtol=0.15,
                               atol=0.15)
    for t in range(T):
        lg, cache = M.decode_step(params, cfg, toks[:, S + t:S + t + 1],
                                  cache, jnp.int32(S + t))
        np.testing.assert_allclose(
            np.asarray(lg[:, 0].astype(jnp.float32)),
            np.asarray(full[:, S + t]), rtol=0.15, atol=0.15)


def test_local_decode_matches_chunked_attention_across_wrap():
    """Module-level local attention: per-token ``attention_decode``
    through the ring buffer equals ``attention_forward`` (the
    ``chunked_attention`` full-sequence path) for every position of a
    sequence three windows long — the ring wraps twice."""
    from repro.models import attention as A
    cfg = get_config("gemma2-9b", reduced=True)          # window 8
    key = jax.random.PRNGKey(7)
    params = A.init_attention(key, cfg)
    B, total = 2, 24
    assert total > 2 * cfg.window_size
    x = jax.random.normal(jax.random.fold_in(key, 1),
                          (B, total, cfg.d_model), jnp.bfloat16)
    positions = jnp.arange(total)[None, :].repeat(B, axis=0)
    full = A.attention_forward(params, cfg, x, positions=positions,
                               kind="local").astype(jnp.float32)
    cache = A.init_attn_cache(cfg, B, total, kind="local")
    for t in range(total):
        y, cache = A.attention_decode(params, cfg, x[:, t:t + 1], cache,
                                      jnp.int32(t), kind="local")
        np.testing.assert_allclose(
            np.asarray(y[:, 0].astype(jnp.float32)),
            np.asarray(full[:, t]), rtol=2e-2, atol=2e-2)


def test_local_ring_smaller_than_window():
    """Regression: the local validity mask must come from the ACTUAL
    ring size min(max_len, window_size), not cfg.window_size — a cache
    allocated for max_len < window used to consult the wrong extent."""
    from repro.models import attention as A
    cfg = get_config("gemma2-9b", reduced=True)          # window 8
    key = jax.random.PRNGKey(8)
    params = A.init_attention(key, cfg)
    B, total = 2, 6
    assert total < cfg.window_size
    x = jax.random.normal(jax.random.fold_in(key, 1),
                          (B, total, cfg.d_model), jnp.bfloat16)
    positions = jnp.arange(total)[None, :].repeat(B, axis=0)
    full = A.attention_forward(params, cfg, x, positions=positions,
                               kind="local").astype(jnp.float32)
    cache = A.init_attn_cache(cfg, B, total, kind="local")
    assert cache["k"].shape[1] == total               # ring < window
    for t in range(total):
        y, cache = A.attention_decode(params, cfg, x[:, t:t + 1], cache,
                                      jnp.int32(t), kind="local")
        np.testing.assert_allclose(
            np.asarray(y[:, 0].astype(jnp.float32)),
            np.asarray(full[:, t]), rtol=2e-2, atol=2e-2)


def test_attention_decode_rejects_cross_kv():
    """Regression: ``cross_kv`` used to be silently ignored (dead
    ``pass`` branch) — now it's a loud NotImplementedError pointing at
    ``cross_attention_decode``."""
    from repro.models import attention as A
    cfg = get_config("gemma2-9b", reduced=True)
    params = A.init_attention(jax.random.PRNGKey(0), cfg)
    cache = A.init_attn_cache(cfg, 1, 4)
    x = jnp.zeros((1, 1, cfg.d_model), jnp.bfloat16)
    fake_kv = (jnp.zeros((1, 4, cfg.num_kv_heads, cfg.head_dim)),) * 2
    with pytest.raises(NotImplementedError, match="cross_attention_decode"):
        A.attention_decode(params, cfg, x, cache, jnp.int32(0),
                           cross_kv=fake_kv)


def test_whisper_decode_matches_teacher_forcing():
    cfg = get_config("whisper-base", reduced=True)
    key = jax.random.PRNGKey(4)
    params = M.init_model(key, cfg)
    from repro.models import encdec as ED
    B, S_enc, T = 2, 10, 6
    enc = jax.random.normal(key, (B, S_enc, cfg.d_model), jnp.bfloat16)
    toks = jax.random.randint(jax.random.fold_in(key, 1), (B, T), 0,
                              cfg.vocab_size)
    full = M.logits_fn(params, cfg, {"tokens": toks, "enc_embeds": enc}
                       ).astype(jnp.float32)
    enc_out = ED.encode(params["encdec"], cfg, enc)
    cache = M.init_cache(params, cfg, B, T, enc_len=S_enc)
    cache["cross"] = ED.precompute_cross_kv(params["encdec"], cfg, enc_out)
    for t in range(T):
        lg, cache = M.decode_step(params, cfg, toks[:, t:t + 1], cache,
                                  jnp.int32(t))
        np.testing.assert_allclose(
            np.asarray(lg[:, 0].astype(jnp.float32)),
            np.asarray(full[:, t]), rtol=3e-2, atol=3e-2)
