"""Property suite for the flash-style blocked binary attention kernel.

Three-way contract over ragged (Sq, Skv, Hq, Hkv) geometries
(``strategies.attention_cases``):

    pallas kernel == jnp oracle (``ref.binary_attention_ref``)
                  == float-sign reference (naive softmax attention on
                     sign-binarized Q/K — an independent formulation)

plus block-knob invariance and the raising knob/argument validation.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import hypothesis
from strategies import attention_blocks, attention_cases, seeds, \
    words_per_steps

from repro.core import binarize as B
from repro.kernels import ops as kops
from repro.kernels import ref as kref

settings = hypothesis.settings(max_examples=15, deadline=None)


def _qkv(case, seed):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 3)
    hq = case.hkv * case.group
    q = jax.random.normal(ks[0], (case.batch, case.sq, hq, case.d),
                          jnp.float32)
    k = jax.random.normal(ks[1], (case.batch, case.skv, case.hkv, case.d),
                          jnp.float32)
    v = jax.random.normal(ks[2], (case.batch, case.skv, case.hkv, case.d),
                          jnp.float32)
    return q, k, v


def _float_sign_naive(q, k, v, *, causal, window, q_offset):
    """Independent reference: exact-softmax attention over the ±1
    sign-binarized Q/K (einsum form, no online recurrence, no packing)."""
    hq, hkv = q.shape[2], k.shape[2]
    g = hq // hkv
    qb = B.sign_pm1(q)
    kb = jnp.repeat(B.sign_pm1(k), g, axis=2)
    vf = jnp.repeat(v.astype(jnp.float32), g, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", qb, kb) * q.shape[-1] ** -0.5
    qpos = q_offset + jnp.arange(q.shape[1])[:, None]
    kpos = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones_like(qpos >= kpos)
    if causal:
        mask = mask & (qpos >= kpos)
    if window is not None:
        mask = mask & (qpos - kpos < window)
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, vf)


@hypothesis.given(case=attention_cases(), seed=seeds())
@settings
def test_kernel_matches_oracle_and_float_sign(case, seed):
    q, k, v = _qkv(case, seed)
    q_offset = max(0, case.skv - case.sq)
    kw = dict(causal=case.causal, window=case.window, q_offset=q_offset)
    out = kops.binary_attention(q, k, v, backend="pallas", **kw)
    oracle = kref.binary_attention_ref(q, k, v, **kw)
    naive = _float_sign_naive(q, k, v, **kw)
    np.testing.assert_allclose(np.asarray(out), np.asarray(oracle),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(oracle), np.asarray(naive),
                               rtol=2e-5, atol=2e-5)


@hypothesis.given(case=attention_cases(), blocks=attention_blocks(),
                  ws=words_per_steps(), seed=seeds())
@settings
def test_output_invariant_to_block_knobs(case, blocks, ws, seed):
    q, k, v = _qkv(case, seed)
    q_offset = max(0, case.skv - case.sq)
    kw = dict(causal=case.causal, window=case.window, q_offset=q_offset)
    base = kops.binary_attention(q, k, v, backend="pallas", **kw)
    block_q, block_kv = blocks
    out = kops.binary_attention(q, k, v, backend="pallas", block_q=block_q,
                                block_kv=block_kv, words_per_step=ws, **kw)
    np.testing.assert_allclose(np.asarray(out), np.asarray(base),
                               rtol=2e-5, atol=2e-5)


def test_softcap_and_backends_agree():
    """Deterministic spot-check of the softcap path (gemma-2 form) on
    every backend, GQA heads, ragged head_dim."""
    case_q, case_k = 9, 21
    key = jax.random.PRNGKey(7)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (2, case_q, 4, 33), jnp.float32)
    k = jax.random.normal(ks[1], (2, case_k, 2, 33), jnp.float32)
    v = jax.random.normal(ks[2], (2, case_k, 2, 33), jnp.float32)
    kw = dict(causal=True, window=7, attn_softcap=30.0,
              q_offset=case_k - case_q)
    out_p = kops.binary_attention(q, k, v, backend="pallas", **kw)
    out_j = kops.binary_attention(q, k, v, backend="jnp", **kw)
    out_r = kops.binary_attention(q, k, v, backend="ref", **kw)
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_j),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_array_equal(np.asarray(out_j), np.asarray(out_r))


def test_invalid_knobs_raise():
    q = jnp.zeros((1, 4, 2, 16), jnp.float32)
    k = v = jnp.zeros((1, 4, 1, 16), jnp.float32)
    with pytest.raises(ValueError, match="block_q"):
        kops.binary_attention(q, k, v, backend="pallas", block_q=7)
    with pytest.raises(ValueError, match="block_kv"):
        kops.binary_attention(q, k, v, backend="pallas", block_kv=64)
    with pytest.raises(ValueError, match="words_per_step"):
        kops.binary_attention(q, k, v, backend="pallas", words_per_step=3)
    with pytest.raises(ValueError, match="window"):
        kops.binary_attention(q, k, v, window=0)
    with pytest.raises(ValueError, match="backend"):
        kops.binary_attention(q, k, v, backend="pallsa")
    k2 = v2 = jnp.zeros((1, 4, 2, 16), jnp.float32)
    with pytest.raises(ValueError, match="Hq"):
        kops.binary_attention(jnp.zeros((1, 4, 3, 16)), k2, v2,
                              backend="pallas")


def test_no_score_matrix_in_hbm():
    """The flash property: the largest live intermediate of the packed
    attention launch stays far below the (B, Hq, Sq, Skv) float score
    matrix an unfused attention materializes.  Traces the attention
    stage on pre-packed Q/K — the online-softmax claim is about the
    launch, not the (linear-in-S) bitpack staging in front of it —
    and jaxpr never descends into kernel bodies, so intermediates are
    exactly the HBM-visible arrays."""
    from repro.kernels import binary_attention as BA
    from repro.utils import jaxpr as J
    b, s, h, d = 1, 1024, 4, 64
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (b, s, h, d), jnp.float32)
    qp = kops.bitpack(q)

    def packed(qp, kp, v):
        return BA.binary_attention_packed(qp, kp, v, d_true=d,
                                          causal=True, interpret=True)

    def unfused(q, k, v):
        return _float_sign_naive(q, k, v, causal=True, window=None,
                                 q_offset=0)

    packed_bytes, packed_shape = J.max_intermediate_bytes(packed, qp, qp, q)
    unfused_bytes, _ = J.max_intermediate_bytes(unfused, q, q, q)
    score_bytes = b * h * s * s * 4
    assert unfused_bytes >= score_bytes
    assert packed_bytes < score_bytes / 4, (packed_bytes, packed_shape)
